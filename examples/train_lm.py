"""End-to-end training driver example: a ~10M-param qwen3-family model for a
few hundred steps on the synthetic corpus, with checkpoints, auto-resume and
the fault-tolerance machinery of launch/train.py.

This is the reduced-config version of the exact driver the dry-run compiles
at production scale (same train_step, same sharding rules; the mesh here is
whatever devices exist — 1 CPU device in this container).

Run:  PYTHONPATH=src python examples/train_lm.py
(~5 min on 1 CPU core; pass --steps 60 for a quicker look)
"""

import argparse
import shutil
import sys
import tempfile

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="")
args = ap.parse_args()

ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
print(f"checkpoints -> {ckpt}")

# Phase 1: train to steps/2, checkpointing every 25 steps.
rc = train_main([
    "--arch", "qwen3-8b", "--smoke",
    "--steps", str(args.steps // 2),
    "--batch", "8", "--seq", "128",
    "--lr", "3e-3", "--schedule", "wsd", "--warmup", "20",
    "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "10",
])
assert rc == 0

# Phase 2: simulate a restart — the driver auto-resumes from the latest
# checkpoint (elastic restore path) and trains to the full step count.
print("\n--- simulated restart: auto-resume from latest checkpoint ---\n")
rc = train_main([
    "--arch", "qwen3-8b", "--smoke",
    "--steps", str(args.steps),
    "--batch", "8", "--seq", "128",
    "--lr", "3e-3", "--schedule", "wsd", "--warmup", "20",
    "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "10",
])
assert rc == 0
if not args.ckpt_dir:
    shutil.rmtree(ckpt, ignore_errors=True)
print("OK — trained, checkpointed, restarted, resumed.")
