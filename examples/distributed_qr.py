"""Distributed FiGaRo: domain-parallel QR over a mesh (paper Exp. 2 / §7).

Demonstrates the two parallel layers on an 8-device host mesh:
  * partitioned FiGaRo — the fact table is split into row blocks; each worker
    runs FiGaRo independently; the partial R factors merge via TSQR (the
    paper's "domain parallelism", Fig. 6);
  * mesh-distributed THIN/TSQR post-processing of R0 via shard_map — the
    per-thread Givens scheme of §7 mapped onto jax.lax collectives.

Must run as its own process (device count locks at jax init):
  PYTHONPATH=src python examples/distributed_qr.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compat import AxisType, make_mesh  # noqa: E402
from repro.core.distributed import (distributed_postprocess_r0,  # noqa: E402
                                    partitioned_figaro_qr)
from repro.core.figaro import figaro_r0  # noqa: E402
from repro.core.join_tree import build_plan  # noqa: E402
from repro.core.postprocess import normalize_sign  # noqa: E402
from repro.data.relational import yelp_like  # noqa: E402

print(f"devices: {len(jax.devices())}")
mesh = make_mesh((len(jax.devices()),), ("data",),
                 axis_types=(AxisType.Auto,))

tree = yelp_like(scale=400)
plan = build_plan(tree)

# single-worker reference
r_ref = np.asarray(partitioned_figaro_qr(tree, 1))

# 1) domain parallelism: 8 fact-table partitions
r_part = np.asarray(partitioned_figaro_qr(tree, 8))
err1 = np.abs(np.abs(r_part) - np.abs(r_ref)).max() / np.abs(r_ref).max()
print(f"partitioned FiGaRo (8 workers) rel err: {err1:.2e}")

# 2) mesh TSQR post-processing of R0
r0 = figaro_r0(plan, dtype=jnp.float64)
r_mesh = np.asarray(distributed_postprocess_r0(r0, mesh, "data"))
err2 = np.abs(np.abs(r_mesh) - np.abs(r_ref)).max() / np.abs(r_ref).max()
print(f"mesh TSQR post-process         rel err: {err2:.2e}")

assert err1 < 1e-10 and err2 < 1e-10
print("OK — identical R under every parallel decomposition "
      "(the rotation-sequence freedom the paper exploits).")
