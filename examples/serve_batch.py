"""Batched serving example: prefill a batch of prompts, then decode with the
per-architecture KV/state caches (attention KV, Mamba conv+SSM state, RWKV
wkv state, sliding-window ring buffers).

Exercises the same make_prefill / make_decode_step functions the multi-pod
dry-run lowers for the decode_32k / long_500k shapes.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-1.6b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf
from repro.train.serve import sample_loop

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_NAMES, default="granite-3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--steps", type=int, default=48)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
params = tf.init_params(jax.random.PRNGKey(0), cfg)

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                      (args.batch, args.prompt_len), 0,
                                      cfg.vocab)}
if cfg.is_enc_dec:
    batch["frames"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model),
        jnp.bfloat16)
if cfg.patch_positions:
    batch["patches"] = jax.random.normal(
        jax.random.PRNGKey(3), (args.batch, cfg.patch_positions, cfg.d_model),
        jnp.bfloat16)

max_len = args.prompt_len + args.steps + cfg.patch_positions + 1
t0 = time.time()
toks = sample_loop(params, cfg, batch, steps=args.steps, max_len=max_len,
                   temperature=0.8, key=jax.random.PRNGKey(4))
dt = time.time() - t0
toks = np.asarray(toks)
assert toks.shape == (args.batch, args.steps)
assert (toks >= 0).all() and (toks < cfg.vocab).all()
tput = args.batch * args.steps / dt
print(f"arch           : {cfg.name}")
print(f"generated      : {toks.shape} tokens  (first row: {toks[0][:12]}...)")
print(f"decode rate    : {tput:.1f} tok/s total (1 CPU core, reduced config)")
print("OK — batched prefill+decode with per-arch caches.")
