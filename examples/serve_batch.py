"""Batched serving examples.

Default (LM) mode: prefill a batch of prompts, then decode with the
per-architecture KV/state caches (attention KV, Mamba conv+SSM state, RWKV
wkv state, sliding-window ring buffers) — the same make_prefill /
make_decode_step functions the multi-pod dry-run lowers for the
decode_32k / long_500k shapes.

``--figaro`` mode: the linear-algebra-over-joins serving path — one join
structure, a stream of single requests submitted to the async pipelined
server (`Session(mesh=...)` ... ``ds.serve()`` -> ``submit`` -> futures):
pending requests coalesce into bucketed micro-batches sharded over the
local ``data`` mesh, queue depth 2 overlaps the next batch's staging with
the in-flight dispatch, and a streaming ``server.append`` rides the same
stream with zero retraces. One cached executable per (plan signature, mesh
signature) answers every coalesced batch.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-1.6b]
      PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
          python examples/serve_batch.py --figaro [--batch 8]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def lm_demo(args) -> None:
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.train.serve import sample_loop

    cfg = get_config(args.arch, smoke=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (args.batch, args.prompt_len), 0,
                                          cfg.vocab)}
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model),
            jnp.bfloat16)
    if cfg.patch_positions:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (args.batch, cfg.patch_positions, cfg.d_model), jnp.bfloat16)

    max_len = args.prompt_len + args.steps + cfg.patch_positions + 1
    t0 = time.time()
    toks = sample_loop(params, cfg, batch, steps=args.steps, max_len=max_len,
                       temperature=0.8, key=jax.random.PRNGKey(4))
    dt = time.time() - t0
    toks = np.asarray(toks)
    assert toks.shape == (args.batch, args.steps)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()
    tput = args.batch * args.steps / dt
    print(f"arch           : {cfg.name}")
    print(f"generated      : {toks.shape} tokens  "
          f"(first row: {toks[0][:12]}...)")
    print(f"decode rate    : {tput:.1f} tok/s total "
          "(1 CPU core, reduced config)")
    print("OK — batched prefill+decode with per-arch caches.")


def figaro_demo(args) -> None:
    jax.config.update("jax_enable_x64", True)
    from repro import figaro
    from repro.launch.mesh import make_data_mesh

    rng = np.random.default_rng(0)
    tables = {
        "Orders": ({"cust": rng.integers(0, 50, 1500),
                    "prod": rng.integers(0, 30, 1500)},
                   rng.normal(size=(1500, 2)), ["amount", "qty"]),
        "Customers": ({"cust": np.arange(50)}, rng.normal(size=(50, 3)),
                      ["age", "income", "tenure"]),
        "Products": ({"prod": np.arange(30)}, rng.normal(size=(30, 2)),
                     ["price", "weight"]),
    }
    edges = [("Orders", "Customers"), ("Orders", "Products")]

    # One Session owns the mesh + dtype policy; every batched dispatch it
    # makes shards the request axis over mesh["data"] via shard_map.
    mesh = make_data_mesh()  # every local device on a 1-D `data` axis
    sess = figaro.Session(mesh=mesh, dtype=jnp.float64)
    ds = sess.ingest(tables).join("Orders", edges)
    serve_qr = ds.serve(kind="qr", max_batch=args.batch, queue_depth=2)
    serve_lsq = ds.serve(kind="lsq", label_col="amount")

    def requests(k=None):
        return [tuple(np.asarray(d) * (1.0 + 0.02 * i) for d in ds.plan.data)
                for i in range(args.batch if k is None else k)]

    # -- async submit: single requests coalesce into one sharded dispatch ----
    serve_qr.pause()  # pre-load the queue -> one maximally-coalesced batch
    futures = [serve_qr.submit(r) for r in requests()]
    serve_qr.resume()
    rs = [np.asarray(f.result()) for f in futures]  # submission order
    n = ds.plan.num_cols
    assert all(r.shape == (n, n) for r in rs)

    # warm path: pipelined submit stream. pause() pre-loads the queue so the
    # timed stream coalesces into the SAME batch bucket the warm-up compiled
    # — an unpaused race could split it into fresh (uncompiled) buckets and
    # report XLA compilation as serving latency.
    reqs = requests()
    serve_qr.pause()
    futures = [serve_qr.submit(r) for r in reqs]
    t0 = time.time()
    serve_qr.resume()
    rs2 = [np.asarray(f.result()) for f in futures]
    dt = time.time() - t0
    for a, b in zip(rs, rs2):
        assert np.abs(a - b).max() < 1e-9

    # streaming append joins the same stream — shared plan, zero retraces
    in_cap = serve_qr.append("Orders", ({"cust": rng.integers(0, 50, 4),
                                         "prod": rng.integers(0, 30, 4)},
                                        rng.normal(size=(4, 2))))
    live = tuple(rng.normal(size=(ds.stats()["nodes"][nm]["live_rows"],
                                  ds.tree.db[nm].num_data_cols))
                 for nm in ds.tree.preorder())
    serve_qr.submit(live).result()
    assert ds.plan is serve_qr.plan  # one plan state, no fork

    betas, resids = serve_lsq(tuple(np.stack(leaves) for leaves in
                                    zip(*requests())))
    assert betas.shape == (args.batch, n - 1)
    stats = ds.stats()
    print(f"mesh           : {mesh.shape['data']} device(s) on axis 'data'")
    print(f"requests       : {args.batch} futures -> coalesced micro-batches "
          f"(bucketed to a multiple of the mesh inside the engine)")
    print(f"qr stream      : {dt * 1e3:.1f} ms pipelined "
          f"({dt * 1e3 / args.batch:.2f} ms/request, queue depth 2)")
    print(f"append         : in_capacity={in_cap} "
          f"(zero retraces while live sizes fit)")
    print(f"compilations   : qr={stats['traces']['qr_batched']}, "
          f"lsq={stats['traces']['least_squares_batched']} "
          "(one per plan+mesh+bucket signature)")
    serve_qr.close()
    serve_lsq.close()
    print("OK — async sharded FiGaRo serving off one cached executable.")


def main() -> None:
    ap = argparse.ArgumentParser()
    from repro.configs import ARCH_NAMES
    ap.add_argument("--arch", choices=ARCH_NAMES, default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--figaro", action="store_true",
                    help="serve FiGaRo factorizations over the data mesh "
                         "instead of the LM demo")
    args = ap.parse_args()
    if args.figaro:
        figaro_demo(args)
    else:
        lm_demo(args)


if __name__ == "__main__":
    main()
