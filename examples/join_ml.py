"""ML over relational joins — the paper's motivating application (§1).

A feature store defined as a snowflake join feeds three classical-ML tasks,
all computed *without materializing the join* by reading everything off
FiGaRo's R factor, through the one `repro.figaro` façade:

  * linear regression  — ``ds.lsq(label)`` (closed form via
    back-substitution on R),
  * PCA                — ``ds.pca(k=)`` (eigen-decomposition of the N x N
    Gram from R, factorized centering),
  * SVD                — ``ds.svd()`` (singular values/right vectors of the
    join matrix).

Run:  PYTHONPATH=src python examples/join_ml.py
"""

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import figaro
from repro.core.materialize import materialize_join
from repro.data.relational import retailer_like

# Retailer-style snowflake: Inventory fact + Location->Census, Item, Weather.
sess = figaro.Session()  # one engine/dtype/bucketing policy for all 3 tasks
ds = sess.from_tree(retailer_like(scale=800, cols=4))
n = len(ds.columns)

# --- linear regression: predict the last column from the rest ---------------
beta, resid = ds.lsq(n - 1)  # label by index; names work too ("Weather.w3")
a = materialize_join(ds.tree)  # ONLY to verify; FiGaRo never builds this
beta_ref, *_ = np.linalg.lstsq(a[:, :-1], a[:, -1], rcond=None)
print(f"join matrix         : {a.shape[0]} x {a.shape[1]} "
      f"(input rows: {ds.tree.db.total_rows})")
print(f"regression beta err : {np.abs(np.asarray(beta) - beta_ref).max():.2e}")
print(f"residual norm       : {float(resid):.4f}")

# --- PCA ---------------------------------------------------------------------
pca = ds.pca(k=3)
ac = a - a.mean(axis=0)
ev_ref = np.sort(np.linalg.eigvalsh(ac.T @ ac / (a.shape[0] - 1)))[::-1][:3]
print(f"PCA top-3 variance  : {np.asarray(pca.explained_variance).round(3)}")
print(f"       (reference)  : {ev_ref.round(3)}")

# --- SVD ----------------------------------------------------------------------
s, vt = ds.svd()
s_ref = np.linalg.svd(a, compute_uv=False)
print(f"singular values err : {np.abs(np.asarray(s) - s_ref[:len(s)]).max():.2e}")

assert np.abs(np.asarray(beta) - beta_ref).max() < 1e-6
assert np.allclose(np.asarray(pca.explained_variance), ev_ref, rtol=1e-7)
# All three reads hit ONE engine; the QR inside compiled once per signature.
assert ds.stats()["trace_count"] == 3  # qr is re-derived per kind's pipeline
print("OK — regression/PCA/SVD over the join, join never materialized.")
