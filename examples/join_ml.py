"""ML over relational joins — the paper's motivating application (§1).

A feature store defined as a snowflake join feeds three classical-ML tasks,
all computed *without materializing the join* by reading everything off
FiGaRo's R factor:

  * linear regression (closed form via back-substitution on R),
  * PCA (eigen-decomposition of the N x N Gram from R, factorized centering),
  * SVD (singular values/right vectors of the join matrix).

Run:  PYTHONPATH=src python examples/join_ml.py
"""

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.join_tree import build_plan
from repro.core.materialize import materialize_join
from repro.core.svd import (least_squares_over_join, pca_over_join,
                            svd_over_join)
from repro.data.relational import retailer_like

# Retailer-style snowflake: Inventory fact + Location->Census, Item, Weather.
tree = retailer_like(scale=800, cols=4)
plan = build_plan(tree)
n = plan.num_cols

# --- linear regression: predict the last column from the rest ---------------
beta, resid = least_squares_over_join(plan, label_col=n - 1)
a = materialize_join(tree)  # ONLY to verify; FiGaRo never builds this
beta_ref, *_ = np.linalg.lstsq(a[:, :-1], a[:, -1], rcond=None)
print(f"join matrix         : {a.shape[0]} x {a.shape[1]} "
      f"(input rows: {sum(nd.data.shape[0] for nd in plan.nodes)})")
print(f"regression beta err : {np.abs(np.asarray(beta) - beta_ref).max():.2e}")
print(f"residual norm       : {float(resid):.4f}")

# --- PCA ---------------------------------------------------------------------
pca = pca_over_join(plan, k=3)
ac = a - a.mean(axis=0)
ev_ref = np.sort(np.linalg.eigvalsh(ac.T @ ac / (a.shape[0] - 1)))[::-1][:3]
print(f"PCA top-3 variance  : {np.asarray(pca.explained_variance).round(3)}")
print(f"       (reference)  : {ev_ref.round(3)}")

# --- SVD ----------------------------------------------------------------------
s, vt = svd_over_join(plan)
s_ref = np.linalg.svd(a, compute_uv=False)
print(f"singular values err : {np.abs(np.asarray(s) - s_ref[:len(s)]).max():.2e}")

assert np.abs(np.asarray(beta) - beta_ref).max() < 1e-6
assert np.allclose(np.asarray(pca.explained_variance), ev_ref, rtol=1e-7)
print("OK — regression/PCA/SVD over the join, join never materialized.")
