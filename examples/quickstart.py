"""Quickstart: QR decomposition over a database join, without the join.

Builds a small star-schema database (fact table + 2 dimension tables),
computes the upper-triangular R of the join matrix two ways:

  1. FiGaRo (this library): counts -> heads/tails -> R0 -> TSQR post-process,
     touching only the INPUT relations;
  2. the classical baseline: materialize the join, Householder QR;

shows they agree while FiGaRo reads ~10x fewer values, then serves a batch of
feature-set variants through the compiled `FigaroEngine` — one executable per
plan signature, one vmapped dispatch for the whole batch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core.join_tree import JoinTree, build_plan
from repro.core.materialize import join_output_rows, materialize_join
from repro.core.qr import figaro_qr, materialized_qr
from repro.core.relation import Database, full_reduce

rng = np.random.default_rng(0)

# --- 1. a database: Orders + Customers + Products + Reviews (many-to-many) --
n_cust, n_prod, n_orders = 50, 30, 2000
tables = {
    "Orders": ({"cust": rng.integers(0, n_cust, n_orders),
                "prod": rng.integers(0, n_prod, n_orders)},
               rng.normal(size=(n_orders, 2)), ["amount", "qty"]),
    "Customers": ({"cust": np.arange(n_cust)},
                  rng.normal(size=(n_cust, 3)), ["age", "income", "tenure"]),
    "Products": ({"prod": np.arange(n_prod)},
                 rng.normal(size=(n_prod, 2)), ["price", "weight"]),
    # many-to-many: ~6 reviews per product -> the join blows up 6x
    "Reviews": ({"prod": rng.integers(0, n_prod, n_prod * 6)},
                rng.normal(size=(n_prod * 6, 1)), ["stars"]),
}
db = Database.from_arrays(tables)
edges = [("Orders", "Customers"), ("Orders", "Products"),
         ("Products", "Reviews")]
db = full_reduce(db, edges)                      # drop dangling tuples
tree = JoinTree.from_edges(db, "Orders", edges)  # fact table at the root
plan = build_plan(tree)                          # static index structure

# --- 2. FiGaRo: R without materializing the join ----------------------------
r_figaro = figaro_qr(plan, dtype=jnp.float64)

# --- 3. classical baseline: materialize, then QR ----------------------------
a = materialize_join(tree)
r_baseline = materialized_qr(tree)

err = np.abs(np.asarray(r_figaro) - np.asarray(r_baseline)).max() \
    / np.abs(np.asarray(r_baseline)).max()

rows_in = db.total_rows
rows_join = join_output_rows(tree)
print(f"input rows          : {rows_in}")
print(f"join rows           : {rows_join}  ({rows_join / rows_in:.1f}x blowup)")
print(f"R shape             : {r_figaro.shape}")
print(f"max rel. difference : {err:.2e}")
assert err < 1e-10
print("OK — FiGaRo matches the materialized-join QR without building the join.")

# --- 4. the compiled engine: one plan, many feature-sets per dispatch -------
# The plan is a pytree (static spec = treedef, index arrays = leaves), so it
# crosses jax.jit as an ARGUMENT: the engine compiles once per plan signature
# and every same-shaped database / refreshed batch is launch-only.
from repro.core.engine import FigaroEngine  # noqa: E402

engine = FigaroEngine(donate_data=False)
B = 8  # e.g. 8 users' feature-set variants over the same join structure
batch = tuple(np.stack([np.asarray(d) * (1.0 + 0.01 * i) for i in range(B)])
              for d in plan.data)
r_batch = engine.qr(plan, batch, batched=True, dtype=jnp.float64)
assert r_batch.shape == (B, plan.num_cols, plan.num_cols)
r0_check = np.asarray(engine.qr(plan, [d[0] for d in batch],
                                dtype=jnp.float64))
assert np.abs(np.asarray(r_batch[0]) - r0_check).max() < 1e-10
engine.qr(plan, batch, batched=True, dtype=jnp.float64)  # cache hit
assert engine.trace_count("qr_batched") == 1
print(f"engine              : served {B} feature-sets in one dispatch, "
      f"{engine.trace_count()} compilations total")
print("OK — compiled engine: batched serving off one cached executable.")

# --- 5. sharded serving: split the request batch over the data mesh ---------
# `shard=mesh` (or shard=(mesh, axis)) splits the leading batch axis over the
# mesh's `data` axis with shard_map: ONE cached executable per (plan
# signature, mesh signature) answers the global batch across all devices. The
# batch is padded/bucketed to the mesh size inside the engine, so any B works.
# The same entry points back `train.serve.make_figaro_server(..., mesh=mesh)`
# (kinds: qr / svd / pca / lsq) and `distributed.partitioned_figaro_qr(...,
# mesh=mesh)` places one fact partition per device slot.
from repro.launch.mesh import make_data_mesh  # noqa: E402

mesh = make_data_mesh()  # all local devices on a 1-D "data" axis
r_mesh = engine.qr(plan, batch, batched=True, shard=mesh, dtype=jnp.float64)
assert np.abs(np.asarray(r_mesh) - np.asarray(r_batch)).max() < 1e-10
print(f"sharded             : same {B}-request batch over "
      f"{mesh.shape['data']} device(s); run under "
      "XLA_FLAGS=--xla_force_host_platform_device_count=4 to spread it")
print("OK — sharded serving: one executable, the whole mesh answers.")

# --- 6. incremental refresh + bucketed signatures ----------------------------
# The contract: CAPACITY is static, LIVE SIZE is dynamic. A capacity plan
# buckets every node's (rows, keys, parent-keys) up to powers of two and
# carries a live-row mask as a pytree leaf; appending rows only rewrites leaf
# values, so a refresh whose live sizes stay inside the buckets re-dispatches
# the cached executable with ZERO retraces — the compile count tracks tenant
# *shapes* (buckets), not databases or refreshes.
from repro.core.plan_cache import build_capacity_plan, refresh_plan  # noqa: E402

cap = build_capacity_plan(tree, headroom=16)  # room for streaming appends
r_cap = engine.qr(cap, dtype=jnp.float64)
assert np.abs(np.asarray(r_cap) - np.asarray(r_figaro)).max() < 1e-10
compiles = engine.trace_count("qr")

new_stars = ({"prod": rng.integers(0, n_prod, 5)},  # 5 fresh reviews
             rng.normal(size=(5, 1)))
old_spec = cap.spec
cap = refresh_plan(cap, {"Reviews": new_stars})
assert cap.spec == old_spec, "append within capacity must keep the signature"
r_new = engine.qr(cap, dtype=jnp.float64)
assert engine.trace_count("qr") == compiles, "append must not retrace"
r_check = figaro_qr(build_plan(cap.source_tree), dtype=jnp.float64)
assert np.abs(np.asarray(r_new) - np.asarray(r_check)).max() < 1e-10
print(f"refresh             : appended 5 rows, served with "
      f"{engine.trace_count('qr') - compiles} new compilations")
print("OK — incremental refresh: appends are launch-only, capacity is the "
      "signature.")
