"""Quickstart: QR/PCA over a database join, without the join.

The whole FiGaRo path goes through ONE surface — `repro.figaro`
(`Session` / `JoinDataset`):

  1. ingest a small star-schema database and fix the join tree;
  2. `ds.qr()` — the paper's pipeline (counts -> heads/tails -> R0 -> TSQR),
     touching only the INPUT relations; verified against the classical
     baseline (materialize the join, Householder QR) while reading ~10x
     fewer values;
  3. `ds.pca(k=)` / `ds.lsq(label)` — downstream ML reads off the same R;
  4. batched serving: a leading batch axis answers B feature-sets in one
     compiled dispatch (sharded over a device mesh when the Session has one);
  5. `ds.append(...)` — online data refresh with ZERO retraces (capacity is
     the compile signature, live size is data);
  6. `ds.serve(kind=...)` — the standing batched serving endpoint;
  7. async serving: `server.submit(...)` -> futures, micro-batch coalescing,
     and streaming `submit` + `server.append` off one shared plan state;
  8. accelerator knobs: `Session(use_kernel=, assembly=)` — the fused
     per-node Pallas kernel and band-wise R0 assembly, numerics-preserving
     and cached per static signature;
  9. figaro-lint: `python -m repro.analysis` — the repo's own static
     analyzer machine-checks the invariants steps 1-8 rely on;
 10. figaro-san: `FIGARO_SAN=1` — runtime race/retrace/numerics detectors
     over the same serving stack;
 11. figaro-plan: `join(edges)` with no root — the cost-based optimizer
     picks the join-tree orientation, `ds.explain()` shows the ranking, and
     appends can adaptively re-root the live plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import figaro
from repro.core.materialize import join_output_rows, materialize_join
from repro.core.qr import materialized_qr

rng = np.random.default_rng(0)

# --- 1. ingest + join: Orders + Customers + Products + Reviews --------------
n_cust, n_prod, n_orders = 50, 30, 2000
tables = {
    "Orders": ({"cust": rng.integers(0, n_cust, n_orders),
                "prod": rng.integers(0, n_prod, n_orders)},
               rng.normal(size=(n_orders, 2)), ["amount", "qty"]),
    "Customers": ({"cust": np.arange(n_cust)},
                  rng.normal(size=(n_cust, 3)), ["age", "income", "tenure"]),
    "Products": ({"prod": np.arange(n_prod)},
                 rng.normal(size=(n_prod, 2)), ["price", "weight"]),
    # many-to-many: ~6 reviews per product -> the join blows up 6x
    "Reviews": ({"prod": rng.integers(0, n_prod, n_prod * 6)},
                rng.normal(size=(n_prod * 6, 1)), ["stars"]),
}
edges = [("Orders", "Customers"), ("Orders", "Products"),
         ("Products", "Reviews")]

# One Session = one engine + dtype/mesh/bucketing policy. headroom reserves
# row capacity per relation so streaming appends stay inside the compiled
# signature (see step 5).
sess = figaro.Session(dtype=jnp.float64, headroom=16)
ds = sess.ingest(tables).join("Orders", edges)  # fact table at the root

# --- 2. FiGaRo QR vs the classical baseline ---------------------------------
r_figaro = ds.qr()  # first compute: builds the capacity plan, compiles once

a = materialize_join(ds.tree)          # ONLY for the baseline/verification
r_baseline = materialized_qr(ds.tree)
err = np.abs(np.asarray(r_figaro) - np.asarray(r_baseline)).max() \
    / np.abs(np.asarray(r_baseline)).max()

rows_in = ds.tree.db.total_rows
rows_join = join_output_rows(ds.tree)
print(f"input rows          : {rows_in}")
print(f"join rows           : {rows_join}  ({rows_join / rows_in:.1f}x blowup)")
print(f"R shape             : {r_figaro.shape}   columns: {ds.columns[:3]}...")
print(f"max rel. difference : {err:.2e}")
assert err < 1e-10
print("OK — FiGaRo matches the materialized-join QR without building the join.")

# --- 3. downstream ML off the same R: PCA + ridge regression ----------------
pca = ds.pca(k=3)
beta, resid = ds.lsq("price", ridge=0.1)  # label column by name
ac = a - a.mean(axis=0)
ev_ref = np.sort(np.linalg.eigvalsh(ac.T @ ac / (a.shape[0] - 1)))[::-1][:3]
assert np.allclose(np.asarray(pca.explained_variance), ev_ref, rtol=1e-8)
print(f"PCA top-3 variance  : {np.asarray(pca.explained_variance).round(3)}")
print(f"ridge lsq           : beta {beta.shape}, residual {float(resid):.3f}")
print("OK — regression/PCA read off R; the join is never materialized.")

# --- 4. batched serving: one dispatch, many feature-sets --------------------
# A leading batch axis on the data switches to the batched (vmapped)
# executable; with figaro.Session(mesh=make_data_mesh()) the same call
# shards the batch over every device (one executable per plan+mesh
# signature). Requests sized to the LIVE row counts are padded to capacity
# inside the dataset.
B = 8  # e.g. 8 users' feature-set variants over the same join structure
batch = tuple(np.stack([np.asarray(d) * (1.0 + 0.01 * i) for i in range(B)])
              for d in ds.plan.data)
r_batch = ds.qr(batch)
assert r_batch.shape == (B, ds.plan.num_cols, ds.plan.num_cols)
r0_check = np.asarray(ds.qr([d[0] for d in batch]))
assert np.abs(np.asarray(r_batch[0]) - r0_check).max() < 1e-10
ds.qr(batch)  # cache hit: same signature, launch-only
st = ds.stats()
assert st["traces"]["qr_batched"] == 1
print(f"engine              : served {B} feature-sets in one dispatch, "
      f"{st['trace_count']} compilations total")
print("OK — batched serving off one cached executable.")

# --- 5. online append: capacity is the signature, live size is data ---------
# The capacity plan buckets every node's (rows, keys, parent-keys) up to
# powers of two (+ headroom) and carries a live-row mask as a pytree LEAF:
# appending rows only rewrites leaf values, so a refresh inside the buckets
# re-dispatches the cached executable with ZERO retraces. The compile count
# tracks tenant *shapes* (buckets), not databases or refreshes.
compiles = st["traces"]["qr"]
in_capacity = ds.append("Reviews", {"prod": rng.integers(0, n_prod, 5)},
                        rng.normal(size=(5, 1)))  # 5 fresh reviews
assert in_capacity, "append within headroom must keep the plan signature"
r_new = ds.qr()
st = ds.stats()
assert st["traces"]["qr"] == compiles, "append must not retrace"
r_check = materialized_qr(ds.tree)
assert np.abs(np.asarray(r_new) - np.asarray(r_check)).max() \
    / np.abs(np.asarray(r_check)).max() < 1e-10
live = st["nodes"]["Reviews"]
print(f"refresh             : +5 rows, {st['traces']['qr'] - compiles} new "
      f"compilations; Reviews live/capacity = "
      f"{live['live_rows']}/{live['capacity_rows']}")
print("OK — incremental refresh: appends are launch-only.")

# --- 6. a standing serving endpoint -----------------------------------------
server = ds.serve(kind="qr")  # also: svd / pca / lsq(label_col=...)
r_served = server(tuple(np.stack([np.asarray(d)] * 2) for d in ds.plan.data))
assert np.asarray(r_served).shape == (2, ds.plan.num_cols, ds.plan.num_cols)
print("OK — ds.serve(): batched FigaroServer with online server.append().")

# --- 7. async serving: submit -> futures -> streaming append -----------------
# The server is async-first: `submit(request)` enqueues one request (per-node
# [rows_i, n_i] leaves — or a [B, rows_i, n_i] sub-batch) and returns a
# FigaroFuture immediately. Pending requests coalesce into ONE bucketed
# micro-batch dispatch, and with queue_depth >= 2 the next batch's H2D
# staging overlaps the in-flight executable (the blocking `server(batch)`
# of step 6 is just `submit(batch).result()` over this same pipeline).
compiles_b = ds.stats()["traces"].get("qr_batched", 0)
requests = [tuple(np.asarray(d) * (1.0 + 0.1 * i) for d in ds.plan.data)
            for i in range(6)]
futures = [server.submit(r) for r in requests]          # returns immediately
answers = [np.asarray(f.result()) for f in futures]     # submission order
assert all(a.shape == (ds.plan.num_cols,) * 2 for a in answers)

# Streaming append joins the same stream: it drains in-flight requests, then
# refreshes the SHARED plan holder — ds.plan / ds.stats() and the server can
# never fork, and in-capacity refreshes keep the executable (zero retraces).
in_capacity = server.append("Reviews", ({"prod": rng.integers(0, n_prod, 3)},
                                        rng.normal(size=(3, 1))))
assert in_capacity and ds.plan is server.plan
live = tuple(rng.normal(size=(ds.stats()["nodes"][nm]["live_rows"],
                              ds.tree.db[nm].num_data_cols))
             for nm in ds.tree.preorder())
r_after = server.submit(live).result()  # live-sized request, padded inside
assert np.asarray(r_after).shape == (ds.plan.num_cols, ds.plan.num_cols)
st = ds.stats()
assert st["traces"]["qr_batched"] - compiles_b <= 2  # B=2, B=1 buckets only
print(f"async serving       : {len(requests)} futures answered, then "
      f"append+submit with {st['traces']['qr_batched'] - compiles_b} "
      f"batch-bucket compilations (streaming appends retrace nothing)")
server.close()
print("OK — async pipelined serving: submit -> futures -> streaming append.")

# --- 8. accelerator knobs: fused node kernel + band-wise R0 assembly --------
# Two per-dispatch (or per-Session) flags, both numerics-preserving:
#
#   use_kernel=True  routes each join-tree node through the fused
#       `kernels.node_fused` Pallas kernel — live-row masking, segmented
#       head/tail extraction, phi-weight scaling and slab emission in ONE
#       HBM round-trip per node instead of three-plus. On TPU/GPU it runs
#       compiled; on CPU it executes interpret=True (correct but slow — keep
#       the default XLA path for CPU serving).
#   assembly="band"  materializes R0 band-by-band from (col0, width) slab
#       metadata on the plan instead of padding every slab to full width —
#       assembly traffic drops from O(rows * N) to O(sum rows_i * width_i)
#       (`figaro.assembly_traffic` is the analytic model; BENCH_engine.json
#       tracks both wall-clock and bytes).
#
# Both flags ride the STATIC half of the dispatch signature: each (use_kernel,
# assembly) corner compiles once and repeats are launch-only, so flipping a
# corner never invalidates the others' cached executables.
r_band = ds.qr(assembly="band")  # same data as ds.qr(), band-assembled R0
assert np.abs(np.asarray(r_band) - np.asarray(ds.qr())).max() < 1e-10
from repro.core.figaro import assembly_traffic
bytes_padded = assembly_traffic(ds.plan.spec, assembly="padded")
bytes_band = assembly_traffic(ds.plan.spec, assembly="band")
print(f"band assembly       : {bytes_band / bytes_padded:.2f}x the padded "
      f"assembly bytes ({bytes_padded} -> {bytes_band})")
print("OK — Session(use_kernel=, assembly=) select the accelerated paths.")

# --- 9. running figaro-lint: the invariants above, machine-checked ----------
# Everything this example leaned on is a structural invariant nothing at
# runtime enforces: version-sensitive JAX spellings live only in
# repro/compat.py (FIG001); the engine's _STATIC table matches each impl's
# keyword-only options and plans pass THROUGH jit, never closed over
# (FIG002 — the zero-retrace story of steps 4-7); core/ and kernels/ derive
# dtypes from inputs instead of hardcoding float32 (FIG003); every
# pallas_call routes interpret= through kernels/_platform.resolve_interpret
# and grids divide ceil-padded dims (FIG004 — step 8's kernels); the async
# server's shared state is written under its locks (FIG005 — step 7), read
# under them too (FIG006 — unlocked reads of shared mutable attrs are
# cross-thread escapes), and every thread/lock in src/ is constructed
# through the figaro-san wrappers so the runtime sanitizer of step 10 can
# observe it (FIG007).
#
# The analyzer is pure stdlib (no jax import), so CI runs it uninstalled:
#
#   PYTHONPATH=src python -m repro.analysis src/                  # all rules
#   PYTHONPATH=src python -m repro.analysis --baseline analysis_baseline.json src/
#   PYTHONPATH=src python -m repro.analysis --report unused       # dead code
#
# Deliberate violations carry a trailing suppression with a reason:
#
#   return jax.jit(fn)  # figaro-lint: disable=FIG002 -- plan-closed by design
#
# (`disable-file=` at any line suppresses a rule module-wide.) Anything not
# suppressed must be fixed or added to analysis_baseline.json with a
# justification — CI fails on non-baselined findings. To add a rule: drop a
# module in src/repro/analysis/rules/ subclassing `framework.Rule` (set
# rule_id/severity/fix_hint, yield findings from check(ctx)), register it in
# rules/__init__.all_rules, and give it known-bad/known-good fixtures in
# tests/test_analysis.py.
print("OK — see `python -m repro.analysis --help` for the linter surface.")

# --- 10. figaro-san: the runtime counterpart, FIGARO_SAN=1 ------------------
# figaro-lint checks what the source says; figaro-san checks what the
# process does. `FIGARO_SAN=1 python ...` (or `sanitizer.enable()`) arms
# three detectors with near-zero cost when off (the instrumentation hooks
# are physically removed from the classes on disable()):
#
#   race     lockset detection on the @shared_state classes (engine caches,
#            PlanHolder counters, server queues) + a lock-order graph that
#            flags acquisition cycles (potential deadlocks) without needing
#            the unlucky interleaving to actually hang;
#   retrace  every engine compile records its dispatch signature; after
#            `sanitizer.expect_no_retrace()` any further compile is a
#            finding naming the diverged signature component;
#   numerics sampled float64 shadow dispatches assert the f32 error against
#            the paper's database-size budget (eps * slack * Σ relation
#            rows — FiGaRo's rounding error scales with DATABASE size, not
#            join size), plus NaN/Inf tripwires on every sampled output.
from repro import sanitizer

sanitizer.enable()
np.asarray(ds.qr())  # the serving path from the steps above, sanitized
assert sanitizer.findings() == []  # nothing to report on the real stack

# A detector firing looks like this — the classic AB/BA lock inversion:
from repro.sanitizer.locks import san_lock

a, b = san_lock("demo.A"), san_lock("demo.B")
with a:
    with b:
        pass
with b:
    with a:  # reversed order: a cycle in the acquisition graph
        pass
(cycle_finding,) = sanitizer.findings("lock-order")
print("figaro-san          :", cycle_finding.message)
print(sanitizer.report().splitlines()[0])
sanitizer.reset()
sanitizer.disable()

# Adding a runtime check mirrors adding a lint rule (step 9): drop a module
# in src/repro/sanitizer/ that calls `_state.STATE.add_finding(check, msg,
# details=..., dedupe_key=...)` from its instrumentation points, wire its
# enable/reset into sanitizer.enable()/reset(), and give it a fires-on-bad /
# quiet-on-good pair in tests/test_sanitizer.py. CI runs the async serving
# suite and a multi-threaded stress test under FIGARO_SAN=1 asserting zero
# findings, so a new detector immediately guards the real serving stack.
print("OK — FIGARO_SAN=1 arms the race/retrace/numerics sanitizers.")

# --- 11. figaro-plan: cost-based join-tree choice, root="auto" --------------
# Table 2 of the paper shows the join-tree orientation changes FiGaRo's
# runtime by orders of magnitude without changing R. Leaving the root out of
# `join(...)` (or passing root="auto") hands that choice to figaro-plan
# (src/repro/planner/): it keeps EXACT per-relation statistics (row counts,
# distinct join keys, per-edge fan-outs — pure numpy, collected at ingest,
# merged incrementally on append) and scores every rooted orientation of the
# acyclic join graph with the paper's complexity model. The chosen tree is
# built through the same code path as a hand-rooted one, so when the planner
# agrees with you the compiled executable is shared: auto costs zero extra
# retraces.
traces_before = sess.engine.trace_count()
auto = sess.ingest(tables).join(edges)    # no root: the planner picks one
print(auto.explain())                     # ranked orientations + breakdown
assert auto.tree.root == "Orders"         # recovers the step-1 hand choice
np.asarray(auto.qr())
assert sess.engine.trace_count() == traces_before, "auto reused the plan"

# Auto-rooted datasets re-plan adaptively: every append folds the new keys
# into the statistics, and when growth makes another orientation cheaper by
# more than the hysteresis margin — `join(edges, reroot=True,
# hysteresis=0.5)` are the knobs — the dataset rebuilds on the better root
# at a drain point (in-flight server futures still answer on the old plan;
# re-read `ds.columns` afterwards, the column order follows the live tree).
# This star schema keeps its fact table cheapest, so appends never flip it:
auto.append("Orders", {"cust": np.array([0, 1]), "prod": np.array([2, 3])},
            rng.normal(size=(2, 2)))
st = auto.stats()
assert (st["auto_root"], st["reroots"]) == (True, 0)
print(f"after append        : root={st['root']} (re-roots: {st['reroots']}, "
      f"appended rows: {st['append_volume']})")
print("OK — figaro-plan picks the orientation; appends keep it honest.")

# --- 12. figaro-flow: interprocedural analysis + writing a rule on it -------
# Steps 9's rules are per-file; the invariants they can't see are the ones
# that live BETWEEN files: a helper three modules away from the jit boundary
# calling np.asarray on a traced array (FIG009), a traced function bumping a
# module counter once per trace instead of once per call (FIG010), a buffer
# re-read after the engine's donated dispatch consumed it (FIG011), and the
# R0 slab-layout arithmetic drifting between join_tree/plan_cache/PlanSpec
# (FIG012). figaro-flow (repro.analysis.callgraph + .dataflow, still pure
# stdlib) powers them: it builds a whole-program call graph, marks every
# function transitively reachable from an engine `_<kind>_impl`, a
# `jax.jit`/`pallas_call` argument or a `shard_map` body as *traced-context*,
# and runs a per-function taint fixpoint (params -> returns/effects, with
# static/kwonly params, closure constants and .shape/.dtype metadata held
# concrete) over that graph. Inspect the classification directly:
#
#   PYTHONPATH=src python -m repro.analysis --report callgraph src/
#   PYTHONPATH=src python -m repro.analysis --report callgraph --dot flow.dot src/
#   PYTHONPATH=src python -m repro.analysis --report callgraph --json src/
#
# Writing an interprocedural rule: subclass `framework.Rule` as in step 9,
# but implement `check_program(self, program)` instead of (or on top of)
# `check(ctx)`. The driver calls it once per run with the whole-program
# view; `program.graph.traced` is the traced-context set with root chains,
# `program.dataflow().sinks` the taint fixpoint's host-sync sites, and
# `program.traced_chain(qname)` the root->function attribution a finding
# should carry via `self.finding(..., traced_context=chain)` — it lands in
# `--json` as `traced_context` so tooling can jump the whole chain. Per-file
# rules get the same power through `self.program` (FIG006 uses it to verify
# a "private helper" really has no cross-module callers before exempting
# it). The program below shows the classification on a miniature engine:
from repro.analysis import analyze_source, all_rules
from repro.analysis.callgraph import Program
from repro.analysis.framework import FileContext
import ast as _ast
import textwrap as _tw

_MINI = _tw.dedent("""
    import jax
    import numpy as np

    @jax.jit
    def entry(x):
        return helper(x)

    def helper(a):
        return np.asarray(a)      # host sync, two hops from the jit
""")
ctx = FileContext("src/repro/core/mini.py", _MINI, _ast.parse(_MINI))
flow = Program([ctx])
assert "repro.core.mini:helper" in flow.graph.traced
hits = [f for f in analyze_source(_MINI, "src/repro/core/mini.py",
                                  all_rules()) if f.rule == "FIG009"]
assert hits and hits[0].traced_context == ("entry", "helper")
print(f"figaro-flow: {len(flow.graph.functions)} fn(s), "
      f"{len(flow.graph.traced)} traced; FIG009 chain "
      f"{' -> '.join(hits[0].traced_context)}")
print("OK — figaro-flow classifies the jit frontier; rules query it.")
