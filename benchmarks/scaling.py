"""Experiment 2 (Fig. 6): domain-parallel scaling.

The paper splits each relation into contiguous blocks per thread. Here the
same freedom is exercised two ways:
  * ``partitioned_figaro_qr`` — fact-table row partitions, independent FiGaRo
    per partition, TSQR combine (the paper's domain parallelism);
  * device-sharded TSQR post-processing over N host devices (subprocess,
    since the XLA device count is fixed at startup).

This container exposes ONE physical core, so wall-clock speedup is not
observable; the benchmark reports the *load balance* (max rows per worker,
which on real hardware bounds the parallel time) plus wall time for
reference, and asserts result invariance across partition counts.
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core.distributed import partitioned_figaro_qr
from repro.core.join_tree import build_plan
from repro.core.qr import figaro_qr
from repro.data.relational import yelp_like

from ._util import Csv, timeit


def run(csv: Csv, *, fast: bool = False) -> None:
    tree = yelp_like(scale=200 if fast else 500)
    plan = build_plan(tree)
    r_ref = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    fact_rows = plan.nodes[plan.root].data.shape[0]
    for parts in (1, 2, 4, 8):
        t = timeit(lambda: partitioned_figaro_qr(tree, parts), repeats=1)
        r_p = np.asarray(partitioned_figaro_qr(tree, parts))
        err = np.abs(np.abs(r_p) - np.abs(r_ref)).max() / np.abs(r_ref).max()
        case = f"parts{parts}"
        csv.add("scaling", case, "wall_s_1core", t)
        csv.add("scaling", case, "max_rows_per_worker",
                int(np.ceil(fact_rows / parts)))
        csv.add("scaling", case, "result_rel_err", float(err))


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
