"""Experiment 1 (Fig. 4): FiGaRo vs materialized-join QR on the three
paper-style schemas, as a function of dataset scale.

The paper's numbers (Xeon, C++, MKL): FiGaRo-THIN 2.9x (Retailer), 16.1x
(Favorita), 120.5x (Yelp) over MKL-on-the-join. Here both sides run the same
JAX/LAPACK substrate on CPU, so the *ratio* is the comparable quantity — it
tracks |join| / |input| exactly as Theorem 6.1 predicts.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.join_tree import build_plan
from repro.core.materialize import join_output_rows, materialize_join
from repro.core.qr import figaro_qr_fn, materialized_qr
from repro.data.relational import favorita_like, retailer_like, yelp_like

from ._util import Csv, timeit

MAKERS = {
    # key-fkey schemas: |join| ~ |input| rows (value-duplication regime —
    # the paper notes FiGaRo's benefit is small here); many-to-many yelp:
    # |join| >> |input| (the paper's headline regime).
    "retailer": (retailer_like, (2000, 8000)),
    "favorita": (favorita_like, (2000, 8000)),
    "yelp": (yelp_like, (1000, 2000, 4000)),
}


def run(csv: Csv, *, fast: bool = False) -> None:
    for name, (maker, scales) in MAKERS.items():
        for scale in scales[:1] if fast else scales:
            tree = maker(scale=scale)
            plan = build_plan(tree)
            rows_in = sum(nd.data.shape[0] for nd in plan.nodes)
            rows_join = join_output_rows(tree)
            fig = figaro_qr_fn(plan, dtype=jnp.float64)
            data = [jnp.asarray(nd.data) for nd in plan.nodes]
            t_fig = timeit(lambda: fig(data), repeats=2)
            t_mat = timeit(lambda: materialized_qr(tree), repeats=1)
            case = f"{name}@{scale}"
            csv.add("figaro_runtime", case, "input_rows", rows_in)
            csv.add("figaro_runtime", case, "join_rows", rows_join)
            csv.add("figaro_runtime", case, "blowup",
                    rows_join / max(rows_in, 1))
            csv.add("figaro_runtime", case, "figaro_s", t_fig)
            csv.add("figaro_runtime", case, "materialized_s", t_mat)
            csv.add("figaro_runtime", case, "speedup", t_mat / t_fig)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
