"""Experiment 1 (Fig. 5): Cartesian product of two relations, grid over
(#rows, #cols) per relation. FiGaRo scales linearly in rows; the
materialized baseline scales quadratically (it runs on the p*q-row join) and
OOMs first — exactly the paper's table shape.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.join_tree import build_plan
from repro.core.qr import figaro_qr_fn, materialized_qr
from repro.data.relational import cartesian

from ._util import Csv, timeit

GRID_ROWS = (2**8, 2**10, 2**12)
GRID_COLS = (2**3, 2**5)
MATERIALIZE_LIMIT = 2**26  # join cells; beyond this the baseline is skipped


def run(csv: Csv, *, fast: bool = False) -> None:
    rows = GRID_ROWS[:2] if fast else GRID_ROWS
    cols = GRID_COLS[:2] if fast else GRID_COLS
    for m in rows:
        for n in cols:
            tree = cartesian(m, m, n1=n, n2=n, seed=13)
            plan = build_plan(tree)
            case = f"rows{m}xcols{2 * n}"
            fig = figaro_qr_fn(plan, dtype=jnp.float64)
            data = [jnp.asarray(nd.data) for nd in plan.nodes]
            t_fig = timeit(lambda: fig(data),
                           repeats=2 if m <= 2**10 else 1)
            csv.add("cartesian_grid", case, "figaro_s", t_fig)
            join_cells = m * m * 2 * n
            if join_cells <= MATERIALIZE_LIMIT:
                t_mat = timeit(lambda: materialized_qr(tree), repeats=1)
                csv.add("cartesian_grid", case, "materialized_s", t_mat)
                csv.add("cartesian_grid", case, "speedup", t_mat / t_fig)
            else:
                csv.add("cartesian_grid", case, "materialized_s", "OOM-guard")


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
