"""Engine microbenchmarks: scatter vs scatter-free R₀ assembly, per-sample vs
batched (vmapped) dispatch, and single-device vs mesh-sharded batched dispatch
through `FigaroEngine`.

Three comparisons, all on the paper-style schemas:

  * **assembly**: the pre-refactor emission path scattered every block into a
    zeroed [M×N] buffer with ``.at[].set`` (O(nodes) dislocated updates on the
    hot path); the engine assembles R₀ by concatenating column-padded row
    slabs. Both jitted, same plan, same data — wall-clock ratio is the win.
  * **dispatch**: serving B feature-sets as B per-sample engine calls vs one
    vmapped batched dispatch (one launch, one executable).
  * **sharded_dispatch**: the same global batch answered by the 1-executable
    vmapped dispatch vs the `shard_map` dispatch over the local ``data`` mesh
    (`make_data_mesh`). On the default single-CPU-device run the mesh is
    1-wide and the ratio is ~1; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to measure a real
    mesh split.
  * **plan_refresh**: serving latency of an append-only data refresh — a
    capacity plan (`plan_cache.refresh_plan`, zero retraces asserted) vs
    rebuilding the exact plan and recompiling its fresh signature.

Emits the standard ``BENCH_engine.json`` (see `_util.write_bench_json`) so the
perf trajectory tracks this PR onward.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counts import compute_counts
from repro.core.engine import FigaroEngine
from repro.core.figaro import figaro_r0
from repro.core.heads_tails import segmented_head_tail
from repro.core.join_tree import build_plan
from repro.data.relational import favorita_like, yelp_like

from ._util import Csv, block, timeit, write_bench_json


def _scatter_r0(plan, data, *, dtype=jnp.float64):
    """The pre-refactor assembly: emit blocks into jnp.zeros via .at[].set.

    Kept here (benchmarks only) as the baseline side of the assembly
    comparison; the library path is scatter-free.
    """
    spec = plan.spec
    data = [jnp.asarray(d, dtype=dtype) for d in data]
    counts = compute_counts(plan, dtype=dtype)
    carried_data, carried_scales = {}, {}
    out_blocks = []
    row_acc = 0

    def emit(col0, block):
        nonlocal row_acc
        out_blocks.append((row_acc, col0, block))
        row_acc += block.shape[0]

    for idx in reversed(spec.preorder):
        sp, ix = spec.nodes[idx], plan.index[idx]
        cnt = counts[idx]
        x = data[idx]
        ones = jnp.ones((sp.m,), dtype=dtype)
        heads, tails, _ = segmented_head_tail(
            x, ones, jnp.asarray(ix.row_to_group),
            jnp.asarray(ix.pos_in_group), sp.K)
        phi_circ_row = cnt["phi_circ"][jnp.asarray(ix.row_to_group)]
        emit(sp.col_start, tails * jnp.sqrt(phi_circ_row)[:, None])
        scales = jnp.sqrt(cnt["rpk"])
        if sp.children:
            gathered = []
            for ch, rel0 in zip(sp.children, sp.child_rel_col0):
                lookup = jnp.asarray(ix.child_lookup[ch])
                gathered.append((rel0, carried_data.pop(ch)[lookup],
                                 carried_scales.pop(ch)[lookup]))
            prod_all = functools.reduce(jnp.multiply,
                                        [s for _, _, s in gathered])
            parts = [(0, heads * prod_all[:, None])]
            for j, (rel0, dj, _) in enumerate(gathered):
                prod_except = functools.reduce(
                    jnp.multiply,
                    [s for k, (_, _, s) in enumerate(gathered) if k != j],
                    scales)
                parts.append((rel0, dj * prod_except[:, None]))
            data_mat = jnp.zeros((sp.K, sp.subtree_width), dtype=dtype)
            for rel0, block in parts:  # the scatters under benchmark
                data_mat = data_mat.at[:, rel0:rel0 + block.shape[1]].set(block)
            scales = scales * prod_all
        else:
            data_mat = heads
        if sp.parent >= 0:
            gheads, gtails, _ = segmented_head_tail(
                data_mat, scales, jnp.asarray(ix.group_to_pgroup),
                jnp.asarray(ix.pos_in_pgroup), sp.P)
            phi_up_group = cnt["phi_up"][jnp.asarray(ix.group_to_pgroup)]
            emit(sp.subtree_start, gtails * jnp.sqrt(phi_up_group)[:, None])
            carried_data[idx] = gheads
            carried_scales[idx] = jnp.sqrt(cnt["phi_down"])
        else:
            emit(sp.subtree_start, data_mat)

    r0 = jnp.zeros((spec.r0_rows, spec.num_cols), dtype=dtype)
    for row0, col0, block in out_blocks:  # the scatters under benchmark
        r0 = r0.at[row0:row0 + block.shape[0],
                   col0:col0 + block.shape[1]].set(block)
    return r0


def run(csv: Csv, *, fast: bool = False) -> None:
    rows: list[dict] = []

    def add(case, metric, value):
        csv.add("engine", case, metric, value)
        rows.append({"case": case, "metric": metric, "value": float(value)})

    schemas = {"favorita": favorita_like(scale=1000 if fast else 4000),
               "yelp": yelp_like(scale=500 if fast else 2000)}
    for name, tree in schemas.items():
        plan = build_plan(tree)
        data = plan.data

        # -- scatter vs scatter-free assembly (both jitted, plan as arg) ----
        scatter_fn = jax.jit(lambda p, d: _scatter_r0(p, d))
        free_fn = jax.jit(lambda p, d: figaro_r0(p, list(d),
                                                 dtype=jnp.float64))
        stripped = plan.without_data()
        np.testing.assert_allclose(  # same R0, bit-for-bit layout
            np.asarray(scatter_fn(stripped, data)),
            np.asarray(free_fn(stripped, data)), atol=1e-12)
        t_scatter = timeit(lambda: scatter_fn(stripped, data))
        t_free = timeit(lambda: free_fn(stripped, data))
        add(name, "assembly_scatter_s", t_scatter)
        add(name, "assembly_scatter_free_s", t_free)
        add(name, "assembly_speedup", t_scatter / t_free)

        # -- per-sample loop vs batched dispatch ----------------------------
        engine = FigaroEngine(donate_data=False)
        b = 4 if fast else 16
        rng = np.random.default_rng(0)
        batch = tuple(
            np.stack([rng.normal(size=np.asarray(d).shape) for _ in range(b)])
            for d in data)
        per_sample = lambda: [engine.qr(plan, [d[i] for d in batch],
                                        dtype=jnp.float64) for i in range(b)]
        batched = lambda: engine.qr(plan, batch, batched=True,
                                    dtype=jnp.float64)
        t_loop = timeit(per_sample)
        t_batch = timeit(batched)
        add(name, "dispatch_batch_size", b)
        add(name, "dispatch_per_sample_s", t_loop)
        add(name, "dispatch_batched_s", t_batch)
        add(name, "dispatch_speedup", t_loop / t_batch)
        add(name, "traces_qr", engine.trace_count("qr"))
        add(name, "traces_qr_batched", engine.trace_count("qr_batched"))

        # -- façade overhead: Session/JoinDataset dispatch vs direct engine -
        # The repro.figaro Session is the supported surface; it must stay a
        # thin veneer. Same engine, same executable — the delta is pure
        # Python option-resolution, asserted under 5% at bench sizes.
        from repro.api import Session

        def best_of(fn, n=15):
            # Min over many reps: the overhead delta (~µs) sits well under
            # scheduler noise at ms dispatch scale, and min is the standard
            # noise filter for pure-overhead comparisons.
            block(fn())  # warm
            ts = []
            for _ in range(n):
                t0 = time.perf_counter()
                block(fn())
                ts.append(time.perf_counter() - t0)
            return min(ts)

        sess = Session(engine=engine, bucket=False)
        t_direct = best_of(lambda: engine.qr(plan, dtype=jnp.float64))
        t_session = best_of(lambda: sess.qr(plan, dtype=jnp.float64))
        ds = sess.from_tree(tree)
        t_dataset = best_of(lambda: ds.qr(dtype=jnp.float64))
        case = f"{name}:api_overhead"
        add(case, "direct_engine_s", t_direct)
        add(case, "session_s", t_session)
        add(case, "dataset_s", t_dataset)
        add(case, "session_overhead_frac", t_session / t_direct - 1.0)
        add(case, "dataset_overhead_frac", t_dataset / t_direct - 1.0)
        assert t_session < 1.05 * t_direct, (
            f"{name}: Session dispatch {t_session:.6f}s exceeds direct "
            f"engine {t_direct:.6f}s by more than 5%")

        # -- single-device vs mesh-sharded batched dispatch -----------------
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        sharded = lambda: engine.qr(plan, batch, batched=True, shard=mesh,
                                    dtype=jnp.float64)
        t_shard = timeit(sharded)
        case = f"{name}:sharded_dispatch"
        add(case, "mesh_devices", mesh.shape["data"])
        add(case, "batch_size", b)
        add(case, "single_device_s", t_batch)
        add(case, "mesh_s", t_shard)
        add(case, "speedup", t_batch / t_shard)
        add(case, "traces_qr_batched_total", engine.trace_count("qr_batched"))

        # -- append-only refresh: capacity plan vs rebuild-and-recompile ----
        # Serving cost of a data append. Capacity path: host re-ingest + pad
        # (refresh_plan) + a launch-only dispatch of the cached executable.
        # Naive path: build_plan + a dispatch that must compile the fresh
        # exact signature (measured once — that's the point).
        from repro.core.plan_cache import build_capacity_plan, refresh_plan

        cap = build_capacity_plan(tree, headroom=64)
        cap_engine = FigaroEngine(donate_data=False)
        block(cap_engine.qr(cap, dtype=jnp.float64))  # compile once up front
        fact = tree.preorder()[0]
        rel = cap.source_tree.db[fact]
        new_rows = ({a: rel.key_col(a)[:8].copy() for a in rel.key_attrs},
                    rng.normal(size=(8, rel.num_data_cols)))

        t0 = time.perf_counter()
        refreshed = refresh_plan(cap, {fact: new_rows})
        t_refresh_host = time.perf_counter() - t0
        traces_before = cap_engine.trace_count("qr")
        t_refresh_serve = timeit(
            lambda: cap_engine.qr(refreshed, dtype=jnp.float64))
        assert cap_engine.trace_count("qr") == traces_before  # zero retraces

        t0 = time.perf_counter()
        rebuilt = build_plan(refreshed.source_tree)
        fresh_engine = FigaroEngine(donate_data=False)
        block(fresh_engine.qr(rebuilt, dtype=jnp.float64))  # incl. compile
        t_rebuild = time.perf_counter() - t0

        case = f"{name}:plan_refresh"
        add(case, "appended_rows", 8)
        add(case, "refresh_host_s", t_refresh_host)
        add(case, "refresh_serve_s", t_refresh_serve)
        add(case, "rebuild_recompile_s", t_rebuild)
        add(case, "speedup",
            t_rebuild / (t_refresh_host + t_refresh_serve))
        add(case, "retraces_after_refresh",
            cap_engine.trace_count("qr") - traces_before)

    write_bench_json("engine", rows)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c, fast=True)
