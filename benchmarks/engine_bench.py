"""Engine microbenchmarks: scatter vs scatter-free R₀ assembly, per-sample vs
batched (vmapped) dispatch, and single-device vs mesh-sharded batched dispatch
through `FigaroEngine`.

Three comparisons, all on the paper-style schemas:

  * **assembly**: the pre-refactor emission path scattered every block into a
    zeroed [M×N] buffer with ``.at[].set`` (O(nodes) dislocated updates on the
    hot path); the engine assembles R₀ by concatenating column-padded row
    slabs. Both jitted, same plan, same data — wall-clock ratio is the win.
  * **dispatch**: serving B feature-sets as B per-sample engine calls vs one
    vmapped batched dispatch (one launch, one executable).
  * **sharded_dispatch**: the same global batch answered by the 1-executable
    vmapped dispatch vs the `shard_map` dispatch over the local ``data`` mesh
    (`make_data_mesh`). On the default single-CPU-device run the mesh is
    1-wide and the ratio is ~1; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to measure a real
    mesh split.
  * **plan_refresh**: serving latency of an append-only data refresh — a
    capacity plan (`plan_cache.refresh_plan`, zero retraces asserted) vs
    rebuilding the exact plan and recompiling its fresh signature.
  * **async_serving**: a stream of micro-batch requests answered by the
    blocking per-request loop vs the pipelined ``submit`` stream at queue
    depths 1/2/4 (`train.async_serve` — host prep + H2D of the next batch
    overlaps the in-flight dispatch at depth >= 2).

Emits the standard ``BENCH_engine.json`` (see `_util.write_bench_json`) so the
perf trajectory tracks this PR onward.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counts import compute_counts
from repro.core.engine import FigaroEngine
from repro.core.figaro import assembly_traffic, figaro_r0
from repro.core.heads_tails import segmented_head_tail
from repro.core.join_tree import build_plan
from repro.data.relational import favorita_like, yelp_like

from ._util import Csv, block, timeit, write_bench_json


def _scatter_r0(plan, data, *, dtype=jnp.float64):
    """The pre-refactor assembly: emit blocks into jnp.zeros via .at[].set.

    Kept here (benchmarks only) as the baseline side of the assembly
    comparison; the library path is scatter-free.
    """
    spec = plan.spec
    data = [jnp.asarray(d, dtype=dtype) for d in data]
    counts = compute_counts(plan, dtype=dtype)
    carried_data, carried_scales = {}, {}
    out_blocks = []
    row_acc = 0

    def emit(col0, block):
        nonlocal row_acc
        out_blocks.append((row_acc, col0, block))
        row_acc += block.shape[0]

    for idx in reversed(spec.preorder):
        sp, ix = spec.nodes[idx], plan.index[idx]
        cnt = counts[idx]
        x = data[idx]
        ones = jnp.ones((sp.m,), dtype=dtype)
        heads, tails, _ = segmented_head_tail(
            x, ones, jnp.asarray(ix.row_to_group),
            jnp.asarray(ix.pos_in_group), sp.K)
        phi_circ_row = cnt["phi_circ"][jnp.asarray(ix.row_to_group)]
        emit(sp.col_start, tails * jnp.sqrt(phi_circ_row)[:, None])
        scales = jnp.sqrt(cnt["rpk"])
        if sp.children:
            gathered = []
            for ch, rel0 in zip(sp.children, sp.child_rel_col0):
                lookup = jnp.asarray(ix.child_lookup[ch])
                gathered.append((rel0, carried_data.pop(ch)[lookup],
                                 carried_scales.pop(ch)[lookup]))
            prod_all = functools.reduce(jnp.multiply,
                                        [s for _, _, s in gathered])
            parts = [(0, heads * prod_all[:, None])]
            for j, (rel0, dj, _) in enumerate(gathered):
                prod_except = functools.reduce(
                    jnp.multiply,
                    [s for k, (_, _, s) in enumerate(gathered) if k != j],
                    scales)
                parts.append((rel0, dj * prod_except[:, None]))
            data_mat = jnp.zeros((sp.K, sp.subtree_width), dtype=dtype)
            for rel0, block in parts:  # the scatters under benchmark
                data_mat = data_mat.at[:, rel0:rel0 + block.shape[1]].set(block)
            scales = scales * prod_all
        else:
            data_mat = heads
        if sp.parent >= 0:
            gheads, gtails, _ = segmented_head_tail(
                data_mat, scales, jnp.asarray(ix.group_to_pgroup),
                jnp.asarray(ix.pos_in_pgroup), sp.P)
            phi_up_group = cnt["phi_up"][jnp.asarray(ix.group_to_pgroup)]
            emit(sp.subtree_start, gtails * jnp.sqrt(phi_up_group)[:, None])
            carried_data[idx] = gheads
            carried_scales[idx] = jnp.sqrt(cnt["phi_down"])
        else:
            emit(sp.subtree_start, data_mat)

    r0 = jnp.zeros((spec.r0_rows, spec.num_cols), dtype=dtype)
    for row0, col0, block in out_blocks:  # the scatters under benchmark
        r0 = r0.at[row0:row0 + block.shape[0],
                   col0:col0 + block.shape[1]].set(block)
    return r0


def run(csv: Csv, *, fast: bool = False) -> None:
    rows: list[dict] = []

    def add(case, metric, value):
        csv.add("engine", case, metric, value)
        rows.append({"case": case, "metric": metric, "value": float(value)})

    schemas = {"favorita": favorita_like(scale=1000 if fast else 4000),
               "yelp": yelp_like(scale=500 if fast else 2000)}
    for name, tree in schemas.items():
        plan = build_plan(tree)
        data = plan.data

        # -- scatter vs scatter-free assembly (both jitted, plan as arg) ----
        scatter_fn = jax.jit(lambda p, d: _scatter_r0(p, d))
        free_fn = jax.jit(lambda p, d: figaro_r0(p, list(d),
                                                 dtype=jnp.float64))
        stripped = plan.without_data()
        np.testing.assert_allclose(  # same R0, bit-for-bit layout
            np.asarray(scatter_fn(stripped, data)),
            np.asarray(free_fn(stripped, data)), atol=1e-12)
        t_scatter = timeit(lambda: scatter_fn(stripped, data))
        t_free = timeit(lambda: free_fn(stripped, data))
        add(name, "assembly_scatter_s", t_scatter)
        add(name, "assembly_scatter_free_s", t_free)
        add(name, "assembly_speedup", t_scatter / t_free)

        # Bytes-moved model next to the wall-clock: padded assembly re-copies
        # every slab at full R₀ width, band assembly writes each slab at its
        # own width into a zeroed buffer (`figaro.assembly_traffic`).
        bytes_padded = assembly_traffic(plan.spec, assembly="padded")
        bytes_band = assembly_traffic(plan.spec, assembly="band")
        band_fn = jax.jit(lambda p, d: figaro_r0(p, list(d),
                                                 dtype=jnp.float64,
                                                 assembly="band"))
        # Band relocates the same slab values, but the two jitted programs
        # fuse differently, so agreement is ulp-level, not bitwise.
        np.testing.assert_allclose(
            np.asarray(free_fn(stripped, data)),
            np.asarray(band_fn(stripped, data)), rtol=1e-12, atol=1e-12)
        t_band = timeit(lambda: band_fn(stripped, data))
        add(name, "assembly_padded_bytes", bytes_padded)
        add(name, "assembly_band_bytes", bytes_band)
        add(name, "assembly_band_bytes_ratio", bytes_band / bytes_padded)
        add(name, "assembly_band_s", t_band)
        add(name, "assembly_band_vs_padded_speedup", t_free / t_band)

        # -- per-sample loop vs batched dispatch ----------------------------
        engine = FigaroEngine(donate_data=False)
        b = 4 if fast else 16
        rng = np.random.default_rng(0)
        batch = tuple(
            np.stack([rng.normal(size=np.asarray(d).shape) for _ in range(b)])
            for d in data)
        per_sample = lambda: [engine.qr(plan, [d[i] for d in batch],
                                        dtype=jnp.float64) for i in range(b)]
        batched = lambda: engine.qr(plan, batch, batched=True,
                                    dtype=jnp.float64)
        t_loop = timeit(per_sample)
        t_batch = timeit(batched)
        add(name, "dispatch_batch_size", b)
        add(name, "dispatch_per_sample_s", t_loop)
        add(name, "dispatch_batched_s", t_batch)
        add(name, "dispatch_speedup", t_loop / t_batch)
        add(name, "traces_qr", engine.trace_count("qr"))
        add(name, "traces_qr_batched", engine.trace_count("qr_batched"))

        # -- façade overhead: Session/JoinDataset dispatch vs direct engine -
        # The repro.figaro Session is the supported surface; it must stay a
        # thin veneer. Same engine, same executable — the delta is pure
        # Python option-resolution, asserted under 5% at bench sizes.
        from repro.api import Session

        def best_of_each(fns, n=25):
            # Min over many INTERLEAVED reps: the overhead delta (~µs) sits
            # well under scheduler noise at ms dispatch scale; min filters
            # the noise, and round-robin ordering cancels machine drift that
            # would bias back-to-back measurement phases against each other.
            for fn in fns:
                block(fn())  # warm
            ts = [[] for _ in fns]
            for _ in range(n):
                for slot, fn in zip(ts, fns):
                    t0 = time.perf_counter()
                    block(fn())
                    slot.append(time.perf_counter() - t0)
            return [min(s) for s in ts]

        sess = Session(engine=engine, bucket=False)
        ds = sess.from_tree(tree)
        t_direct, t_session, t_dataset = best_of_each([
            lambda: engine.qr(plan, dtype=jnp.float64),
            lambda: sess.qr(plan, dtype=jnp.float64),
            lambda: ds.qr(dtype=jnp.float64)])
        case = f"{name}:api_overhead"
        add(case, "direct_engine_s", t_direct)
        add(case, "session_s", t_session)
        add(case, "dataset_s", t_dataset)
        add(case, "session_overhead_frac", t_session / t_direct - 1.0)
        add(case, "dataset_overhead_frac", t_dataset / t_direct - 1.0)
        # 5% relative plus a 1 ms absolute allowance: the façade's real cost
        # is a constant few µs of option resolution, so at ms dispatch scale
        # a tight bound trips on scheduler jitter (measured ~0.5 ms swings
        # on a busy 2-core box even with interleaved reps), not regressions.
        # The failure mode this guards — per-dispatch plan flattening or
        # plan rebuilds sneaking into the façade — costs >= 100% at these
        # sizes and still trips it.
        assert t_session < 1.05 * t_direct + 1e-3, (
            f"{name}: Session dispatch {t_session:.6f}s exceeds direct "
            f"engine {t_direct:.6f}s by more than 5% + 1ms")

        # -- single-device vs mesh-sharded batched dispatch -----------------
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        sharded = lambda: engine.qr(plan, batch, batched=True, shard=mesh,
                                    dtype=jnp.float64)
        t_shard = timeit(sharded)
        case = f"{name}:sharded_dispatch"
        add(case, "mesh_devices", mesh.shape["data"])
        add(case, "batch_size", b)
        add(case, "single_device_s", t_batch)
        add(case, "mesh_s", t_shard)
        add(case, "speedup", t_batch / t_shard)
        add(case, "traces_qr_batched_total", engine.trace_count("qr_batched"))

        # -- kernel path: fused node kernel × assembly variant --------------
        # All four (use_kernel × assembly) corners through the same engine.
        # On CPU the fused kernel runs interpret=True (emulation — expect it
        # to LOSE here; the comparison that transfers to TPU is the bytes
        # model above and the parity columns). Zero extra retraces: repeat
        # dispatches of every corner stay launch-only.
        kp_engine = FigaroEngine(donate_data=False)
        case = f"{name}:kernel_path"
        r_base = None
        for use_kernel in (False, True):
            for asm in ("padded", "band"):
                fn = lambda: kp_engine.qr(plan, dtype=jnp.float64,
                                          use_kernel=use_kernel, assembly=asm)
                t_corner = timeit(fn)
                tag = f"{'fused' if use_kernel else 'xla'}_{asm}"
                add(case, f"qr_{tag}_s", t_corner)
                r = fn()
                if r_base is None:
                    r_base = r
                else:
                    add(case, f"qr_{tag}_max_abs_err",
                        float(jnp.abs(r - r_base).max()))
        traces_now = kp_engine.trace_count("qr")
        for use_kernel in (False, True):  # repeat every corner: launch-only
            for asm in ("padded", "band"):
                block(kp_engine.qr(plan, dtype=jnp.float64,
                                   use_kernel=use_kernel, assembly=asm))
        add(case, "retraces_on_repeat",
            kp_engine.trace_count("qr") - traces_now)

        # -- append-only refresh: capacity plan vs rebuild-and-recompile ----
        # Serving cost of a data append. Capacity path: host re-ingest + pad
        # (refresh_plan) + a launch-only dispatch of the cached executable.
        # Naive path: build_plan + a dispatch that must compile the fresh
        # exact signature (measured once — that's the point).
        from repro.core.plan_cache import build_capacity_plan, refresh_plan

        cap = build_capacity_plan(tree, headroom=64)
        cap_engine = FigaroEngine(donate_data=False)
        block(cap_engine.qr(cap, dtype=jnp.float64))  # compile once up front
        fact = tree.preorder()[0]
        rel = cap.source_tree.db[fact]
        new_rows = ({a: rel.key_col(a)[:8].copy() for a in rel.key_attrs},
                    rng.normal(size=(8, rel.num_data_cols)))

        t0 = time.perf_counter()
        refreshed = refresh_plan(cap, {fact: new_rows})
        t_refresh_host = time.perf_counter() - t0
        traces_before = cap_engine.trace_count("qr")
        t_refresh_serve = timeit(
            lambda: cap_engine.qr(refreshed, dtype=jnp.float64))
        assert cap_engine.trace_count("qr") == traces_before  # zero retraces

        t0 = time.perf_counter()
        rebuilt = build_plan(refreshed.source_tree)
        fresh_engine = FigaroEngine(donate_data=False)
        block(fresh_engine.qr(rebuilt, dtype=jnp.float64))  # incl. compile
        t_rebuild = time.perf_counter() - t0

        case = f"{name}:plan_refresh"
        add(case, "appended_rows", 8)
        add(case, "refresh_host_s", t_refresh_host)
        add(case, "refresh_serve_s", t_refresh_serve)
        add(case, "rebuild_recompile_s", t_rebuild)
        add(case, "speedup",
            t_rebuild / (t_refresh_host + t_refresh_serve))
        add(case, "retraces_after_refresh",
            cap_engine.trace_count("qr") - traces_before)

        # -- async serving: blocking per-request loop vs pipelined stream ---
        # Same engine, same executable, same micro-batches (max_batch pins
        # the coalescer so every group is exactly one request — the delta is
        # pure pipelining: at queue depth >= 2 the next batch's host prep +
        # H2D staging overlaps the in-flight dispatch). Depth 1 serializes
        # the same machinery and is the sync baseline.
        from repro.train.serve import make_figaro_server

        micro_b = 2 if fast else 4
        n_req = 8 if fast else 16
        serve_engine = FigaroEngine(donate_data=False)
        reqs = [tuple(np.stack([rng.normal(size=np.asarray(d).shape)
                                for _ in range(micro_b)]) for d in data)
                for _ in range(n_req)]

        def run_stream(server, pipelined):
            t0 = time.perf_counter()
            if pipelined:
                futures = [server.submit(r) for r in reqs]
                for f in futures:
                    f.result()
            else:
                for r in reqs:
                    server(r)  # submit(...).result(): blocking
            return time.perf_counter() - t0

        # One server per configuration, warmed up front; reps are then
        # INTERLEAVED round-robin across configurations (min per config) so
        # machine drift cannot bias one whole configuration's phase —
        # measured back-to-back, a load spike lands on a single config and
        # fabricates a 2x swing either way at these stream lengths.
        configs = [("sync", 1, False), ("depth1", 1, True),
                   ("depth2", 2, True), ("depth4", 4, True)]
        servers = {key: make_figaro_server(
            plan, kind="qr", dtype=jnp.float64, engine=serve_engine,
            max_batch=micro_b, queue_depth=depth)
            for key, depth, _ in configs}
        for server in servers.values():
            server(reqs[0])  # warm: compile once, outside the timing
        stream_ts: dict = {key: [] for key, _, _ in configs}
        for _ in range(5):
            for key, _, pipelined in configs:
                stream_ts[key].append(run_stream(servers[key], pipelined))
        best = {key: min(ts) for key, ts in stream_ts.items()}
        for server in servers.values():
            server.close()

        case = f"{name}:async_serving"
        add(case, "micro_batch", micro_b)
        add(case, "requests", n_req)
        add(case, "sync_s", best["sync"])
        add(case, "sync_req_per_s", n_req * micro_b / best["sync"])
        for depth in (1, 2, 4):
            t_pipe = best[f"depth{depth}"]
            add(case, f"pipelined_depth{depth}_s", t_pipe)
            add(case, f"pipelined_depth{depth}_req_per_s",
                n_req * micro_b / t_pipe)
            add(case, f"speedup_depth{depth}", best["sync"] / t_pipe)
        add(case, "traces_qr_batched", serve_engine.trace_count("qr_batched"))

    # -- figaro-lint overhead: the analysis CI job must stay interactive ----
    # Full-repo wall time of the AST analyzer (every rule family over src/,
    # including the figaro-flow interprocedural pass). Pure host Python — no
    # jit, no device. The bound is generous on purpose: tripping it means a
    # rule went accidentally quadratic, not that the runner was busy.
    from pathlib import Path

    from repro.analysis import analyze_paths, load_program

    repo = Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    findings = analyze_paths([str(repo / "src")], root=str(repo))
    t_lint = time.perf_counter() - t0
    case = "analysis_overhead"
    add(case, "wall_s", t_lint)
    add(case, "files", sum(1 for _ in (repo / "src").rglob("*.py")))
    add(case, "findings", len(findings))
    assert t_lint < 10.0, (
        f"figaro-lint full-repo pass took {t_lint:.2f}s (>= 10s budget) — "
        f"a rule likely went quadratic")

    # figaro-flow in isolation: call-graph build + jit-region marking +
    # dataflow fixpoint over src/, reported as its own row so a regression in
    # the interprocedural layer is visible separately from the lexical rules.
    t0 = time.perf_counter()
    program = load_program([str(repo / "src")], root=str(repo))
    sinks = program.dataflow().sinks
    t_flow = time.perf_counter() - t0
    case = "analysis_interprocedural"
    add(case, "wall_s", t_flow)
    add(case, "functions", len(program.graph.functions))
    add(case, "traced", len(program.graph.traced))
    add(case, "roots", len(program.graph.roots))
    add(case, "sinks", len(sinks))
    assert t_flow < 10.0, (
        f"figaro-flow interprocedural pass took {t_flow:.2f}s (>= 10s "
        f"budget) — the callgraph/dataflow fixpoint likely went quadratic")

    # -- figaro-san overhead: disabled mode must cost (nearly) nothing ------
    # The runtime sanitizer's disabled contract is physical: the race hooks
    # are removed from the instrumented classes and the engine pays one
    # STATE flag read per dispatch. Measured on the hot (fully cached)
    # dispatch path, interleaved with enable/disable cycles so a leaked
    # __getattribute__ hook after disable() — the real regression mode —
    # shows up as a disabled-mode slowdown. Enabled-mode overhead (hooks +
    # lockset bookkeeping; float64 requests, so no shadow dispatch) is
    # reported, not bounded: it is diagnostic tooling, not the serving path.
    from repro import sanitizer as figaro_san

    san_engine = FigaroEngine(donate_data=False)
    san_plan = build_plan(yelp_like(scale=20, cols=2))
    hot = lambda: san_engine.qr(san_plan, dtype=jnp.float64)
    block(hot())  # compile once; every timed call below is a cache hit
    t_base = timeit(hot)
    n_reps = 25
    t_off, t_on = [], []
    for _ in range(n_reps):
        t0 = time.perf_counter()
        block(hot())
        t_off.append(time.perf_counter() - t0)
        figaro_san.enable(sample_every=10 ** 9)
        try:
            t0 = time.perf_counter()
            block(hot())
            t_on.append(time.perf_counter() - t0)
        finally:
            figaro_san.disable()
    figaro_san.reset()
    t_disabled, t_enabled = min(t_off), min(t_on)
    case = "sanitizer_overhead"
    add(case, "baseline_s", t_base)
    add(case, "disabled_s", t_disabled)
    add(case, "enabled_s", t_enabled)
    add(case, "disabled_overhead_frac", t_disabled / t_base - 1.0)
    add(case, "enabled_overhead_frac", t_enabled / t_base - 1.0)
    # 2% relative plus a 1 ms absolute allowance, same rationale as the
    # api_overhead bound: the guarded failure (hooks surviving disable())
    # costs far more than jitter at these sizes.
    assert t_disabled < 1.02 * t_base + 1e-3, (
        f"sanitizer disabled-mode dispatch {t_disabled:.6f}s exceeds "
        f"baseline {t_base:.6f}s by more than 2% + 1ms — are the race "
        f"hooks being uninstalled?")

    # -- planner: predicted-cost ranking vs measured runtime per retailer
    # orientation, plus root="auto" planning overhead vs one compile
    # (implementation shared with benchmarks.join_tree_effect).
    from .join_tree_effect import planner_section

    planner_section(add, fast=fast)

    write_bench_json("engine", rows)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c, fast=True)
