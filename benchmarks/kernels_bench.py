"""Kernel-layer benchmark: FiGaRo inner loop (segmented head/tail), the fused
node kernel, and the post-processing panel QR.

On this CPU container the Pallas kernels execute in ``interpret=True`` mode
(Python emulation — NOT indicative of TPU speed); wall time is reported for
the XLA path that actually runs here, and the kernel path is checked for
agreement. On TPU the kernel path replaces the XLA scan with one fused
HBM→VMEM pass (see EXPERIMENTS.md §Perf for the roofline accounting).

Emits the standard ``BENCH_kernels.json`` (see `_util.write_bench_json`) so
the kernel-layer perf trajectory is tracked alongside the engine's.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.heads_tails import segmented_head_tail
from repro.core.postprocess import blocked_qr_r
from repro.kernels.node_fused import fused_node_pass, fused_node_pass_ref
from repro.kernels.panel_qr import ops as pq_ops, ref as pq_ref

from ._util import Csv, timeit, write_bench_json


def _segments(rng, m):
    """Sorted segment ids + position-within-segment for m rows."""
    seg = np.sort(rng.integers(0, m // 16, size=m)).astype(np.int32)
    pos = np.zeros(m, np.int32)
    pos[1:] = np.where(seg[1:] == seg[:-1], 1, 0)
    pos = np.cumsum(pos) * (pos > 0)
    return seg, pos


def run(csv: Csv, *, fast: bool = False) -> None:
    rows: list[dict] = []

    def add(case, metric, value):
        csv.add("kernels", case, metric, value)
        rows.append({"case": case, "metric": metric, "value": float(value)})

    rng = np.random.default_rng(0)
    sizes = [(4096, 64), (16384, 64)] if fast else \
        [(4096, 64), (16384, 64), (65536, 64)]
    for m, n in sizes:
        data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
        w = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
        seg, pos = _segments(rng, m)
        args = (data, w, jnp.array(seg), jnp.array(pos), int(seg.max()) + 1)
        t = timeit(lambda: segmented_head_tail(*args))
        case = f"headtail_{m}x{n}"
        add(case, "xla_path_s", t)
        add(case, "rows_per_s", m / t)
        if m <= 4096:  # interpret mode is slow; validate on the small size
            h1, t1, _ = segmented_head_tail(*args, use_kernel=False)
            h2, t2, _ = segmented_head_tail(*args, use_kernel=True)
            add(case, "kernel_max_abs_err", float(jnp.abs(t1 - t2).max()))

    # -- fused node pass: one-kernel mask+scan+scale+emit vs its XLA ref ----
    # The ref is the path figaro_r0(use_kernel=False) effectively runs; the
    # fused kernel replaces three-plus HBM round-trips per node with one.
    for m, n in [(4096, 64)] if fast else [(4096, 64), (16384, 64)]:
        data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
        w = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
        seg, pos = _segments(rng, m)
        num_seg = int(seg.max()) + 1
        pos_j = jnp.array(pos)
        emit = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
        starts = np.nonzero(np.r_[True, seg[1:] != seg[:-1]])[0]
        last = jnp.array(np.r_[starts[1:] - 1, m - 1].astype(np.int32))
        live = jnp.ones((num_seg,), bool)
        f_args = (data, w, pos_j, emit, last, live)
        t_ref = timeit(lambda: fused_node_pass_ref(*f_args))
        case = f"node_fused_{m}x{n}"
        add(case, "xla_ref_s", t_ref)
        add(case, "rows_per_s", m / t_ref)
        if m <= 4096:  # interpret-mode check on the small size only
            s1, h1, nn1 = fused_node_pass_ref(*f_args)
            s2, h2, nn2 = fused_node_pass(*f_args)
            add(case, "kernel_slab_max_abs_err", float(jnp.abs(s1 - s2).max()))
            add(case, "kernel_head_max_abs_err", float(jnp.abs(h1 - h2).max()))

    for m, nb in [(512, 64)] if fast else [(512, 64), (2048, 128)]:
        a = jnp.array(rng.normal(size=(m, nb)), jnp.float32)
        t = timeit(lambda: blocked_qr_r(a, panel=32))
        add(f"panelqr_{m}x{nb}", "xla_path_s", t)
        v1, b1, r1 = pq_ops.panel_qr(a[:, :32])
        v2, b2, r2 = pq_ref.panel_qr_ref(a[:, :32])
        add(f"panelqr_{m}x{nb}", "kernel_max_abs_err",
            float(jnp.abs(r1 - r2).max()))

    write_bench_json("kernels", rows)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
