"""Kernel-layer benchmark: FiGaRo inner loop (segmented head/tail) and the
post-processing panel QR.

On this CPU container the Pallas kernels execute in ``interpret=True`` mode
(Python emulation — NOT indicative of TPU speed); wall time is reported for
the XLA path that actually runs here, and the kernel path is checked for
agreement. On TPU the kernel path replaces the XLA scan with one fused
HBM→VMEM pass (see EXPERIMENTS.md §Perf for the roofline accounting).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.heads_tails import segmented_head_tail
from repro.core.postprocess import blocked_qr_r
from repro.kernels.panel_qr import ops as pq_ops, ref as pq_ref

from ._util import Csv, timeit


def run(csv: Csv, *, fast: bool = False) -> None:
    rng = np.random.default_rng(0)
    sizes = [(4096, 64), (16384, 64)] if fast else \
        [(4096, 64), (16384, 64), (65536, 64)]
    for m, n in sizes:
        data = jnp.array(rng.normal(size=(m, n)), jnp.float32)
        w = jnp.array(rng.uniform(0.5, 2.0, size=m), jnp.float32)
        seg = np.sort(rng.integers(0, m // 16, size=m)).astype(np.int32)
        pos = np.zeros(m, np.int32)
        pos[1:] = np.where(seg[1:] == seg[:-1], 1, 0)
        pos = np.cumsum(pos) * (pos > 0)  # position within segment
        args = (data, w, jnp.array(seg), jnp.array(pos), int(seg.max()) + 1)
        t = timeit(lambda: segmented_head_tail(*args))
        case = f"headtail_{m}x{n}"
        csv.add("kernels", case, "xla_path_s", t)
        csv.add("kernels", case, "rows_per_s", m / t)
        if m <= 4096:  # interpret mode is slow; validate on the small size
            h1, t1, _ = segmented_head_tail(*args, use_kernel=False)
            h2, t2, _ = segmented_head_tail(*args, use_kernel=True)
            csv.add("kernels", case, "kernel_max_abs_err",
                    float(jnp.abs(t1 - t2).max()))
    for m, nb in [(512, 64)] if fast else [(512, 64), (2048, 128)]:
        a = jnp.array(rng.normal(size=(m, nb)), jnp.float32)
        t = timeit(lambda: blocked_qr_r(a, panel=32))
        csv.add("kernels", f"panelqr_{m}x{nb}", "xla_path_s", t)
        v1, b1, r1 = pq_ops.panel_qr(a[:, :32])
        v2, b2, r2 = pq_ref.panel_qr_ref(a[:, :32])
        csv.add("kernels", f"panelqr_{m}x{nb}", "kernel_max_abs_err",
                float(jnp.abs(r1 - r2).max()))


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
