"""Benchmark driver: one module per paper table/figure.

  python -m benchmarks.run            # full set
  python -m benchmarks.run --fast     # reduced sizes (CI)
  python -m benchmarks.run --only accuracy,scaling

Emits ``benchmark,case,metric,value`` CSV on stdout.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (accuracy, cartesian_grid, counts_bench, engine_bench,
               figaro_runtime, join_tree_effect, kernels_bench, lm_roofline,
               scaling)
from ._util import Csv

BENCHES = {
    "figaro_runtime": figaro_runtime.run,    # Fig 4
    "cartesian_grid": cartesian_grid.run,    # Fig 5
    "scaling": scaling.run,                  # Fig 6
    "join_tree_effect": join_tree_effect.run,  # Table 2
    "accuracy": accuracy.run,                # Table 3
    "counts": counts_bench.run,              # Algorithm 1 (ours)
    "kernels": kernels_bench.run,            # Pallas layer (ours)
    "lm_roofline": lm_roofline.run,          # §Roofline table (ours)
    "engine": engine_bench.run,              # compiled engine (this PR)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)
    csv = Csv()
    csv.header()
    failed = []
    for name, fn in BENCHES.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            fn(csv, fast=args.fast)
            csv.add(name, "_total", "bench_wall_s", time.time() - t0)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            csv.add(name, "_total", "ERROR", f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
