"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable

import jax

# The paper evaluates in double precision; every benchmark that asks for
# float64 needs x64 enabled before the first trace.
jax.config.update("jax_enable_x64", True)


def block(x):
    return jax.tree_util.tree_map(
        lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
        else l, x)


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds of ``fn()`` (device-synchronized)."""
    for _ in range(warmup):
        block(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        block(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Csv:
    """Collects (benchmark, case, metric, value) rows and prints CSV."""

    def __init__(self):
        self.rows: list[tuple[str, str, str, str]] = []

    def add(self, bench: str, case: str, metric: str, value) -> None:
        if isinstance(value, float):
            value = f"{value:.6g}"
        self.rows.append((bench, case, metric, str(value)))
        print(f"{bench},{case},{metric},{value}", flush=True)

    def header(self) -> None:
        print("benchmark,case,metric,value", flush=True)
