"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

# The paper evaluates in double precision; every benchmark that asks for
# float64 needs x64 enabled before the first trace.
jax.config.update("jax_enable_x64", True)


def block(x):
    return jax.tree_util.tree_map(
        lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
        else l, x)


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds of ``fn()`` (device-synchronized)."""
    for _ in range(warmup):
        block(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        block(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Csv:
    """Collects (benchmark, case, metric, value) rows and prints CSV."""

    def __init__(self):
        self.rows: list[tuple[str, str, str, str]] = []

    def add(self, bench: str, case: str, metric: str, value) -> None:
        if isinstance(value, float):
            value = f"{value:.6g}"
        self.rows.append((bench, case, metric, str(value)))
        print(f"{bench},{case},{metric},{value}", flush=True)

    def header(self) -> None:
        print("benchmark,case,metric,value", flush=True)


def write_bench_json(bench: str, rows: list[dict], out_dir: str = ".") -> str:
    """Emit the standard ``BENCH_<name>.json`` perf-trajectory artifact.

    Shape (schema ``bench.v1``): ``{"benchmark", "schema", "created_unix",
    "rows": [{"case", "metric", "value"}, ...]}``. Dashboards diff these
    across PRs; every benchmark that should be tracked writes one.
    """
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    payload = {
        "benchmark": bench,
        "schema": "bench.v1",
        "created_unix": int(time.time()),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return path
