"""Experiment 4 (Table 3): accuracy against a reverse-engineered ground truth.

``accuracy_db`` constructs relations S, T whose Cartesian-product QR has a
*known* upper-triangular block R_fixed (the paper's construction). Both
FiGaRo and the materialized baseline run in float32 (the TPU working dtype);
the error is measured against the float64 ground truth:

    err = ||R_fixed_hat - R_fixed||_F / ||R_fixed||_F          (Table 3 left)
    ratio = err_materialized / err_figaro                      (Table 3 right)

ratio > 1 reproduces the paper's claim: FiGaRo commits fewer rounding errors
because it never forms (or sweeps over) the p*q-row join.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.join_tree import build_plan
from repro.core.materialize import materialize_join
from repro.core.postprocess import normalize_sign
from repro.core.qr import figaro_qr
from repro.data.relational import accuracy_db

from ._util import Csv

# Square p == q (paper Table 3): the join is rows² — the regime where the
# materialized sweep accumulates rounding error and FiGaRo does not.
GRID = [(2**9, 2**4), (2**10, 2**4), (2**11, 2**4), (2**9, 2**6),
        (2**10, 2**6)]


def _err(r_hat: np.ndarray, r_fixed: np.ndarray) -> float:
    n = r_fixed.shape[0]
    blk = r_hat[n:, n:]
    sign = np.sign(np.diag(blk)) * np.sign(np.diag(r_fixed))
    return float(np.linalg.norm(blk * sign[:, None] - r_fixed)
                 / np.linalg.norm(r_fixed))


def run(csv: Csv, *, fast: bool = False) -> None:
    grid = GRID[:2] if fast else GRID
    for rows, n in grid:
        q = rows
        tree, r_fixed = accuracy_db(rows, q, n, seed=7)
        plan = build_plan(tree)
        case = f"rows{rows}xcols{n}"
        r_fig = np.asarray(figaro_qr(plan, dtype=jnp.float32))
        err_fig = _err(r_fig, r_fixed)
        csv.add("accuracy", case, "figaro_err", err_fig)
        join_cells = rows * q * 2 * n
        if join_cells <= 2**28:
            a32 = jnp.asarray(materialize_join(tree), jnp.float32)
            r_mat = np.asarray(normalize_sign(
                jnp.linalg.qr(a32, mode="r")[: 2 * n]))
            err_mat = _err(r_mat, r_fixed)
            csv.add("accuracy", case, "materialized_err", err_mat)
            csv.add("accuracy", case, "err_ratio", err_mat / max(err_fig,
                                                                 1e-30))
        else:
            csv.add("accuracy", case, "materialized_err", "OOM-guard")


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
