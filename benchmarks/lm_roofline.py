"""LM dry-run roofline table: reads benchmarks/results/dryrun/*.json
(produced by ``python -m repro.launch.dryrun``) and prints the per-cell
roofline terms — the §Roofline deliverable in EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

from ._util import Csv

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run(csv: Csv, *, fast: bool = False) -> None:
    recs = load_records()
    if not recs:
        csv.add("lm_roofline", "all", "status",
                "no dry-run records (run: python -m repro.launch.dryrun --all)")
        return
    for r in recs:
        case = f"{r['arch']}:{r['shape']}"
        if r["status"] == "skipped":
            csv.add("lm_roofline", case, "status", "skip")
            continue
        if r["status"] != "ok":
            csv.add("lm_roofline", case, "status", f"ERROR:{r.get('error')}")
            continue
        if "compute_s" not in r:
            csv.add("lm_roofline", case, "status", "compile-only")
            continue
        csv.add("lm_roofline", case, "compute_s", r["compute_s"])
        csv.add("lm_roofline", case, "memory_s", r["memory_s"])
        csv.add("lm_roofline", case, "collective_s", r["collective_s"])
        csv.add("lm_roofline", case, "dominant", r["dominant"])
        csv.add("lm_roofline", case, "mfu", r["mfu"])
        csv.add("lm_roofline", case, "useful_flops_fraction",
                r["useful_flops_fraction"])
        csv.add("lm_roofline", case, "mem_gb_per_dev",
                r["peak_memory_per_device"] / 1e9)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
