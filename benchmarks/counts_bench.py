"""Algorithm 1 throughput: the batched count queries are two passes and
linear time — rows/second should be flat across scales."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.counts import compute_counts
from repro.core.join_tree import build_plan
from repro.data.relational import favorita_like

from ._util import Csv, timeit


def run(csv: Csv, *, fast: bool = False) -> None:
    scales = (500, 2000) if fast else (500, 2000, 8000)
    for scale in scales:
        tree = favorita_like(scale=scale)
        plan = build_plan(tree)
        rows = sum(nd.data.shape[0] for nd in plan.nodes)
        t = timeit(lambda: compute_counts(plan, dtype=jnp.float64))
        csv.add("counts", f"scale{scale}", "rows", rows)
        csv.add("counts", f"scale{scale}", "seconds", t)
        csv.add("counts", f"scale{scale}", "rows_per_s", rows / t)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
