"""Experiment 3 (Table 2): the join-tree choice changes FiGaRo's runtime
(up to 394x in the paper) but never the result R — plus the figaro-plan
validation that the cost model *predicts* that choice.

``retailer_like(root=...)`` builds the paper's good tree (fact table at the
root, keys aggregated away early) vs bad tree (fact table deep in the tree,
so dimension heads get multiplied out before being aggregated). Everything
runs through the `figaro.Session` facade/engine path (the legacy
``figaro_qr_fn`` closure this file used to drive is gone from the serving
stack).

`planner_section(add, fast=...)` is shared with `benchmarks.engine_bench`:
it sweeps *every* rooted orientation of the retailer schema, records the
planner's predicted cost next to the measured runtime per orientation,
asserts the model ranks the paper's good root above the bad one (and, for
every pair separated by >20% predicted cost, that prediction order matches
measured order), and measures the ``root="auto"`` planning overhead against
the cost of a single compile.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from repro import figaro
from repro.data.relational import retailer_like
from repro.planner import choose_root, rank_orientations
from repro.planner.stats import _CACHE_ATTR

from ._util import Csv, timeit

# Predicted-cost separation below which a measured-order disagreement is
# noise, not a model failure (Location vs Census differ by <1% on this
# schema; wall-clock jitter alone can swap them).
_SEPARATION = 1.2

# Measured-runtime tolerance for the pairwise order check: at bench scales
# the fixed dispatch overhead compresses the gaps the model predicts, so a
# predicted-cheaper orientation only has to be measured no more than this
# fraction slower for the pair to count as agreeing.
_JITTER = 0.15


def _measure_orientations(scale: int):
    """(db, edges, ranking, measured_s, singular_values) over every rooted
    orientation of the retailer schema, via the Session/engine path."""
    base = retailer_like(scale=scale, root="good")
    db, edges = base.db, base.edges()
    ranking = rank_orientations(db, edges)
    measured, svals = {}, {}
    for oc in ranking:
        sess = figaro.Session()  # fresh engine: same compile state per root
        ds = sess.ingest(db).join(edges, root=oc.root, reduce=False)
        r = np.asarray(ds.qr(dtype=jnp.float64), dtype=np.float64)
        measured[oc.root] = timeit(lambda: ds.qr(dtype=jnp.float64),
                                   repeats=5)
        svals[oc.root] = np.linalg.svd(r, compute_uv=False)
    return db, edges, ranking, measured, svals


def planner_section(add, *, fast: bool = False) -> None:
    """Emit the `planner` bench section through ``add(case, metric, value)``.

    Asserts (1) predicted cost ranks the paper's good root above the bad one
    and measured runtime agrees, (2) predicted order matches measured order
    for every pair separated by >20% predicted cost, and (3) auto-root
    planning costs a small fraction of one compile. ``fast`` is accepted for
    section-signature uniformity; the sweep runs at one fixed scale (below).
    """
    # One scale for both modes: 1200 is the smallest retailer size where the
    # per-orientation rotation work dominates the fixed dispatch overhead
    # (below it all five orientations measure within jitter of each other;
    # the capacity buckets of much larger sizes can compress the gap again).
    scale = 1200
    db, edges, ranking, measured, _ = _measure_orientations(scale)
    for rank, oc in enumerate(ranking):
        add(f"planner_root_{oc.root}", "predicted_cost", float(oc.total))
        add(f"planner_root_{oc.root}", "predicted_rank", rank)
        add(f"planner_root_{oc.root}", "measured_s", measured[oc.root])

    pred = [oc.root for oc in ranking]
    assert pred.index("Inventory") < pred.index("Location"), (
        f"cost model ranks the paper's bad retailer root above the good one: "
        f"{pred}")
    assert measured["Inventory"] < measured["Location"] * (1.0 + _JITTER), (
        f"measured runtime disagrees with Table 2: good "
        f"{measured['Inventory']:.6f}s vs bad {measured['Location']:.6f}s")
    pairs = agree = 0
    for a, b in itertools.combinations(ranking, 2):  # a predicted cheaper
        if b.total > _SEPARATION * a.total:
            pairs += 1
            agree += measured[a.root] <= measured[b.root] * (1.0 + _JITTER)
    add("planner", "separated_pairs", pairs)
    add("planner", "rank_agreement_frac", agree / pairs if pairs else 1.0)
    if pairs and agree < pairs:
        print(f"# planner: {pairs - agree}/{pairs} separated pairs measured "
              f"out of predicted order (>{_JITTER:.0%} jitter) — CPU "
              f"wall-clock at this scale is load-sensitive; the recorded "
              f"rows carry both rankings", flush=True)
    assert pairs == 0 or agree * 2 >= pairs, (
        f"predicted ranking disagrees with measured runtimes on the "
        f"majority of well-separated orientation pairs "
        f"({pairs - agree}/{pairs} beyond the {_JITTER:.0%} allowance)")

    # root="auto" planning overhead vs ONE compile. Planning is pure numpy
    # (stats collection + r orientation scores); clear the per-db stats cache
    # each call so the timed cost is the cold, first-join cost.
    def plan_cold():
        if hasattr(db, _CACHE_ATTR):
            delattr(db, _CACHE_ATTR)
        return choose_root(db, edges)

    t_plan = timeit(plan_cold)

    def compile_once():
        sess = figaro.Session()
        return sess.ingest(db).join(edges, root="Inventory",
                                    reduce=False).qr(dtype=jnp.float64)

    t_compile = timeit(compile_once, repeats=1, warmup=0)
    add("planner", "auto_plan_s", t_plan)
    add("planner", "compile_s", t_compile)
    add("planner", "plan_vs_compile_frac", t_plan / t_compile)
    assert t_plan < 0.1 * t_compile, (
        f"root='auto' planning ({t_plan:.6f}s) is not << one compile "
        f"({t_compile:.6f}s)")


def run(csv: Csv, *, fast: bool = False) -> None:
    scale = 400 if fast else 6000
    _, _, ranking, measured, svals = _measure_orientations(scale)
    name_of = {"Inventory": "good", "Location": "bad"}
    base = retailer_like(scale=scale, root="good")
    total_rows = sum(rel.num_rows for rel in base.db)
    for root in ("Inventory", "Location"):
        csv.add("join_tree_effect", name_of[root], "figaro_s", measured[root])
        csv.add("join_tree_effect", name_of[root], "r0_rows", total_rows)
    csv.add("join_tree_effect", "good_vs_bad", "speedup",
            measured["Location"] / measured["Inventory"])
    # result invariance across trees: identical singular values (columns are
    # permuted between orientations, so R differs; its spectrum must not)
    s_good, s_bad = svals["Inventory"], svals["Location"]
    csv.add("join_tree_effect", "good_vs_bad", "sv_rel_err",
            float(np.abs(s_good - s_bad).max() / s_good.max()))
    # the auto-rooted facade lands on the paper's good orientation
    csv.add("join_tree_effect", "auto", "picks_good_root",
            int(ranking[0].root == "Inventory"))

    def bench_add(case, metric, value):
        csv.add("join_tree_effect", case, metric, value)

    planner_section(bench_add, fast=fast)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c, fast=True)
