"""Experiment 3 (Table 2): the join-tree choice changes FiGaRo's runtime
(up to 394x in the paper) but never the result R.

``retailer_like(root=...)`` builds the paper's good tree (fact table at the
root, keys aggregated away early) vs bad tree (fact table deep in the tree,
so dimension heads get multiplied out before being aggregated).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.join_tree import build_plan
from repro.core.qr import figaro_qr_fn
from repro.data.relational import retailer_like

from ._util import Csv, timeit


def run(csv: Csv, *, fast: bool = False) -> None:
    scale = 400 if fast else 6000
    r_by_tree = {}
    for root in ("good", "bad"):
        tree = retailer_like(scale=scale, root=root)
        plan = build_plan(tree)
        fig = figaro_qr_fn(plan, dtype=jnp.float64)
        data = [jnp.asarray(nd.data) for nd in plan.nodes]
        t = timeit(lambda: fig(data))
        r_by_tree[root] = (t, np.asarray(fig(data)))
        csv.add("join_tree_effect", root, "figaro_s", t)
        csv.add("join_tree_effect", root, "r0_rows",
                int(sum(nd.data.shape[0] for nd in plan.nodes)))
    csv.add("join_tree_effect", "good_vs_bad", "speedup",
            r_by_tree["bad"][0] / r_by_tree["good"][0])
    # result invariance across trees: identical singular values
    s_good = np.linalg.svd(r_by_tree["good"][1], compute_uv=False)
    s_bad = np.linalg.svd(r_by_tree["bad"][1], compute_uv=False)
    csv.add("join_tree_effect", "good_vs_bad", "sv_rel_err",
            float(np.abs(s_good - s_bad).max() / s_good.max()))


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
