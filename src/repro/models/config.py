"""Model configuration schema for the 10 assigned architectures.

A model is a stack of ``n_blocks`` identical *super-blocks*; each super-block
is a static list of `LayerSpec`s. Homogeneous archs use a 1-layer super-block
(n_blocks == n_layers); jamba uses an 8-layer super-block (1 attention : 7
mamba, MoE on odd positions). `lax.scan` runs over super-blocks so compiled
HLO size is independent of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Mixer = Literal["attn", "mamba", "rwkv6", "none"]
Mlp = Literal["dense", "moe", "dense+moe", "rwkv_cmix", "none"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None  # default d_model // 16


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    lora_decay: int = 64
    lora_mix: int = 32


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    mlp: Mlp = "dense"
    cross_attn: bool = False  # decoder layers of enc-dec models


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_blocks: int  # number of scanned super-blocks
    block: tuple[LayerSpec, ...] = (LayerSpec(),)

    head_dim: int | None = None  # default d_model // n_heads
    qk_norm: bool = False
    swa_window: int | None = None  # sliding-window attention
    rope_theta: float = 1e4
    norm: Literal["rms", "layer"] = "rms"
    tie_embeddings: bool = False
    use_bias: bool = False

    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None

    # Encoder-decoder (whisper): encoder super-blocks + fixed frame count.
    encoder_blocks: int = 0
    encoder_block: tuple[LayerSpec, ...] = ()
    encoder_len: int = 0  # e.g. 1500 audio frames (frontend stubbed)

    # VLM (llava): number of prefix patch-embedding positions (stub frontend).
    patch_positions: int = 0

    # Precision / memory policy.
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    # lax.scan over super-blocks (compile-size O(1) in depth). The dry-run's
    # cost pass sets False: XLA's cost_analysis counts loop bodies once, so
    # FLOP/collective accounting needs the unrolled artifact (launch/dryrun.py).
    scan_layers: bool = True
    # FSDP-style weight sharding over the data axis (ZeRO) — needed by the
    # biggest archs to fit; see sharding/rules.py.
    fsdp: bool = False
    # Attention KV-block size for the blockwise (online-softmax) path.
    attn_block_kv: int = 1024
    # Unroll the KV-block loop (Python loop instead of lax.scan). Used by the
    # dry-run cost pass: cost_analysis counts scan bodies once, so honest
    # FLOP/byte accounting of the fused (flash-style) attention needs the
    # unrolled artifact. Production keeps the scan (small HLO).
    attn_unroll_blocks: bool = False
    # Route train/prefill self-attention through the fused Pallas kernel
    # (kernels/flash_attn). TPU production path; on CPU it runs interpreted
    # (tests only) — the XLA blockwise scan is the CPU execution path.
    use_flash_kernel: bool = False
    # Chunk length of the two-level SSM/linear-RNN scan (models/ssm.py).
    ssm_chunk: int = 64
    # Mesh axis names carrying data parallelism, e.g. ("pod", "data").
    # When set, the model inserts with_sharding_constraint on activations at
    # block boundaries — without these, GSPMD propagation can replicate the
    # token dim and silently lose DP compute scaling (found in the dry-run;
    # see EXPERIMENTS.md §Perf iteration 0).
    dp_axes: tuple[str, ...] | None = None
    # Hierarchical MoE dispatch: split tokens into this many groups (== DP
    # shard count on the mesh) so the routing argsort/scatter stays local and
    # only capacity-bounded [G, E, C, d] buffers cross the expert axis.
    # 1 == the global sort (single-device semantics). §Perf iteration A1.
    moe_groups: int = 1
    # Mesh axes carrying the expert dimension (EP), e.g. ("model",) when
    # num_experts % |model| == 0; None -> TP-on-ff fallback.
    ep_axes: tuple[str, ...] | None = None
    # Sequence parallelism: shard the token/sequence dim of activations over
    # `model` between blocks (turns per-layer TP all-reduces into
    # reduce-scatter + all-gather and shards norm compute). §Perf iter Q1.
    seq_shard_activations: bool = False
    # Keep the vocab dim of the output logits sharded over `model` (decode
    # samples from the shards). §Perf iteration C1. No-op when dp_axes unset.
    shard_logits: bool = True

    # Sub-quadratic family? (drives long_500k applicability; see DESIGN.md)
    @property
    def subquadratic(self) -> bool:
        if self.swa_window is not None:
            return True
        mixers = {spec.mixer for spec in self.block}
        return bool(mixers & {"mamba", "rwkv6"}) and ("attn" not in mixers or
                                                      self.family == "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 128 — lane-aligned and
        divisible by the 16-way model axis (production practice; padded ids
        are masked out of logits)."""
        return -(-self.vocab // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return self.n_blocks * len(self.block) + \
            self.encoder_blocks * len(self.encoder_block)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_blocks > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model-FLOPs in roofline)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_params() -> int:
            return d * hd * (nq + 2 * nkv) + nq * hd * d

        def dense_mlp() -> int:
            return 3 * d * ff  # SwiGLU

        def moe_mlp() -> int:
            assert self.moe is not None
            return self.moe.num_experts * 3 * d * ff + d * self.moe.num_experts

        def mamba_params() -> int:
            mc = self.mamba or MambaConfig()
            di = mc.expand * d
            dt_rank = mc.dt_rank or d // 16
            return (d * 2 * di + di * mc.d_conv + di * (dt_rank + 2 * mc.d_state)
                    + dt_rank * di + di * mc.d_state + di + di * d)

        def rwkv_params() -> int:
            rc = self.rwkv or RWKVConfig()
            return 4 * d * d + d * d + 2 * d * rc.lora_decay + \
                5 * 2 * d * rc.lora_mix + 2 * d * ff

        def spec_params(spec: LayerSpec) -> int:
            p = 0
            if spec.mixer == "attn":
                p += attn_params()
            elif spec.mixer == "mamba":
                p += mamba_params()
            elif spec.mixer == "rwkv6":
                p += rwkv_params()
            if spec.cross_attn:
                p += attn_params()
            if spec.mlp == "dense":
                p += dense_mlp()
            elif spec.mlp == "moe":
                p += moe_mlp()
            elif spec.mlp == "dense+moe":
                p += dense_mlp() + moe_mlp()
            elif spec.mlp == "rwkv_cmix":
                p += 2 * d * ff
            return p

        total += self.n_blocks * sum(spec_params(s) for s in self.block)
        total += self.encoder_blocks * sum(spec_params(s)
                                           for s in self.encoder_block)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of E experts) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.num_experts, self.moe.top_k
        expert_p = self.moe.num_experts * 3 * self.d_model * self.d_ff
        n_moe_layers = self.n_blocks * sum(
            1 for s in self.block if s.mlp in ("moe", "dense+moe"))
        inactive = n_moe_layers * expert_p * (1 - k / e) // 1
        return int(full - inactive)
