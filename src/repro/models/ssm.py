"""State-space / linear-RNN mixers: Mamba (jamba) and RWKV-6 (Finch).

Both are instances of a diagonal linear recurrence ``h_t = a_t ⊙ h_{t-1} + u_t``.
Materializing [T, state] is hopeless at 4k–500k tokens, so training/prefill use
a *chunked* two-level scan (DESIGN.md §7): an outer `lax.scan` over chunks
carries the state; inside a chunk the recurrence closes with an associative
scan over ≤`chunk` steps, materializing only [B, chunk, state]. The chunk body
is `jax.checkpoint`-ed, so backward recomputes per chunk (remat). Decode is the
O(1)-state single-step update — the reason these archs run `long_500k`.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .config import MambaConfig, ModelConfig, RWKVConfig
from .layers import Params, dense_init

DEFAULT_CHUNK = 64


def chunked_recurrence(inputs, init_state, body: Callable, chunk: int):
    """Outer scan over chunks of the time axis (axis=1 of every input leaf).

    ``body(h0, chunk_inputs) -> (h_out, chunk_outputs)``; the body is
    checkpointed. Returns (outputs concatenated over chunks, final state).
    """
    t = jax.tree_util.tree_leaves(inputs)[0].shape[1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        inputs = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)),
            inputs)
    nc = (t + pad) // chunk
    stacked = jax.tree_util.tree_map(
        lambda x: x.reshape((x.shape[0], nc, chunk) + x.shape[2:])
        .swapaxes(0, 1), inputs)

    wrapped = jax.checkpoint(lambda h, xs: body(h, xs))
    final, outs = jax.lax.scan(wrapped, init_state, stacked)
    outs = jax.tree_util.tree_map(
        lambda y: y.swapaxes(0, 1).reshape((y.shape[1], nc * chunk) + y.shape[3:]),
        outs)
    if pad:
        outs = jax.tree_util.tree_map(lambda y: y[:, :t], outs)
    return outs, final


def _assoc_inclusive(decay, u):
    """Inclusive states of h_t = decay_t ⊙ h_{t-1} + u_t along axis=1 (h_0=0)."""

    def combine(a, b):
        return b[0] * a[0], b[0] * a[1] + b[1]

    dd, uu = jax.lax.associative_scan(combine, (decay, u), axis=1)
    return dd, uu  # hs = uu + dd * h0


# ---------------------------------------------------------------------------
# Mamba (selective SSM, mamba-1 recurrence as in jamba)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig) -> Params:
    mc = cfg.mamba or MambaConfig()
    d = cfg.d_model
    di = mc.expand * d
    dtr = mc.dt_rank or d // 16
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dt),
        "conv_w": dense_init(ks[1], (mc.d_conv, di), dt, scale=0.2),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * mc.d_state), dt),
        "dt_proj": dense_init(ks[3], (dtr, di), dt),
        "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(~0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=dt),
                                  (di, 1))),
        "d_skip": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[4], (di, d), dt),
    }


def _mamba_inner(p, x, z, conv_state, h0, cfg: ModelConfig):
    """Shared train/decode core given post-projection x [B,T,di]."""
    mc = cfg.mamba or MambaConfig()
    cdt = jnp.dtype(cfg.compute_dtype)
    b, t, di = x.shape
    ds = mc.d_state
    dtr = mc.dt_rank or cfg.d_model // 16

    # Causal depthwise conv over time (state = last d_conv-1 inputs).
    xin = jnp.concatenate([conv_state.astype(cdt), x], axis=1)
    new_conv_state = xin[:, -(mc.d_conv - 1):]
    conv = sum(xin[:, i:i + t] * p["conv_w"][i].astype(cdt)
               for i in range(mc.d_conv))
    x = jax.nn.silu(conv + p["conv_b"].astype(cdt))

    dbc = jnp.einsum("btd,de->bte", x, p["x_proj"].astype(cdt))
    dt_r, bmat, cmat = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_r, p["dt_proj"].astype(cdt))
        + p["dt_bias"].astype(cdt)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [di, ds]

    def body(h0, xs):
        delta_c, b_c, x_c = xs  # [B,L,di], [B,L,ds], [B,L,di]
        decay = jnp.exp(delta_c[..., None] * a)              # [B,L,di,ds]
        u = (delta_c * x_c.astype(jnp.float32))[..., None] * \
            b_c.astype(jnp.float32)[:, :, None, :]           # [B,L,di,ds]
        dd, uu = _assoc_inclusive(decay, u)
        hs = uu + dd * h0[:, None]
        return hs[:, -1], hs

    hs, h_last = chunked_recurrence((delta, bmat, x), h0.astype(jnp.float32),
                                    body, cfg.ssm_chunk)
    y = jnp.einsum("btds,bts->btd", hs.astype(cdt), cmat)
    y = y + x * p["d_skip"].astype(cdt)
    y = y * jax.nn.silu(z)
    return y, new_conv_state, h_last


def apply_mamba(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                cache: Params | None = None):
    """x [B, T, d] -> (y [B, T, d], new_cache)."""
    mc = cfg.mamba or MambaConfig()
    cdt = jnp.dtype(cfg.compute_dtype)
    b, t, d = x.shape
    di = mc.expand * d
    xz = jnp.einsum("btd,de->bte", x.astype(cdt), p["in_proj"].astype(cdt))
    xi, z = jnp.split(xz, 2, axis=-1)
    if cache is None:
        conv_state = jnp.zeros((b, mc.d_conv - 1, di), cdt)
        h0 = jnp.zeros((b, di, mc.d_state), jnp.float32)
    else:
        conv_state, h0 = cache["conv"], cache["ssm"]
    y, conv_state, h_last = _mamba_inner(p, xi, z, conv_state, h0, cfg)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(cdt))
    new_cache = {"conv": conv_state.astype(cdt), "ssm": h_last}
    return out.astype(x.dtype), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int) -> Params:
    mc = cfg.mamba or MambaConfig()
    di = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear attention + channel mix
# ---------------------------------------------------------------------------


def init_rwkv(key, cfg: ModelConfig) -> Params:
    rc = cfg.rwkv or RWKVConfig()
    d = cfg.d_model
    h = d // rc.head_size
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    return {
        "mu": dense_init(ks[0], (5, d), dt, scale=0.2),      # r,k,v,w,g shifts
        "mix_w1": dense_init(ks[1], (d, 5 * rc.lora_mix), dt),
        "mix_w2": dense_init(ks[2], (5, rc.lora_mix, d), dt, scale=0.1),
        "wr": dense_init(ks[3], (d, d), dt),
        "wk": dense_init(ks[4], (d, d), dt),
        "wv": dense_init(ks[5], (d, d), dt),
        "wg": dense_init(ks[6], (d, d), dt),
        "wo": dense_init(ks[7], (d, d), dt),
        "w0": jnp.full((d,), -2.0, dt),
        "decay_w1": dense_init(ks[8], (d, rc.lora_decay), dt),
        "decay_w2": dense_init(ks[9], (rc.lora_decay, d), dt, scale=0.1),
        "bonus": dense_init(ks[10], (h, rc.head_size), dt, scale=0.5),
        "ln_x": jnp.ones((d,), dt),
    }


def apply_rwkv(p: Params, x: jnp.ndarray, cfg: ModelConfig,
               cache: Params | None = None):
    """RWKV-6 time-mix. x [B,T,d] -> (y, new_cache)."""
    rc = cfg.rwkv or RWKVConfig()
    cdt = jnp.dtype(cfg.compute_dtype)
    b, t, d = x.shape
    hd = rc.head_size
    h = d // hd
    xc = x.astype(cdt)

    if cache is None:
        x_prev_last = jnp.zeros((b, 1, d), cdt)
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    else:
        x_prev_last, s0 = cache["shift"].astype(cdt), cache["state"]
    x_prev = jnp.concatenate([x_prev_last, xc[:, :-1]], axis=1)
    dx = x_prev - xc

    # Data-dependent token-shift (ddlerp): per-channel r,k,v,w,g mixes.
    lora = jnp.tanh(jnp.einsum("btd,de->bte", xc, p["mix_w1"].astype(cdt)))
    lora = lora.reshape(b, t, 5, rc.lora_mix)
    mix = p["mu"].astype(cdt)[None, None] + jnp.einsum(
        "btcl,cld->btcd", lora, p["mix_w2"].astype(cdt))
    xr, xk, xv, xw, xg = [xc + dx * mix[:, :, i] for i in range(5)]

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(cdt)).reshape(b, t, h, hd)
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(cdt)).reshape(b, t, h, hd)
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(cdt)).reshape(b, t, h, hd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(cdt)))
    # Data-dependent decay w_t = exp(-exp(w0 + lora_w(x_w))) in (0, 1).
    wlog = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btd,de,ef->btf", xw.astype(jnp.float32),
        p["decay_w1"].astype(jnp.float32), p["decay_w2"].astype(jnp.float32))
    decay = jnp.exp(-jnp.exp(wlog)).reshape(b, t, h, hd)
    u = p["bonus"].astype(jnp.float32)  # [h, hd]

    def body(s0, xs):
        r_c, k_c, v_c, w_c = xs  # [B,L,h,hd]
        kf, vf = k_c.astype(jnp.float32), v_c.astype(jnp.float32)
        kv = kf[..., :, None] * vf[..., None, :]        # [B,L,h,hd,hd]
        dd, uu = _assoc_inclusive(w_c[..., None], kv)
        hs = uu + dd * s0[:, None]
        s_prev = jnp.concatenate([s0[:, None], hs[:, :-1]], axis=1)
        rf = r_c.astype(jnp.float32)
        y = jnp.einsum("blhk,blhkv->blhv", rf, s_prev)
        y += jnp.einsum("blhk,hk,blhk,blhv->blhv", rf, u, kf, vf)
        return hs[:, -1], y

    y, s_last = chunked_recurrence((r, k, v, decay), s0, body, cfg.ssm_chunk)
    # Per-head group norm, then gate + output projection.
    yf = y.reshape(b, t, h, hd)
    mu_ = yf.mean(-1, keepdims=True)
    var = ((yf - mu_) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu_) * jax.lax.rsqrt(var + 1e-5)
    yf = yf.reshape(b, t, d) * p["ln_x"].astype(jnp.float32)
    out = jnp.einsum("btd,de->bte", yf.astype(cdt) * g, p["wo"].astype(cdt))
    new_cache = {"shift": xc[:, -1:], "state": s_last}
    return out.astype(x.dtype), new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int) -> Params:
    rc = cfg.rwkv or RWKVConfig()
    d = cfg.d_model
    h = d // rc.head_size
    return {
        "shift": jnp.zeros((batch, 1, d), jnp.dtype(cfg.compute_dtype)),
        "state": jnp.zeros((batch, h, rc.head_size, rc.head_size), jnp.float32),
    }


def init_rwkv_cmix(key, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "mu_k": dense_init(ks[0], (d,), dt, scale=0.2),
        "mu_r": dense_init(ks[1], (d,), dt, scale=0.2),
        "wk": dense_init(ks[2], (d, ff), dt),
        "wv": dense_init(jax.random.fold_in(key, 7), (ff, d), dt),
        "wr": dense_init(jax.random.fold_in(key, 8), (d, d), dt),
    }


def apply_rwkv_cmix(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                    cache: Params | None = None):
    cdt = jnp.dtype(cfg.compute_dtype)
    b, t, d = x.shape
    xc = x.astype(cdt)
    prev = jnp.zeros((b, 1, d), cdt) if cache is None else \
        cache["shift"].astype(cdt)
    x_prev = jnp.concatenate([prev, xc[:, :-1]], axis=1)
    dx = x_prev - xc
    xk = xc + dx * p["mu_k"].astype(cdt)
    xr = xc + dx * p["mu_r"].astype(cdt)
    kk = jnp.einsum("btd,df->btf", xk, p["wk"].astype(cdt))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("btf,fd->btd", kk, p["wv"].astype(cdt))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(cdt)))
    return (rr * vv).astype(x.dtype), {"shift": xc[:, -1:]}
