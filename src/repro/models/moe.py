"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Dispatch is gather/scatter (argsort by expert, positions via cumulative
counts), *not* one-hot einsum — the HLO FLOP count then reflects real expert
compute (tokens·k·3·d·ff), which keeps the roofline analysis honest.

Sharding: expert weights are laid out [E, d, ff]. When ``E % |model axis| == 0``
the rules shard E over `model` (expert parallelism: arctic 128e, jamba 16e);
otherwise ff is sharded (TP fallback: mixtral 8e on a 16-way axis). The
dispatch buffer [E, C, d] inherits E's sharding, so GSPMD inserts the
token-exchange collectives (hillclimbed in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import Params, dense_init


def init_moe(key, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    e, d, ff = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dt, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, ff), dt),
        "w_up": dense_init(ks[2], (e, d, ff), dt),
        "w_down": dense_init(ks[3], (e, ff, d), dt),
    }


def _constrain(x, spec):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


def _dispatch_one(xt, gate_e, gate_w, *, e: int, cap: int, cdt):
    """Sort-based dispatch of ONE token group [n, d] into buffers [e*cap, d].

    Returns (buf [e*cap, d], slot [n*k], stok [n*k], sw [n*k], keep [n*k]).
    """
    n, d = xt.shape
    k = gate_e.shape[-1]
    flat_e = gate_e.reshape(-1)  # [n*k]
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.arange(n * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)  # stable
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # Position within expert = rank - first rank of that expert.
    expert_first = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(n * k) - expert_first[se]
    keep = pos < cap
    slot = se * cap + jnp.where(keep, pos, 0)
    buf = jnp.zeros((e * cap, d), cdt)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[stok], 0.0))
    return buf, slot, stok, sw, keep


def _combine_one(out_flat, slot, stok, sw, keep, n: int, cdt):
    """Inverse of `_dispatch_one`: [e*cap, d] expert outputs -> [n, d]."""
    gathered = out_flat[slot]
    contrib = jnp.where(keep[:, None], gathered * sw[:, None].astype(cdt), 0.0)
    return jnp.zeros((n, out_flat.shape[-1]), cdt).at[stok].add(contrib)


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d] -> (y [B, T, d], aux_loss scalar).

    **Hierarchical dispatch** (cfg.moe_groups > 1): tokens are split into G
    data-parallel groups; the argsort/scatter runs *per group* (local, no
    cross-shard data motion) and only the compact [G, E, C_loc, d] buffers
    cross the expert-parallel axis. With the global sort (G == 1 semantics on
    a mesh) GSPMD has to all-gather every token to every device — measured
    9.4 TB/device on arctic-480b×train_4k; the hierarchical path moves only
    capacity-bounded buffers (EXPERIMENTS.md §Perf iteration A1). Capacity is
    applied per group (standard local-capacity MoE practice).
    """
    mc = cfg.moe
    assert mc is not None
    b, t, d = x.shape
    e, k = mc.num_experts, mc.top_k
    cdt = jnp.dtype(cfg.compute_dtype)
    n = b * t
    grp = max(1, cfg.moe_groups)
    if n % grp != 0:  # tiny smoke batches: fall back to one group
        grp = 1
    nl = n // grp
    dp = cfg.dp_axes
    ep = cfg.ep_axes
    xt = x.reshape(grp, nl, d).astype(cdt)
    xt = _constrain(xt, None if dp is None else P(dp, None, None))

    logits = jnp.einsum("gnd,de->gne", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)  # [g, nl, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style) + router z-loss.
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(
        jnp.ones((n * k,), jnp.float32)) / (n * k)
    aux = mc.aux_loss_coef * e * jnp.sum(me * ce)
    aux += mc.router_z_coef * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- per-group sort-based dispatch into [G, E, C_loc, d] ----------------
    cap = int(mc.capacity_factor * nl * k / e)
    cap = max(8, -(-cap // 8) * 8)  # sublane-align capacity
    buf, slot, stok, sw, keep = jax.vmap(
        lambda xg, eg, wg: _dispatch_one(xg, eg, wg, e=e, cap=cap, cdt=cdt)
    )(xt, gate_e, gate_w)
    buf = buf.reshape(grp, e, cap, d)
    # Expert-parallel placement: the compact buffer crosses the `ep` axis —
    # this is the only tensor that moves between expert shards.
    buf_spec = None if dp is None else P(dp, ep, None, None)
    buf = _constrain(buf, buf_spec)

    g_act = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(cdt))
    u_act = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(cdt))
    h = jax.nn.silu(g_act) * u_act
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    out = _constrain(out, buf_spec)

    y = jax.vmap(lambda of, sl, st, w, kp: _combine_one(
        of, sl, st, w, kp, nl, cdt))(out.reshape(grp, e * cap, d), slot,
                                     stok, sw, keep)
    y = _constrain(y, None if dp is None else P(dp, None, None))
    return y.reshape(b, t, d).astype(x.dtype), aux.astype(jnp.float32)
