"""Model assembly: params init, train forward, prefill, and decode.

The depth dimension is a `lax.scan` over stacked super-block params (HLO size
independent of layer count — critical for the 512-device dry-run compile).
Per-super-block structure is static Python (`cfg.block` LayerSpecs), so jamba's
1-attn:7-mamba interleave and arctic's dense+MoE parallel residual stay
scan-able. `jax.checkpoint` wraps the block body when ``cfg.remat``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from . import layers, moe as moe_lib, ssm
from .config import LayerSpec, ModelConfig
from .layers import Params


def _constrain_act(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pin activations to batch-over-DP (see ModelConfig.dp_axes).

    With ``cfg.seq_shard_activations`` the sequence dim additionally shards
    over `model` between blocks (Megatron-style sequence parallelism: GSPMD
    then lowers the per-layer TP all-reduces to reduce-scatter + all-gather
    and shards the norm compute; §Perf iteration Q1)."""
    if cfg.dp_axes is None:
        return x
    if (cfg.seq_shard_activations and x.ndim >= 3 and
            x.shape[1] >= 128 and x.shape[1] % 128 == 0):
        spec = P(cfg.dp_axes, "model", *([None] * (x.ndim - 2)))
    else:
        spec = P(cfg.dp_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_sublayer(key, spec: LayerSpec, cfg: ModelConfig) -> Params:
    ks = iter(jax.random.split(key, 8))
    p: Params = {"norm1": layers.init_norm(cfg)}
    if spec.mixer == "attn":
        p["mixer"] = layers.init_attention(next(ks), cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm.init_mamba(next(ks), cfg)
    elif spec.mixer == "rwkv6":
        p["mixer"] = ssm.init_rwkv(next(ks), cfg)
    if spec.cross_attn:
        p["norm_x"] = layers.init_norm(cfg)
        p["cross"] = layers.init_attention(next(ks), cfg)
    if spec.mlp != "none":
        p["norm2"] = layers.init_norm(cfg)
    if spec.mlp == "dense":
        p["mlp"] = layers.init_mlp(next(ks), cfg)
    elif spec.mlp == "moe":
        p["moe"] = moe_lib.init_moe(next(ks), cfg)
    elif spec.mlp == "dense+moe":
        p["mlp"] = layers.init_mlp(next(ks), cfg)
        p["moe"] = moe_lib.init_moe(next(ks), cfg)
    elif spec.mlp == "rwkv_cmix":
        p["mlp"] = ssm.init_rwkv_cmix(next(ks), cfg)
    return p


def _init_stack(key, specs, n_blocks: int, cfg: ModelConfig) -> Params:
    """Stack super-block params along a leading scan axis [n_blocks, ...]."""

    def one(k):
        ks = jax.random.split(k, len(specs))
        return {f"pos{i}": _init_sublayer(ks[i], s, cfg)
                for i, s in enumerate(specs)}

    return jax.vmap(one)(jax.random.split(key, n_blocks))


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "blocks": _init_stack(ks[1], cfg.block, cfg.n_blocks, cfg),
        "final_norm": layers.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[2], (cfg.d_model,
                                                 cfg.padded_vocab), dt)
    if cfg.is_enc_dec:
        p["encoder"] = _init_stack(ks[3], cfg.encoder_block,
                                   cfg.encoder_blocks, cfg)
        p["enc_norm"] = layers.init_norm(cfg)
        p["enc_pos"] = (jax.random.normal(ks[4], (cfg.encoder_len,
                                                  cfg.d_model)) * 0.02).astype(dt)
    if cfg.patch_positions:
        p["patch_proj"] = layers.dense_init(ks[5], (cfg.d_model, cfg.d_model), dt)
    return p


# ---------------------------------------------------------------------------
# Super-block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_sublayer(spec: LayerSpec, p: Params, x, cfg: ModelConfig, *,
                    positions, causal, enc_out, cache, cache_pos):
    """One residual sub-layer. Returns (x, new_cache, aux)."""
    new_cache: dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["norm1"], x, cfg)
    if spec.mixer == "attn":
        y, c = layers.attention(p["mixer"], h, cfg, positions=positions,
                                causal=causal,
                                cache=None if cache is None else cache["attn"],
                                cache_pos=cache_pos)
        if c is not None:
            new_cache["attn"] = c
        x = x + y
    elif spec.mixer == "mamba":
        y, c = ssm.apply_mamba(p["mixer"], h, cfg,
                               cache=None if cache is None else cache["mamba"])
        new_cache["mamba"] = c
        x = x + y
    elif spec.mixer == "rwkv6":
        y, c = ssm.apply_rwkv(p["mixer"], h, cfg,
                              cache=None if cache is None else cache["rwkv"])
        new_cache["rwkv"] = c
        x = x + y
    if spec.cross_attn:
        h = layers.apply_norm(p["norm_x"], x, cfg)
        y, c = layers.attention(
            p["cross"], h, cfg, positions=positions, causal=False,
            cross=True, kv_x=enc_out,
            cache=None if cache is None else cache.get("cross"),
            cache_pos=cache_pos)
        if c is not None:
            new_cache["cross"] = c
        x = x + y
    if spec.mlp != "none":
        h = layers.apply_norm(p["norm2"], x, cfg)
        if spec.mlp == "dense":
            x = x + layers.apply_mlp(p["mlp"], h, cfg)
        elif spec.mlp == "moe":
            y, aux = moe_lib.apply_moe(p["moe"], h, cfg)
            x = x + y
        elif spec.mlp == "dense+moe":  # arctic: parallel dense residual + MoE
            y, aux = moe_lib.apply_moe(p["moe"], h, cfg)
            x = x + layers.apply_mlp(p["mlp"], h, cfg) + y
        elif spec.mlp == "rwkv_cmix":
            y, c = ssm.apply_rwkv_cmix(p["mlp"], h, cfg,
                                       cache=None if cache is None else
                                       cache.get("cmix"))
            new_cache["cmix"] = c
            x = x + y
    return x, new_cache, aux


def _scan_stack(params_stack, specs, x, cfg: ModelConfig, *, positions,
                causal, enc_out=None, caches=None, cache_pos=None):
    """Scan over stacked super-blocks. Returns (x, new_caches, aux_sum)."""

    def block_fn(x, inputs):
        pblk, cblk = inputs
        x = _constrain_act(x, cfg)
        aux_tot = jnp.zeros((), jnp.float32)
        new_c = {}
        for i, spec in enumerate(specs):
            c_i = None if cblk is None else cblk[f"pos{i}"]
            x, nc, aux = _apply_sublayer(
                spec, pblk[f"pos{i}"], x, cfg, positions=positions,
                causal=causal, enc_out=enc_out, cache=c_i,
                cache_pos=cache_pos)
            new_c[f"pos{i}"] = nc
            aux_tot = aux_tot + aux
        return x, (new_c, aux_tot)

    fn = jax.checkpoint(block_fn) if cfg.remat else block_fn

    n = jax.tree_util.tree_leaves(params_stack)[0].shape[0]
    if not cfg.scan_layers:  # unrolled (dry-run cost pass)
        ncs_list, aux_tot = [], jnp.zeros((), jnp.float32)
        for i in range(n):
            take = lambda t: jax.tree_util.tree_map(lambda l: l[i], t)
            x, (nc, aux) = fn(x, (take(params_stack),
                                  None if caches is None else take(caches)))
            ncs_list.append(nc)
            aux_tot = aux_tot + aux
        ncs = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ncs_list)
        return x, ncs, aux_tot

    def scan_body(x, inputs):
        x, (nc, aux) = fn(x, inputs)
        return x, (nc, aux)

    if caches is None:
        x, (ncs, auxs) = jax.lax.scan(
            lambda x, pb: scan_body(x, (pb, None)), x, params_stack)
    else:
        x, (ncs, auxs) = jax.lax.scan(scan_body, x, (params_stack, caches))
    return x, ncs, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Train-mode forward + loss
# ---------------------------------------------------------------------------


def _encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"].astype(x.dtype)[None, : x.shape[1]]
    pos = jnp.arange(x.shape[1])
    x, _, _ = _scan_stack(params["encoder"], cfg.encoder_block, x, cfg,
                          positions=pos, causal=False)
    return layers.apply_norm(params["enc_norm"], x, cfg)


def _embed_inputs(params, cfg: ModelConfig, batch: Params):
    """Token (+ modality-stub) embedding. Returns (x, positions, text_offset)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][batch["tokens"]].astype(cdt)
    offset = 0
    if cfg.patch_positions:
        patches = batch["patches"].astype(cdt)
        patches = jnp.einsum("bpd,de->bpe", patches,
                             params["patch_proj"].astype(cdt))
        x = jnp.concatenate([patches, x], axis=1)
        offset = patches.shape[1]
    positions = jnp.arange(x.shape[1])
    return _constrain_act(x, cfg), positions, offset


def _logits(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x.astype(jnp.float32),
                        head.astype(jnp.float32))
    if cfg.padded_vocab != cfg.vocab:  # mask the vocab-padding rows
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.finfo(jnp.float32).min, logits)
    if cfg.dp_axes is not None and cfg.shard_logits:
        # Keep the vocab dim sharded over `model`: decoding/loss work on the
        # shards (local argmax/logsumexp + tiny combine) — replicating
        # [B, 256k] f32 logits cost 53 GB/device/token on command-r decode
        # (§Perf iteration C1).
        logits = jax.lax.with_sharding_constraint(
            logits, P(cfg.dp_axes, None, "model"))
    return logits


def forward(params: Params, cfg: ModelConfig, batch: Params):
    """Logits over the decoder sequence: [B, S(+patches), padded_vocab]."""
    x, positions, offset = _embed_inputs(params, cfg, batch)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.is_enc_dec else None
    x, _, aux = _scan_stack(params["blocks"], cfg.block, x, cfg,
                            positions=positions, causal=True, enc_out=enc_out)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    return _logits(params, cfg, x), aux, offset


def loss_fn(params: Params, cfg: ModelConfig, batch: Params):
    """Next-token cross entropy (+ MoE aux + z-loss). Returns (loss, metrics)."""
    logits, aux, offset = forward(params, cfg, batch)
    tokens = batch["tokens"]
    logits_text = logits[:, offset:][:, :-1]
    targets = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(targets, jnp.float32) if mask is None else \
        mask[:, 1:].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits_text, axis=-1)
    # One-hot contraction instead of take_along_axis: stays fused and keeps
    # vocab-sharded (TP) logits local — no all-gather of [B,S,V].
    vocab_ids = jnp.arange(logits_text.shape[-1], dtype=targets.dtype)
    tgt_logit = jnp.sum(
        jnp.where(vocab_ids == targets[..., None], logits_text, 0.0), axis=-1)
    nll = (lse - tgt_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    zloss = 1e-4 * ((lse * mask) ** 2).sum() / denom
    loss = ce + zloss + aux
    return loss, {"ce": ce, "aux": aux, "zloss": zloss,
                  "tokens": denom}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Stacked per-super-block cache pytree (leading axis = n_blocks)."""
    cdt = jnp.dtype(cfg.compute_dtype)

    def one_block(_):
        c = {}
        for i, spec in enumerate(cfg.block):
            ci: dict[str, Any] = {}
            if spec.mixer == "attn":
                ci["attn"] = layers.init_attn_cache(cfg, batch, max_len, cdt)
            elif spec.mixer == "mamba":
                ci["mamba"] = ssm.init_mamba_cache(cfg, batch)
            elif spec.mixer == "rwkv6":
                ci["rwkv"] = ssm.init_rwkv_cache(cfg, batch)
            if spec.cross_attn:
                hd = cfg.resolved_head_dim
                ci["cross"] = {
                    "k": jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads, hd), cdt),
                    "v": jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads, hd), cdt),
                }
            if spec.mlp == "rwkv_cmix":
                ci["cmix"] = {"shift": jnp.zeros((batch, 1, cfg.d_model), cdt)}
            c[f"pos{i}"] = ci
        return c

    return jax.vmap(one_block)(jnp.arange(cfg.n_blocks))


def _fill_cross_caches(params, cfg: ModelConfig, caches, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    cdt = jnp.dtype(cfg.compute_dtype)

    def per_block(pblk, cblk):
        for i, spec in enumerate(cfg.block):
            if spec.cross_attn:
                pa = pblk[f"pos{i}"]["cross"]
                k = jnp.einsum("btd,dhk->bthk", enc_out.astype(cdt),
                               pa["wk"].astype(cdt))
                v = jnp.einsum("btd,dhk->bthk", enc_out.astype(cdt),
                               pa["wv"].astype(cdt))
                cblk = dict(cblk)
                ci = dict(cblk[f"pos{i}"])
                ci["cross"] = {"k": k, "v": v}
                cblk[f"pos{i}"] = ci
        return cblk

    return jax.vmap(per_block, in_axes=(0, 0))(params["blocks"], caches)


def prefill(params: Params, cfg: ModelConfig, batch: Params, max_len: int):
    """Run the prompt through the stack, returning (last_logits, cache).

    ``max_len`` is the total KV-cache capacity of the *embedded* sequence —
    for VLM configs it must include ``cfg.patch_positions`` prefix slots.
    """
    x, positions, offset = _embed_inputs(params, cfg, batch)
    b, t = x.shape[:2]
    caches = init_cache(cfg, b, max_len)
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = _encode(params, cfg, batch["frames"])
        caches = _fill_cross_caches(params, cfg, caches, enc_out)
    x, caches, _ = _scan_stack(params["blocks"], cfg.block, x, cfg,
                               positions=positions, causal=True,
                               enc_out=enc_out, caches=caches,
                               cache_pos=jnp.zeros((), jnp.int32))
    x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg)
    logits = _logits(params, cfg, x)
    return logits[:, 0], {"blocks": caches, "pos": jnp.array(t, jnp.int32)}


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray):
    """One token step: tokens [B, 1] -> (logits [B, vocab], new cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = params["embed"][tokens].astype(cdt)
    positions = pos + jnp.arange(tokens.shape[1])
    x, caches, _ = _scan_stack(params["blocks"], cfg.block, x, cfg,
                               positions=positions, causal=True,
                               caches=cache["blocks"], cache_pos=pos)
    x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg)
    logits = _logits(params, cfg, x)
    return logits[:, 0], {"blocks": caches, "pos": pos + tokens.shape[1]}
