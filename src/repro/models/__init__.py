from .config import LayerSpec, MambaConfig, ModelConfig, MoEConfig, RWKVConfig  # noqa: F401
from . import layers, moe, ssm, transformer  # noqa: F401
