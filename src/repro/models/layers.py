"""Shared neural layers: norms, RoPE, GQA attention (blockwise + decode), MLP.

Everything is functional: ``init_*`` builds param pytrees, ``apply``-style
functions consume them. Sharding is by constraint propagation from the param
PartitionSpecs (sharding/rules.py); activations get explicit constraints only
at block boundaries (train/step.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), _dtype(cfg))}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((d,), _dtype(cfg))
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_vec(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window / cross-attention)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq, hd), dt),
        "wk": dense_init(ks[1], (d, nkv, hd), dt),
        "wv": dense_init(ks[2], (d, nkv, hd), dt),
        "wo": dense_init(ks[3], (nq, hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


_PAD_POS = jnp.iinfo(jnp.int32).max


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None, dtype):
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = (kp != _PAD_POS) & (kp >= 0)  # padded / unwritten cache slots
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, jnp.finfo(dtype).min).astype(dtype)


def _sdpa(q, k, v, bias):
    """q [B,Tq,Hq,hd], k/v [B,Tk,Hkv,hd] (GQA broadcast), bias [Tq,Tk]."""
    b, tq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = logits + bias.astype(jnp.float32)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, tq, hq, hd)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window, block_kv: int,
                    unroll: bool = False):
    """Online-softmax over KV blocks; activation memory O(Tq·block_kv)."""
    b, tq, hq, hd = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    nb = -(-tk // block_kv)
    pad = nb * block_kv - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(b, nb, block_kv, hkv, hd).swapaxes(0, 1)
    vb = v.reshape(b, nb, block_kv, hkv, hd).swapaxes(0, 1)
    pb = k_pos.reshape(nb, block_kv)
    qg = (q.reshape(b, tq, hkv, g, hd) / jnp.sqrt(hd).astype(q.dtype))

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk = blk
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk).astype(jnp.float32)
        bias = _mask_bias(q_pos, pblk, causal, window, jnp.float32)
        logits = logits + bias
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, hd), jnp.float32)
    if unroll:  # dry-run cost pass: count every block (see ModelConfig)
        carry = (m0, l0, a0)
        for i in range(nb):
            carry, _ = step(carry, (kb[i], vb[i], pb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.astype(q.dtype).transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, hd)
    return out


def _attend(q, k, v, q_pos, k_pos, causal, window, block_kv,
            unroll: bool = False):
    """Dispatch direct vs. blockwise (online-softmax) attention."""
    if k.shape[1] > block_kv:
        return _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window,
                               block_kv, unroll)
    bias = _mask_bias(q_pos, k_pos, causal, window, jnp.float32)
    return _sdpa(q, k, v, bias)


def attention(
    p: Params,
    x: jnp.ndarray,  # [B, T, d]
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray | None = None,  # [T] absolute positions
    causal: bool = True,
    cross: bool = False,
    kv_x: jnp.ndarray | None = None,  # cross-attention source [B, Tk, d]
    cache: Params | None = None,
    cache_pos: jnp.ndarray | None = None,  # scalar write position
) -> tuple[jnp.ndarray, Params | None]:
    """GQA attention. Modes:

      train:    cache=None              -> attend over x (blockwise if long)
      prefill:  cache given, T > 1      -> attend over x AND populate cache
      decode:   cache given, T == 1     -> write slot, attend over cache
      cross:    cross=True              -> attend over kv_x or prefilled cache

    Self-attention caches are ring buffers when ``cfg.swa_window`` is set
    (slots == window), else linear buffers of max_len slots.
    """
    b, t, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    if positions is None:
        positions = jnp.arange(t)
    q = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["wq"].astype(cdt))
    if cfg.qk_norm:
        q = rms_norm_vec(q, p["q_norm"])

    if cross:
        if cache is not None and "k" in cache:
            k, v = cache["k"].astype(cdt), cache["v"].astype(cdt)
        else:
            src = kv_x.astype(cdt)
            k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(cdt))
            v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(cdt))
            if cfg.qk_norm:
                k = rms_norm_vec(k, p["k_norm"])
        k_pos = jnp.arange(k.shape[1])
        out = _attend(q, k, v, positions, k_pos, False, None,
                      cfg.attn_block_kv, cfg.attn_unroll_blocks)
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(cdt))
        return y.astype(x.dtype), cache

    k = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["wk"].astype(cdt))
    v = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["wv"].astype(cdt))
    if cfg.qk_norm:
        k = rms_norm_vec(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.swa_window

    if cache is None:  # train
        if cfg.use_flash_kernel:
            from repro.kernels.flash_attn import flash_attention
            out = flash_attention(q, k, v, positions.astype(jnp.int32),
                                  positions.astype(jnp.int32),
                                  causal=causal, window=window)
        else:
            out = _attend(q, k, v, positions, positions, causal, window,
                          cfg.attn_block_kv, cfg.attn_unroll_blocks)
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(cdt))
        return y.astype(x.dtype), None

    slots = cache["k"].shape[1]
    kd = cache["k"].dtype
    if t == 1:  # decode step
        slot = (cache_pos % slots) if window is not None else cache_pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(kd),
                                                 slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(kd),
                                                 slot, axis=1)
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(cache["pos"].dtype), slot, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        out = _attend(q, ck.astype(cdt), cv.astype(cdt), positions, cp,
                      True, window, cfg.attn_block_kv,
                      cfg.attn_unroll_blocks)
    else:  # prefill: attend over the prompt itself, then fill the cache
        out = _attend(q, k, v, positions, positions, causal, window,
                      cfg.attn_block_kv, cfg.attn_unroll_blocks)
        if t <= slots:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(kd), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(kd), 0, axis=1)
            cp = cache["pos"].at[:t].set(positions.astype(cache["pos"].dtype))
        else:  # ring buffer (SWA): keep the last `slots`, ring-aligned
            shift = t % slots
            ck = jnp.roll(k[:, -slots:].astype(kd), shift, axis=1)
            cv = jnp.roll(v[:, -slots:].astype(kd), shift, axis=1)
            cp = jnp.roll(positions[-slots:].astype(cache["pos"].dtype),
                          shift, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cp}
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(cdt))
    return y.astype(x.dtype), new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype) -> Params:
    slots = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dt),
        "w_up": dense_init(ks[1], (d, ff), dt),
        "w_down": dense_init(ks[2], (ff, d), dt),
    }


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    g = jnp.einsum("btd,df->btf", xc, p["w_gate"].astype(cdt))
    u = jnp.einsum("btd,df->btf", xc, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("btf,fd->btd", h, p["w_down"].astype(cdt)).astype(x.dtype)
