"""`--report unused`: the repro import graph and dead-module report.

Builds a static import graph over every module under ``src/repro`` and
classifies each module by how it is reached:

  * **facade** — reachable from the public facade ``repro.figaro`` (what
    ``import repro.figaro`` actually pulls in, statically);
  * **entrypoint** — not behind the facade but named by an entry-point root
    (``repro.analysis``, ``repro.launch`` CLIs) or reachable from one;
  * **external-only** — unreachable from any root, but textually referenced
    by tests/examples/benchmarks: quarantined seed scaffolding that only the
    harness keeps alive;
  * **orphan** — unreachable AND unreferenced: dead code, safe to delete.

Resolution handles the three import forms the tree uses — absolute
(``import repro.core.engine``), from-imports of modules or symbols
(``from repro.core import engine`` / ``from .engine import FigaroEngine``),
and the dynamic registry idiom
``importlib.import_module(f"repro.configs.{name}")``, which is modeled as an
edge to *every* module under the f-string's literal prefix (the registry can
name any of them at runtime).

External references are textual on purpose: tests invoke modules via
``subprocess -m repro.launch.dryrun`` and importlib strings, which no import
statement ever mentions. A regex over ``repro.dotted.names`` in the external
trees catches those.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Iterator

#: Graph roots: the public facade first, then the executable entry points
#: that users/CI invoke directly with `python -m` (which runs __main__).
DEFAULT_ROOTS = ("repro.figaro", "repro.analysis.__main__",
                 "repro.launch.dryrun")

_EXTERNAL_REF_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z_0-9]*)+")


def _module_name(py_path: str, src_root: str) -> str | None:
    rel = os.path.relpath(py_path, src_root)
    if rel.startswith(".."):
        return None
    parts = rel.replace(os.sep, "/").split("/")
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][:-3]  # strip .py
    return ".".join(parts) if parts else None


def _walk_py(root: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for f in sorted(filenames):
            if f.endswith(".py"):
                yield os.path.join(dirpath, f)


def _fstring_prefix(node: ast.JoinedStr) -> str | None:
    """Literal prefix of an f-string up to the first interpolation."""
    if not node.values:
        return None
    first = node.values[0]
    if isinstance(first, ast.Constant) and isinstance(first.value, str):
        return first.value
    return None


@dataclasses.dataclass
class ImportGraph:
    """Static module-level import graph for one package tree."""

    src_root: str                       # e.g. "src"
    modules: dict[str, str]             # module name -> file path
    edges: dict[str, set[str]]          # module -> imported modules (in-tree)
    packages: set[str]                  # names that are packages (dirs)

    @classmethod
    def build(cls, src_root: str, package: str = "repro") -> "ImportGraph":
        pkg_dir = os.path.join(src_root, package)
        modules: dict[str, str] = {}
        packages: set[str] = {package}
        for path in _walk_py(pkg_dir):
            name = _module_name(path, src_root)
            if name is None:
                continue
            modules[name] = path
            if path.endswith("__init__.py"):
                packages.add(name)
        graph = cls(src_root=src_root, modules=modules, edges={},
                    packages=packages)
        for name, path in modules.items():
            graph.edges[name] = graph._module_edges(name, path)
        return graph

    # -- edge extraction -----------------------------------------------------

    def _module_edges(self, name: str, path: str) -> set[str]:
        try:
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
        except (OSError, SyntaxError):
            return set()
        out: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out |= self._resolve_target(a.name)
            elif isinstance(node, ast.ImportFrom):
                base = self._from_base(name, node)
                if base is None:
                    continue
                out |= self._resolve_target(base)
                for a in node.names:
                    if a.name != "*":
                        # `from pkg import sub` may name a submodule.
                        out |= self._resolve_target(f"{base}.{a.name}")
            elif isinstance(node, ast.Call):
                out |= self._dynamic_edges(node)
        out.discard(name)
        return out

    def _from_base(self, name: str, node: ast.ImportFrom) -> str | None:
        if not node.level:
            return node.module
        # Relative import: climb from the importer's package.
        base_parts = name.split(".")
        if name not in self.packages:
            base_parts = base_parts[:-1]  # module -> containing package
        climb = node.level - 1
        if climb > len(base_parts):
            return None
        base_parts = base_parts[:len(base_parts) - climb]
        if node.module:
            base_parts = base_parts + node.module.split(".")
        return ".".join(base_parts) if base_parts else None

    def _resolve_target(self, dotted: str) -> set[str]:
        """In-tree modules a dotted import target refers to. Importing a
        package also executes its __init__, so parent packages join too."""
        out: set[str] = set()
        parts = dotted.split(".")
        for i in range(1, len(parts) + 1):
            prefix = ".".join(parts[:i])
            if prefix in self.modules:
                out.add(prefix)
        return out

    def _dynamic_edges(self, node: ast.Call) -> set[str]:
        """`importlib.import_module(f"repro.configs.{...}")` → edges to every
        module under the literal prefix."""
        fn = node.func
        callee = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else "")
        if callee != "import_module" or not node.args:
            return set()
        arg = node.args[0]
        prefix: str | None = None
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return self._resolve_target(arg.value)
        if isinstance(arg, ast.JoinedStr):
            prefix = _fstring_prefix(arg)
        if not prefix or not prefix.startswith("repro"):
            return set()
        prefix = prefix.rstrip(".")
        return {m for m in self.modules
                if m == prefix or m.startswith(prefix + ".")}

    # -- reachability --------------------------------------------------------

    def reachable_from(self, roots: Iterable[str]) -> set[str]:
        seen: set[str] = set()
        stack = [r for r in roots if r in self.modules]
        while stack:
            mod = stack.pop()
            if mod in seen:
                continue
            seen.add(mod)
            # Importing repro.a.b first imports packages repro and repro.a.
            parts = mod.split(".")
            for i in range(1, len(parts)):
                parent = ".".join(parts[:i])
                if parent in self.modules and parent not in seen:
                    stack.append(parent)
            stack.extend(self.edges.get(mod, ()) - seen)
        return seen


def _import_refs(text: str, path: str) -> set[str]:
    """Dotted repro names an external file's *import statements* mention —
    catches `from repro.kernels.flash_attn import ref`, where the submodule
    name never appears as a dotted string the regex could see."""
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError:
        return set()
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out |= {a.name for a in node.names}
        elif isinstance(node, ast.ImportFrom) and node.module \
                and not node.level:
            out.add(node.module)
            out |= {f"{node.module}.{a.name}" for a in node.names
                    if a.name != "*"}
    return {n for n in out if n == "repro" or n.startswith("repro.")}


def _external_refs(external_dirs: Iterable[str],
                   modules: Iterable[str]) -> dict[str, list[str]]:
    """module -> files outside src/ that mention it. Two detectors: import
    statements (AST), and a dotted-name regex over the raw text (catches
    importlib strings and `subprocess ... -m repro.launch.dryrun`
    invocations that no import statement names)."""
    names = set(modules)
    hits: dict[str, set[str]] = {m: set() for m in names}
    for d in external_dirs:
        if not os.path.isdir(d):
            continue
        for path in _walk_py(d):
            try:
                with open(path, encoding="utf-8") as fh:
                    text = fh.read()
            except (OSError, UnicodeDecodeError):
                continue
            found = set(_EXTERNAL_REF_RE.findall(text))
            found |= _import_refs(text, path)
            for ref in found:
                # "repro.core.engine" also vouches for packages repro.core.
                parts = ref.split(".")
                for i in range(2, len(parts) + 1):
                    cand = ".".join(parts[:i])
                    if cand in names:
                        hits[cand].add(path)
    return {m: sorted(files) for m, files in hits.items() if files}


def unused_report(src_root: str = "src",
                  external_dirs: Iterable[str] = ("tests", "examples",
                                                  "benchmarks"),
                  roots: Iterable[str] = DEFAULT_ROOTS) -> dict:
    """Classify every repro module: facade / entrypoint / external-only /
    orphan. Returns a JSON-ready dict; the CLI renders it."""
    graph = ImportGraph.build(src_root)
    roots = list(roots)
    facade = graph.reachable_from(roots[:1])
    all_reachable = graph.reachable_from(roots)
    ext = _external_refs(external_dirs, graph.modules)

    classes: dict[str, dict] = {}
    for mod in sorted(graph.modules):
        if mod in facade:
            cls = "facade"
        elif mod in all_reachable:
            cls = "entrypoint"
        elif mod in ext:
            cls = "external-only"
        else:
            cls = "orphan"
        classes[mod] = {"class": cls, "path": graph.modules[mod]}
        if cls == "external-only":
            classes[mod]["referenced_by"] = ext[mod]

    counts: dict[str, int] = {}
    for info in classes.values():
        counts[info["class"]] = counts.get(info["class"], 0) + 1
    return {
        "roots": roots,
        "counts": counts,
        "modules": classes,
        "orphans": [m for m, i in classes.items() if i["class"] == "orphan"],
    }
