"""Rule framework for figaro-lint: findings, suppressions, the file driver.

A rule is a small class with a stable id (``FIG001``...), a default severity,
and a ``check(ctx)`` generator over `Finding`s for one parsed file. The driver
(`analyze_paths`) parses each file once, hands every rule the same
`FileContext` (AST + source + resolved import aliases), and filters the
yielded findings through the file's suppression comments:

    expr  # figaro-lint: disable=FIG002 -- reason
    # figaro-lint: disable-file=FIG003 -- reason

Line suppressions match findings anchored on that physical line; file
suppressions match the whole module. Suppressions should carry a
``--``-separated reason for review, but the analyzer only needs the rule
list.

Everything here is stdlib-only on purpose: the CI analysis job runs the
analyzer without installing jax.
"""

from __future__ import annotations

import ast
import dataclasses
import enum
import os
import re
import tokenize
from typing import Iterable, Iterator


class Severity(enum.IntEnum):
    """Ordered so ``max()`` over findings is the run's worst severity."""

    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # "error" in human output, not "Severity.ERROR"
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str         # "FIG001"
    severity: Severity
    path: str         # repo-relative, posix separators
    line: int         # 1-based
    message: str
    fix_hint: str = ""
    #: For interprocedural findings: the short-name call chain from a traced
    #: root (engine impl / jit arg / shard_map body) to the finding site.
    traced_context: tuple[str, ...] = ()

    def fingerprint(self) -> tuple[str, str, str]:
        """Baseline identity: line numbers drift with unrelated edits, so the
        baseline matches on (rule, path, message) instead."""
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": str(self.severity),
                "path": self.path, "line": self.line,
                "message": self.message, "fix_hint": self.fix_hint,
                "traced_context": list(self.traced_context)}

    def render(self) -> str:
        """Human-readable form, fix hint included on its own indented line —
        the hint must reach terminal users, not just the `--json` payload."""
        head = (f"{self.path}:{self.line}: {self.rule} {self.severity}: "
                f"{self.message}")
        if not self.fix_hint:
            return head
        return f"{head}\n    fix: {self.fix_hint}"


_SUPPRESS_RE = re.compile(
    r"#\s*figaro-lint:\s*(disable|disable-file)\s*=\s*"
    r"(?P<rules>[A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)")


@dataclasses.dataclass
class Suppressions:
    by_line: dict[int, set[str]]  # physical line -> suppressed rule ids
    file_wide: set[str]

    def covers(self, finding: Finding) -> bool:
        if finding.rule in self.file_wide:
            return True
        return finding.rule in self.by_line.get(finding.line, ())


def _parse_suppressions(source: str) -> Suppressions:
    """Comment scan via tokenize, so a suppression-looking *string literal*
    in fixture code never suppresses anything."""
    by_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    lines = source.splitlines(keepends=True)
    try:
        tokens = tokenize.generate_tokens(iter(lines).__next__)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")}
            if m.group(1) == "disable-file":
                file_wide |= rules
            else:
                by_line.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenizeError:
        pass  # unparsable files already surface as FIG000
    return Suppressions(by_line, file_wide)


class FileContext:
    """Everything a rule sees for one file: AST, source, import aliases."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path          # repo-relative posix path
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        #: local alias -> dotted module/symbol it names, e.g.
        #: {"jnp": "jax.numpy", "P": "jax.sharding.PartitionSpec"}
        self.aliases = _collect_aliases(tree)

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted name of a Name/Attribute chain with the leading alias
        expanded: ``jnp.float32`` -> "jax.numpy.float32". None for anything
        that is not a plain dotted chain."""
        parts = _dotted_parts(node)
        if parts is None:
            return None
        head = self.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])


def _dotted_parts(node: ast.AST) -> list[str] | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return parts[::-1]


def _collect_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


class Rule:
    """Base class: subclasses set the id/severity/hint and implement check.

    Interprocedural rules additionally implement ``check_program``, which the
    driver calls once per run with the whole-program `Program` (call graph +
    dataflow over every analyzed file). During a run every rule also sees the
    program on ``self.program`` — per-file rules can use it for call-graph
    queries (FIG006's cross-file exemption) while staying file-anchored.
    """

    rule_id: str = "FIG000"
    severity: Severity = Severity.ERROR
    fix_hint: str = ""
    #: Whole-program view, set by the driver for the duration of a run.
    program = None

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError

    def check_program(self, program) -> Iterator[Finding]:
        """Whole-program pass; called once per run, after the per-file
        passes. Default: no interprocedural findings."""
        return iter(())

    def finding(self, ctx: FileContext, node: ast.AST | int, message: str,
                *, severity: Severity | None = None,
                fix_hint: str | None = None,
                traced_context: tuple[str, ...] = ()) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        return Finding(rule=self.rule_id,
                       severity=self.severity if severity is None else severity,
                       path=ctx.path, line=line, message=message,
                       fix_hint=self.fix_hint if fix_hint is None else fix_hint,
                       traced_context=tuple(traced_context))


def _iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__", ".git"))
                for f in sorted(filenames):
                    if f.endswith(".py"):
                        yield os.path.join(dirpath, f)


def _relpath(path: str, root: str | None) -> str:
    rel = os.path.relpath(path, root) if root else path
    if rel.startswith(".." + os.sep):  # outside the root: keep it absolute
        rel = os.path.abspath(path)
    return rel.replace(os.sep, "/")


def _syntax_error_finding(path: str, e: SyntaxError) -> Finding:
    return Finding(
        rule="FIG000", severity=Severity.ERROR, path=path,
        line=e.lineno or 1,
        message=(f"syntax error: {e.msg} — figaro-lint cannot analyze "
                 f"this file (suppressions use `# figaro-lint: "
                 f"disable=FIGxxx -- reason` once it parses)"),
        fix_hint=("fix the parse error first; FIG000 itself cannot be "
                  "suppressed because suppression comments are read "
                  "from the parsed file"))


def _run_rules(items: list[tuple[FileContext, Suppressions]],
               rules: list[Rule]) -> list[Finding]:
    """Shared driver: per-file passes over every context, then one
    whole-program pass per rule — all against a single `Program` built from
    the full context set, so `analyze_source` (one-file program) and
    `analyze_paths` (whole-tree program) share semantics."""
    from .callgraph import Program  # deferred: callgraph imports framework

    program = Program([ctx for ctx, _ in items])
    sups = {ctx.path: sup for ctx, sup in items}
    out: list[Finding] = []
    seen: set[tuple[str, str, int, str]] = set()

    def add(finding: Finding) -> None:
        # Dedupe: rules that walk nested scopes can surface one defect
        # from two enclosing scopes.
        key = (finding.rule, finding.path, finding.line, finding.message)
        if key in seen:
            return
        sup = sups.get(finding.path)
        if sup is not None and sup.covers(finding):
            return
        seen.add(key)
        out.append(finding)

    try:
        for rule in rules:
            rule.program = program
        for rule in rules:
            for ctx, _ in items:
                for finding in rule.check(ctx):
                    add(finding)
            for finding in rule.check_program(program):
                add(finding)
    finally:
        for rule in rules:
            rule.program = None
    return out


def analyze_source(source: str, path: str,
                   rules: Iterable[Rule]) -> list[Finding]:
    """Analyze one in-memory module (the fixture-test entry point). The
    module becomes a single-file `Program`, so interprocedural rules run on
    fixtures too — with the call graph restricted to what the file defines."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [_syntax_error_finding(path, e)]
    ctx = FileContext(path, source, tree)
    sup = _parse_suppressions(source)
    return _run_rules([(ctx, sup)], list(rules))


def analyze_paths(paths: Iterable[str], *, rules: Iterable[Rule] | None = None,
                  root: str | None = None) -> list[Finding]:
    """Run every rule over every ``.py`` file under ``paths``.

    ``root`` (default cwd) anchors the repo-relative paths findings carry —
    the baseline and suppression story depends on paths being stable across
    checkouts.
    """
    if rules is None:
        from .rules import all_rules
        rules = all_rules()
    rules = list(rules)
    root = os.getcwd() if root is None else root
    findings: list[Finding] = []
    items: list[tuple[FileContext, Suppressions]] = []
    for fpath in _iter_py_files(paths):
        rel = _relpath(fpath, root)
        try:
            with open(fpath, encoding="utf-8") as fh:
                source = fh.read()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="FIG000", severity=Severity.ERROR,
                path=rel, line=1,
                message=f"unreadable file: {e}",
                fix_hint="fix the file's encoding/permissions or remove it "
                         "from the analyzed paths"))
            continue
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            findings.append(_syntax_error_finding(rel, e))
            continue
        items.append((FileContext(rel, source, tree),
                      _parse_suppressions(source)))
    findings.extend(_run_rules(items, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def load_program(paths: Iterable[str], *, root: str | None = None):
    """Build the whole-program view (`callgraph.Program`) for ``paths``
    without running any rules — the `--report callgraph` entry point.
    Unreadable/unparsable files are skipped (they surface as FIG000 in the
    lint run, not here)."""
    from .callgraph import Program

    root = os.getcwd() if root is None else root
    contexts: list[FileContext] = []
    for fpath in _iter_py_files(paths):
        rel = _relpath(fpath, root)
        try:
            with open(fpath, encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=rel)
        except (OSError, UnicodeDecodeError, SyntaxError):
            continue
        contexts.append(FileContext(rel, source, tree))
    return Program(contexts)
