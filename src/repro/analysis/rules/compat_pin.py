"""FIG001 — version-sensitive JAX symbols must come from repro/compat.py.

The container pins a JAX whose spelling of ``shard_map`` / ``make_mesh`` /
``AxisType`` / ``AbstractMesh`` / ``axis_size`` differs from the current
surface; `repro.compat` is the one module allowed to touch the raw spellings
and it normalizes all of them. A direct import anywhere else works on exactly
one JAX version and silently breaks the pin contract.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: `from <module> import <name>` spellings that bypass the shim. ``None``
#: means every name in that module is version-sensitive.
_SENSITIVE_FROM: dict[str, frozenset | None] = {
    "jax.experimental.shard_map": None,
    "jax.sharding": frozenset({"AxisType", "AbstractMesh"}),
}

#: fully-resolved dotted uses that bypass the shim.
_SENSITIVE_DOTTED = frozenset({
    "jax.shard_map",
    "jax.make_mesh",
    "jax.lax.axis_size",
    "jax.sharding.AxisType",
    "jax.sharding.AbstractMesh",
    "jax.experimental.shard_map.shard_map",
})

_EXEMPT_SUFFIX = "repro/compat.py"


class CompatPinRule(Rule):
    rule_id = "FIG001"
    severity = Severity.ERROR
    fix_hint = ("import the symbol from repro.compat — the version shim is "
                "the only module allowed to spell raw JAX names")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.path.endswith(_EXEMPT_SUFFIX):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                allowed = _SENSITIVE_FROM.get(node.module, frozenset())
                names = {a.name for a in node.names}
                bad = names if allowed is None else names & allowed
                for name in sorted(bad):
                    yield self.finding(
                        ctx, node,
                        f"version-sensitive JAX import "
                        f"`from {node.module} import {name}` outside "
                        f"repro/compat.py")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name in _SENSITIVE_FROM and a.name != "jax.sharding":
                        yield self.finding(
                            ctx, node,
                            f"version-sensitive JAX import "
                            f"`import {a.name}` outside repro/compat.py")
            elif isinstance(node, ast.Attribute):
                dotted = ctx.resolve(node)
                if dotted in _SENSITIVE_DOTTED:
                    yield self.finding(
                        ctx, node,
                        f"version-sensitive JAX symbol `{dotted}` used "
                        f"outside repro/compat.py")
