"""FIG004 — Pallas kernel-site invariants: interpret routing, grid safety,
VMEM-budgeted autotune tables.

Three ways a kernel site rots that nothing catches until a TPU run:

  * ``interpret=`` policy: this container validates every kernel in
    interpret mode on CPU and compiles on TPU/GPU; the decision lives in
    `kernels/_platform.resolve_interpret` and NOWHERE else. A `pallas_call`
    without an ``interpret=`` kwarg (silently always-compiled), with a
    hardcoded True/False, or an ops-layer wrapper forwarding its unresolved
    ``interpret=None`` parameter straight through all bypass the policy.
  * grid truncation: a grid entry ``m // bm`` over a dim that was not first
    padded to a multiple of ``bm`` silently drops the ragged tail rows.
    Grids must floor-divide a ceil-padded capacity (``mp = -(-m // bm) * bm``)
    or use ``pl.cdiv`` with in-kernel masking. Both the padding and the grid
    may be one module-level call away: ``mp = _pad(m, bm)`` where ``_pad``'s
    body is the ceil-mult, and ``grid=_grid(mp, np_, bm, bn)`` where
    ``_grid`` returns a tuple — the rule follows one call level of each.
  * autotune drift: `node_fused.AUTOTUNE` block sizes are analytic; each
    entry's live tile set (4 [bm, bn] tiles: data in, two outs, plus
    coefficient/carry slack) must fit the per-backend budget model. Keys are
    ``(backend, itemsize, bound)`` (legacy ``(itemsize, bound)`` means tpu).
    TPU rows must be sublane-aligned (8) / lane-aligned (128); GPU rows must
    be power-of-two (warp-tiling). Every (backend, itemsize) group must end
    with a ``None`` catch-all bound, and a catch-all for a narrow itemsize
    must still fit the budget at f64 itemsize — a missing-dtype lookup falls
    through to it.
"""

from __future__ import annotations

import ast
import copy
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: Live working set the budget models: 4 resident [bm, bn] tiles (input,
#: two outputs, double-buffering slack). Conservative on purpose.
_LIVE_TILES = 4

#: Per-backend memory the live tile set may claim. TPU cores have ~16 MiB of
#: VMEM; the table leaves most of it to Mosaic's own pipelining. The GPU
#: model is Triton shared-memory/register tiles: 256 KiB keeps the live set
#: within an SM's shared memory across generations.
VMEM_BUDGET_BYTES = {"tpu": 2 * 1024 * 1024, "gpu": 256 * 1024}


def _call_name(ctx: FileContext, node: ast.Call) -> str:
    dotted = ctx.resolve(node.func)
    return dotted.rsplit(".", 1)[-1] if dotted else ""


def _keyword(node: ast.Call, name: str) -> ast.keyword | None:
    for kw in node.keywords:
        if kw.arg == name:
            return kw
    return None


def _is_ceil_div(node: ast.AST) -> tuple[bool, str | None]:
    """Matches ``-(-x // b)``; returns (True, divisor-name-if-Name)."""
    if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.BinOp)
            and isinstance(node.operand.op, ast.FloorDiv)
            and isinstance(node.operand.left, ast.UnaryOp)
            and isinstance(node.operand.left.op, ast.USub)):
        div = node.operand.right
        return True, div.id if isinstance(div, ast.Name) else None
    return False, None


def _is_ceil_mult(node: ast.AST) -> str | None:
    """Matches ``-(-x // b) * b`` (a dim padded UP to a multiple of b);
    returns the divisor name, or None."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        for ceil, other in ((node.left, node.right), (node.right, node.left)):
            ok, div = _is_ceil_div(ceil)
            if ok and div is not None and isinstance(other, ast.Name) \
                    and other.id == div:
                return div
    return None


def _padded_names(fn: ast.AST) -> dict[str, str]:
    """{var: divisor} for locals assigned a ceil-padded multiple."""
    out: dict[str, str] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            div = _is_ceil_mult(node.value)
            if div is not None:
                out[node.targets[0].id] = div
    return out


def _local_tuples(fn: ast.AST) -> dict[str, ast.AST]:
    """{var: tuple-literal} for locals like ``grid = (m // bm, n // bn)``."""
    out: dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            out[node.targets[0].id] = node.value
    return out


def _fn_body(fn: ast.AST) -> list[ast.stmt]:
    """Function body with a leading docstring stripped."""
    body = list(fn.body)
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        body = body[1:]
    return body


def _module_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def _pad_helper_divisors(tree: ast.Module) -> dict[str, int]:
    """{helper: index of its divisor param} for single-expression module
    helpers of the shape ``def f(x, b): return -(-x // b) * b`` — calling
    one proves the result padded to a multiple of the divisor argument."""
    out: dict[str, int] = {}
    for name, fn in _module_functions(tree).items():
        body = _fn_body(fn)
        if len(body) != 1 or not isinstance(body[0], ast.Return) \
                or body[0].value is None:
            continue
        div = _is_ceil_mult(body[0].value)
        if div is None:
            continue
        params = [a.arg for a in fn.args.args]
        if div in params:
            out[name] = params.index(div)
    return out


def _grid_helper_tuple(tree: ast.Module, name: str) -> ast.AST | None:
    """Return-tuple of a single-statement module helper ``def g(...):
    return (a // b, ...)``, or None."""
    fn = _module_functions(tree).get(name)
    if fn is None:
        return None
    body = _fn_body(fn)
    if len(body) == 1 and isinstance(body[0], ast.Return) \
            and isinstance(body[0].value, (ast.Tuple, ast.List)):
        return body[0].value
    return None


class _SubstituteNames(ast.NodeTransformer):
    """Rewrite helper params to the caller's argument names."""

    def __init__(self, mapping: dict[str, ast.expr]) -> None:
        self.mapping = mapping

    def visit_Name(self, node: ast.Name):  # noqa: N802 (ast API)
        rep = self.mapping.get(node.id)
        return copy.deepcopy(rep) if rep is not None else node


class PallasKernelRule(Rule):
    rule_id = "FIG004"
    severity = Severity.ERROR
    fix_hint = ("route interpret= through kernels/_platform.resolve_interpret "
                "and pad dims to block multiples before grid division")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, fn)
        yield from self._check_autotune(ctx)

    # -- per-function checks -------------------------------------------------

    def _check_function(self, ctx, fn) -> Iterator[Finding]:
        padded = _padded_names(fn)
        padded.update(self._helper_padded(ctx, fn))
        tuples = _local_tuples(fn)
        interpret_default = self._interpret_default(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _call_name(ctx, node) == "pallas_call":
                yield from self._check_pallas_call(ctx, node, padded, tuples)
            if interpret_default == "none":
                yield from self._check_forwarding(ctx, fn, node)

    @staticmethod
    def _helper_padded(ctx, fn) -> dict[str, str]:
        """{var: divisor} for locals padded via a module ceil-mult helper:
        ``mp = _pad_to(m, bm)`` proves mp a multiple of bm."""
        helpers = _pad_helper_divisors(ctx.tree)
        out: dict[str, str] = {}
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                continue
            idx = helpers.get(_call_name(ctx, node.value))
            if idx is None:
                continue
            args = node.value.args
            if len(args) > idx and isinstance(args[idx], ast.Name):
                out[node.targets[0].id] = args[idx].id
        return out

    @staticmethod
    def _interpret_default(fn) -> str | None:
        a = fn.args
        for params, defaults in ((a.kwonlyargs, a.kw_defaults),
                                 (a.args, [None] * (len(a.args)
                                                    - len(a.defaults))
                                  + list(a.defaults))):
            for p, d in zip(params, defaults):
                if p.arg == "interpret" and isinstance(d, ast.Constant):
                    return "none" if d.value is None else "bool"
        return None

    def _check_forwarding(self, ctx, fn, call: ast.Call) -> Iterator[Finding]:
        """In a wrapper whose ``interpret`` defaults to None, forwarding the
        raw parameter skips the platform resolution."""
        callee = _call_name(ctx, call)
        if callee == "resolve_interpret":
            return
        kw = _keyword(call, "interpret")
        if kw is not None and isinstance(kw.value, ast.Name) \
                and kw.value.id == "interpret":
            yield self.finding(
                ctx, call,
                f"`{fn.name}` forwards its unresolved interpret=None "
                f"parameter to `{callee or '<call>'}` — wrap it in "
                f"kernels/_platform.resolve_interpret(interpret)")

    def _check_pallas_call(self, ctx, node: ast.Call,
                           padded: dict[str, str],
                           tuples: dict[str, ast.AST]) -> Iterator[Finding]:
        kw = _keyword(node, "interpret")
        if kw is None:
            yield self.finding(
                ctx, node,
                "pallas_call without interpret= — the platform policy "
                "(compiled on TPU/GPU, interpreted on CPU) is silently "
                "bypassed")
        elif isinstance(kw.value, ast.Constant) and isinstance(kw.value.value,
                                                               bool):
            yield self.finding(
                ctx, kw.value,
                f"pallas_call with hardcoded interpret={kw.value.value} — "
                f"the decision belongs to kernels/_platform."
                f"resolve_interpret (tests override explicitly)")
        grid_kw = _keyword(node, "grid")
        if grid_kw is None:
            return
        grid = grid_kw.value
        if isinstance(grid, ast.Name):  # grid = (...) assigned earlier
            grid = tuples.get(grid.id, grid)
        if isinstance(grid, ast.Call):  # grid=_grid_for(mp, bm, ...)
            grid = self._resolve_grid_call(ctx, grid)
        if isinstance(grid, (ast.Tuple, ast.List)):
            for elt in grid.elts:
                yield from self._check_grid_elt(ctx, elt, padded)

    @staticmethod
    def _resolve_grid_call(ctx, call: ast.Call) -> ast.AST:
        """Inline a one-statement module grid helper: substitute its params
        with the caller's argument names so the caller's padded-proof
        applies, and re-anchor line numbers at the call site."""
        ret = _grid_helper_tuple(ctx.tree, _call_name(ctx, call))
        if ret is None:
            return call
        fn = _module_functions(ctx.tree)[_call_name(ctx, call)]
        params = [a.arg for a in fn.args.args]
        mapping: dict[str, ast.expr] = {}
        for p, a in zip(params, call.args):
            mapping[p] = a
        for kw in call.keywords:
            if kw.arg is not None and kw.arg in params:
                mapping[kw.arg] = kw.value
        inlined = _SubstituteNames(mapping).visit(copy.deepcopy(ret))
        for sub in ast.walk(inlined):
            if hasattr(sub, "lineno"):
                sub.lineno = call.lineno
        return inlined

    def _check_grid_elt(self, ctx, elt: ast.AST,
                        padded: dict[str, str]) -> Iterator[Finding]:
        """Flag ``X // b`` grid entries whose numerator is not ceil-padded
        to a multiple of the same divisor. cdiv/ceil-div entries and plain
        names (block counts computed elsewhere) pass."""
        if not (isinstance(elt, ast.BinOp)
                and isinstance(elt.op, ast.FloorDiv)):
            return
        ok, _ = _is_ceil_div(elt)  # a cdiv INSIDE the grid is fine
        if ok:
            return
        num, div = elt.left, elt.right
        div_name = div.id if isinstance(div, ast.Name) else None
        if isinstance(num, ast.Name) and div_name is not None \
                and padded.get(num.id) == div_name:
            return
        yield self.finding(
            ctx, elt,
            f"grid entry `{ast.unparse(elt)}` floor-divides a dim not "
            f"proven padded to a multiple of the divisor — ragged tail "
            f"blocks are silently dropped",
            fix_hint="pad the dim first (`mp = -(-m // bm) * bm`; grid "
                     "`mp // bm`) or use pl.cdiv with in-kernel masking")

    # -- AUTOTUNE table budget ----------------------------------------------

    def _check_autotune(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, (ast.Assign, ast.AnnAssign))):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if not any(isinstance(t, ast.Name) and t.id == "AUTOTUNE"
                       for t in targets):
                continue
            value = node.value
            if not isinstance(value, ast.Dict):
                continue
            yield from self._check_autotune_dict(ctx, value)

    def _check_autotune_dict(self, ctx, table: ast.Dict) -> Iterator[Finding]:
        last_bound: dict[tuple[str, int], object] = {}
        for key, val in zip(table.keys, table.values):
            entry = self._literal_entry(key, val)
            if entry is None:
                continue
            backend, explicit, itemsize, bound, bm, bn = entry
            where = (f"AUTOTUNE[({backend}, {itemsize}, {bound})]" if explicit
                     else f"AUTOTUNE[({itemsize}, {bound})]")
            budget = VMEM_BUDGET_BYTES.get(backend)
            if budget is None:
                yield self.finding(
                    ctx, key,
                    f"{where}: backend \"{backend}\" has no budget model — "
                    f"table rows must target tpu or gpu")
                continue
            last_bound[(backend, itemsize)] = bound
            if backend == "tpu":
                if bn % 128 != 0:
                    yield self.finding(
                        ctx, key,
                        f"{where}: block_cols={bn} is not lane-aligned "
                        f"(multiple of 128)")
                if bm % 8 != 0:
                    yield self.finding(
                        ctx, key,
                        f"{where}: block_rows={bm} is not sublane-aligned "
                        f"(multiple of 8)")
            else:  # gpu: Triton warp tiling wants power-of-two blocks
                for label, b in (("block_rows", bm), ("block_cols", bn)):
                    if b <= 0 or b & (b - 1):
                        yield self.finding(
                            ctx, key,
                            f"{where}: {label}={b} is not a power of two — "
                            f"gpu tiles must be pow2 for warp scheduling")
            live = _LIVE_TILES * bm * bn * itemsize
            if live > budget:
                yield self.finding(
                    ctx, key,
                    f"{where}: blocks ({bm}, {bn}) put {live // 1024} KiB "
                    f"live in VMEM — past the {budget // 1024} KiB {backend} "
                    f"budget model ({_LIVE_TILES} resident tiles)",
                    fix_hint="shrink block_rows/block_cols so "
                             f"{_LIVE_TILES}*bm*bn*itemsize fits the budget")
            elif bound is None and itemsize < 8 \
                    and _LIVE_TILES * bm * bn * 8 > budget:
                yield self.finding(
                    ctx, key,
                    f"{where}: catch-all blocks ({bm}, {bn}) exceed the "
                    f"{budget // 1024} KiB {backend} budget at f64 itemsize "
                    f"— a missing-dtype lookup falls through to this row",
                    fix_hint="size the None catch-all row so "
                             f"{_LIVE_TILES}*bm*bn*8 fits the budget")
        for (backend, itemsize), bound in sorted(last_bound.items()):
            if bound is not None:
                yield self.finding(
                    ctx, table,
                    f"AUTOTUNE {backend} itemsize {itemsize} does not end "
                    f"with a None (catch-all) width bound — wide nodes "
                    f"would fall through the table")

    @staticmethod
    def _literal_entry(key, val):
        """(backend, explicit, itemsize, bound, bm, bn) for a literal row.
        Keys are ``(backend, itemsize, bound)``; legacy two-element
        ``(itemsize, bound)`` keys mean tpu."""
        if not (isinstance(key, ast.Tuple) and len(key.elts) in (2, 3)
                and isinstance(val, ast.Tuple) and len(val.elts) == 2):
            return None
        kelts = [e.value if isinstance(e, ast.Constant) else None
                 for e in key.elts]
        if len(kelts) == 3:
            backend, itemsize, bound = kelts
            explicit = True
            if not isinstance(backend, str):
                return None
        else:
            (itemsize, bound), backend, explicit = kelts, "tpu", False
        bm, bn = [e.value if isinstance(e, ast.Constant) else None
                  for e in val.elts]
        if not isinstance(itemsize, int) or not isinstance(bm, int) \
                or not isinstance(bn, int):
            return None
        if bound is not None and not isinstance(bound, int):
            return None
        return backend, explicit, itemsize, bound, bm, bn
