"""FIG004 — Pallas kernel-site invariants: interpret routing, grid safety,
VMEM-budgeted autotune tables.

Three ways a kernel site rots that nothing catches until a TPU run:

  * ``interpret=`` policy: this container validates every kernel in
    interpret mode on CPU and compiles on TPU/GPU; the decision lives in
    `kernels/_platform.resolve_interpret` and NOWHERE else. A `pallas_call`
    without an ``interpret=`` kwarg (silently always-compiled), with a
    hardcoded True/False, or an ops-layer wrapper forwarding its unresolved
    ``interpret=None`` parameter straight through all bypass the policy.
  * grid truncation: a grid entry ``m // bm`` over a dim that was not first
    padded to a multiple of ``bm`` silently drops the ragged tail rows.
    Grids must floor-divide a ceil-padded capacity (``mp = -(-m // bm) * bm``)
    or use ``pl.cdiv`` with in-kernel masking.
  * autotune drift: `node_fused.AUTOTUNE` block sizes are analytic; each
    entry's live tile set (4 [bm, bn] tiles: data in, two outs, plus
    coefficient/carry slack) must fit the per-backend VMEM budget model, rows
    must be sublane-aligned (8) and columns lane-aligned (128), and every
    itemsize group must end with a ``None`` catch-all bound.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: Live working set the budget models: 4 resident [bm, bn] tiles (input,
#: two outputs, double-buffering slack). Conservative on purpose.
_LIVE_TILES = 4

#: Per-backend VMEM the live set may claim. TPU cores have ~16 MiB of VMEM;
#: the table leaves most of it to Mosaic's own pipelining.
VMEM_BUDGET_BYTES = {"tpu": 2 * 1024 * 1024}


def _call_name(ctx: FileContext, node: ast.Call) -> str:
    dotted = ctx.resolve(node.func)
    return dotted.rsplit(".", 1)[-1] if dotted else ""


def _keyword(node: ast.Call, name: str) -> ast.keyword | None:
    for kw in node.keywords:
        if kw.arg == name:
            return kw
    return None


def _is_ceil_div(node: ast.AST) -> tuple[bool, str | None]:
    """Matches ``-(-x // b)``; returns (True, divisor-name-if-Name)."""
    if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.BinOp)
            and isinstance(node.operand.op, ast.FloorDiv)
            and isinstance(node.operand.left, ast.UnaryOp)
            and isinstance(node.operand.left.op, ast.USub)):
        div = node.operand.right
        return True, div.id if isinstance(div, ast.Name) else None
    return False, None


def _is_ceil_mult(node: ast.AST) -> str | None:
    """Matches ``-(-x // b) * b`` (a dim padded UP to a multiple of b);
    returns the divisor name, or None."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        for ceil, other in ((node.left, node.right), (node.right, node.left)):
            ok, div = _is_ceil_div(ceil)
            if ok and div is not None and isinstance(other, ast.Name) \
                    and other.id == div:
                return div
    return None


def _padded_names(fn: ast.AST) -> dict[str, str]:
    """{var: divisor} for locals assigned a ceil-padded multiple."""
    out: dict[str, str] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            div = _is_ceil_mult(node.value)
            if div is not None:
                out[node.targets[0].id] = div
    return out


def _local_tuples(fn: ast.AST) -> dict[str, ast.AST]:
    """{var: tuple-literal} for locals like ``grid = (m // bm, n // bn)``."""
    out: dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            out[node.targets[0].id] = node.value
    return out


class PallasKernelRule(Rule):
    rule_id = "FIG004"
    severity = Severity.ERROR
    fix_hint = ("route interpret= through kernels/_platform.resolve_interpret "
                "and pad dims to block multiples before grid division")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, fn)
        yield from self._check_autotune(ctx)

    # -- per-function checks -------------------------------------------------

    def _check_function(self, ctx, fn) -> Iterator[Finding]:
        padded = _padded_names(fn)
        tuples = _local_tuples(fn)
        interpret_default = self._interpret_default(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _call_name(ctx, node) == "pallas_call":
                yield from self._check_pallas_call(ctx, node, padded, tuples)
            if interpret_default == "none":
                yield from self._check_forwarding(ctx, fn, node)

    @staticmethod
    def _interpret_default(fn) -> str | None:
        a = fn.args
        for params, defaults in ((a.kwonlyargs, a.kw_defaults),
                                 (a.args, [None] * (len(a.args)
                                                    - len(a.defaults))
                                  + list(a.defaults))):
            for p, d in zip(params, defaults):
                if p.arg == "interpret" and isinstance(d, ast.Constant):
                    return "none" if d.value is None else "bool"
        return None

    def _check_forwarding(self, ctx, fn, call: ast.Call) -> Iterator[Finding]:
        """In a wrapper whose ``interpret`` defaults to None, forwarding the
        raw parameter skips the platform resolution."""
        callee = _call_name(ctx, call)
        if callee == "resolve_interpret":
            return
        kw = _keyword(call, "interpret")
        if kw is not None and isinstance(kw.value, ast.Name) \
                and kw.value.id == "interpret":
            yield self.finding(
                ctx, call,
                f"`{fn.name}` forwards its unresolved interpret=None "
                f"parameter to `{callee or '<call>'}` — wrap it in "
                f"kernels/_platform.resolve_interpret(interpret)")

    def _check_pallas_call(self, ctx, node: ast.Call,
                           padded: dict[str, str],
                           tuples: dict[str, ast.AST]) -> Iterator[Finding]:
        kw = _keyword(node, "interpret")
        if kw is None:
            yield self.finding(
                ctx, node,
                "pallas_call without interpret= — the platform policy "
                "(compiled on TPU/GPU, interpreted on CPU) is silently "
                "bypassed")
        elif isinstance(kw.value, ast.Constant) and isinstance(kw.value.value,
                                                               bool):
            yield self.finding(
                ctx, kw.value,
                f"pallas_call with hardcoded interpret={kw.value.value} — "
                f"the decision belongs to kernels/_platform."
                f"resolve_interpret (tests override explicitly)")
        grid_kw = _keyword(node, "grid")
        if grid_kw is None:
            return
        grid = grid_kw.value
        if isinstance(grid, ast.Name):  # grid = (...) assigned earlier
            grid = tuples.get(grid.id, grid)
        if isinstance(grid, (ast.Tuple, ast.List)):
            for elt in grid.elts:
                yield from self._check_grid_elt(ctx, elt, padded)

    def _check_grid_elt(self, ctx, elt: ast.AST,
                        padded: dict[str, str]) -> Iterator[Finding]:
        """Flag ``X // b`` grid entries whose numerator is not ceil-padded
        to a multiple of the same divisor. cdiv/ceil-div entries and plain
        names (block counts computed elsewhere) pass."""
        if not (isinstance(elt, ast.BinOp)
                and isinstance(elt.op, ast.FloorDiv)):
            return
        ok, _ = _is_ceil_div(elt)  # a cdiv INSIDE the grid is fine
        if ok:
            return
        num, div = elt.left, elt.right
        div_name = div.id if isinstance(div, ast.Name) else None
        if isinstance(num, ast.Name) and div_name is not None \
                and padded.get(num.id) == div_name:
            return
        yield self.finding(
            ctx, elt,
            f"grid entry `{ast.unparse(elt)}` floor-divides a dim not "
            f"proven padded to a multiple of the divisor — ragged tail "
            f"blocks are silently dropped",
            fix_hint="pad the dim first (`mp = -(-m // bm) * bm`; grid "
                     "`mp // bm`) or use pl.cdiv with in-kernel masking")

    # -- AUTOTUNE table budget ----------------------------------------------

    def _check_autotune(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, (ast.Assign, ast.AnnAssign))):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if not any(isinstance(t, ast.Name) and t.id == "AUTOTUNE"
                       for t in targets):
                continue
            value = node.value
            if not isinstance(value, ast.Dict):
                continue
            yield from self._check_autotune_dict(ctx, value)

    def _check_autotune_dict(self, ctx, table: ast.Dict) -> Iterator[Finding]:
        budget = VMEM_BUDGET_BYTES["tpu"]
        last_bound: dict[int, object] = {}
        for key, val in zip(table.keys, table.values):
            entry = self._literal_entry(key, val)
            if entry is None:
                continue
            itemsize, bound, bm, bn = entry
            last_bound[itemsize] = bound
            where = f"AUTOTUNE[({itemsize}, {bound})]"
            if bn % 128 != 0:
                yield self.finding(
                    ctx, key,
                    f"{where}: block_cols={bn} is not lane-aligned "
                    f"(multiple of 128)")
            if bm % 8 != 0:
                yield self.finding(
                    ctx, key,
                    f"{where}: block_rows={bm} is not sublane-aligned "
                    f"(multiple of 8)")
            live = _LIVE_TILES * bm * bn * itemsize
            if live > budget:
                yield self.finding(
                    ctx, key,
                    f"{where}: blocks ({bm}, {bn}) put {live // 1024} KiB "
                    f"live in VMEM — past the {budget // 1024} KiB tpu "
                    f"budget model ({_LIVE_TILES} resident tiles)",
                    fix_hint="shrink block_rows/block_cols so "
                             f"{_LIVE_TILES}*bm*bn*itemsize fits the budget")
        for itemsize, bound in sorted(last_bound.items()):
            if bound is not None:
                yield self.finding(
                    ctx, table,
                    f"AUTOTUNE itemsize {itemsize} does not end with a None "
                    f"(catch-all) width bound — wide nodes would fall "
                    f"through the table")

    @staticmethod
    def _literal_entry(key, val):
        if not (isinstance(key, ast.Tuple) and len(key.elts) == 2
                and isinstance(val, ast.Tuple) and len(val.elts) == 2):
            return None
        elts = [e.value if isinstance(e, ast.Constant) else None
                for e in list(key.elts) + list(val.elts)]
        itemsize, bound, bm, bn = elts
        if not isinstance(itemsize, int) or not isinstance(bm, int) \
                or not isinstance(bn, int):
            return None
        if bound is not None and not isinstance(bound, int):
            return None
        return itemsize, bound, bm, bn
