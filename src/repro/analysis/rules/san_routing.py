"""FIG007 — src/ threads and locks must route through figaro-san wrappers.

The runtime sanitizer can only observe what goes through its wrappers: a
raw ``threading.Lock()`` in the serving stack is invisible to the lock-order
graph and the lockset race detector, so one forgotten conversion silently
blinds FIGARO_SAN on exactly the code most likely to race. This rule pins
the routing: every ``threading.Thread`` / ``Lock`` / ``RLock`` /
``Condition`` **call** in ``src/repro`` must be the sanitizer-aware
equivalent (`repro.sanitizer.locks.san_lock` / ``san_rlock`` /
``san_condition``, `repro.sanitizer.threads.san_thread`).

Scope is ``src/repro`` only, excluding ``repro/sanitizer`` itself (the
wrappers are implemented over the raw primitives). Tests, benchmarks and
examples may use raw threading freely — stress tests hammer servers from
plain ``threading.Thread``s on purpose. Thread-safe primitives the
sanitizer does not model (``Event``, ``Semaphore``, ``queue.Queue``) are
not restricted.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

_WRAPPED = {
    "threading.Thread": "repro.sanitizer.threads.san_thread",
    "threading.Lock": "repro.sanitizer.locks.san_lock",
    "threading.RLock": "repro.sanitizer.locks.san_rlock",
    "threading.Condition": "repro.sanitizer.locks.san_condition",
}


def _in_scope(path: str) -> bool:
    in_src = "src/repro/" in path or path.startswith("repro/")
    return in_src and "repro/sanitizer/" not in path


class SanRoutingRule(Rule):
    rule_id = "FIG007"
    severity = Severity.ERROR
    fix_hint = ("construct through the sanitizer-aware wrapper instead "
                "(repro.sanitizer.locks.san_lock/san_rlock/san_condition, "
                "repro.sanitizer.threads.san_thread) so FIGARO_SAN=1 can "
                "observe it; suppress with a reason only for locks that "
                "must not be instrumented")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not _in_scope(ctx.path):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func)
            wrapper = _WRAPPED.get(dotted or "")
            if wrapper is None:
                continue
            yield self.finding(
                ctx, node,
                f"`{dotted}(...)` bypasses the sanitizer wrappers — use "
                f"`{wrapper}` so the runtime race detector can see it")
