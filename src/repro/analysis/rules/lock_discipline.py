"""FIG005 — lock-owning classes must write shared state under their lock.

`AsyncFigaroServer` dispatches from background threads while the owning
session keeps dispatching from the caller's thread; `PlanHolder` is shared by
a dataset and every server it spawns; `FigaroEngine`'s executable cache and
counters are hit from both. Every one of them constructs its locks in
``__init__`` and the concurrency story is exactly "mutations happen inside
``with self._lock``". A bare ``self.x = ...`` added to any other method is a
data race that no single-threaded test will ever catch.

The rule is structural, not name-based: any class whose ``__init__`` creates
a ``threading.Lock`` / ``RLock`` / ``Condition`` attribute is
lock-disciplined, and every attribute write on ``self`` outside ``__init__``
must sit lexically inside a ``with self.<that lock>`` block. Single-threaded
setup paths that deliberately skip the lock carry a line suppression with
the reason.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: Raw threading factories plus the sanitizer-aware wrappers
#: (`repro.sanitizer.locks`) that FIG007 requires src/ code to use — a class
#: is lock-disciplined whichever spelling it constructs its locks with.
_LOCK_FACTORIES = frozenset({"Lock", "RLock", "Condition",
                             "san_lock", "san_rlock", "san_condition"})
_EXEMPT_METHODS = frozenset({"__init__", "__new__", "__init_subclass__"})


def _self_attr_target(node: ast.AST) -> str | None:
    """"attr" when ``node`` writes ``self.attr`` or ``self.attr[...]``."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _lock_attrs(ctx: FileContext, cls: ast.ClassDef) -> set[str]:
    """Attributes assigned a threading lock/condition in __init__."""
    out: set[str] = set()
    for stmt in cls.body:
        if not (isinstance(stmt, ast.FunctionDef)
                and stmt.name == "__init__"):
            continue
        for node in ast.walk(stmt):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            callee = ctx.resolve(node.value.func)
            base = callee.rsplit(".", 1)[-1] if callee else ""
            if base not in _LOCK_FACTORIES:
                continue
            for tgt in node.targets:
                attr = _self_attr_target(tgt)
                if attr is not None:
                    out.add(attr)
    return out


class LockDisciplineRule(Rule):
    rule_id = "FIG005"
    severity = Severity.ERROR
    fix_hint = ("wrap the write in `with self._lock:` (any of the class's "
                "__init__-created locks), or suppress with a reason if the "
                "path is provably single-threaded")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _lock_attrs(ctx, cls)
            if not locks:
                continue
            for method in cls.body:
                if not isinstance(method, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                    continue
                if method.name in _EXEMPT_METHODS:
                    continue
                yield from self._check_method(ctx, cls, method, locks)

    def _check_method(self, ctx, cls, method, locks) -> Iterator[Finding]:
        for stmt in method.body:
            yield from self._walk(ctx, cls, method, stmt, locks,
                                  locked=False)

    def _walk(self, ctx, cls, method, stmt, locks,
              locked: bool) -> Iterator[Finding]:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            holds = locked or self._with_holds_lock(stmt, locks)
            for inner in stmt.body:
                yield from self._walk(ctx, cls, method, inner, locks, holds)
            return
        targets: list[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, ast.AugAssign):
            targets = [stmt.target]
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        for tgt in targets:
            for t in (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                      else [tgt]):
                attr = _self_attr_target(t)
                if attr is not None and not locked:
                    yield self.finding(
                        ctx, stmt,
                        f"{cls.name}.{method.name} writes `self.{attr}` "
                        f"outside a `with self.<lock>` region "
                        f"(locks: {', '.join(sorted(locks))})")
        for inner in ast.iter_child_nodes(stmt):
            if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue  # nested defs run later, on their own thread story
            if isinstance(inner, ast.stmt):
                yield from self._walk(ctx, cls, method, inner, locks, locked)
            elif isinstance(inner, ast.ExceptHandler) or (
                    hasattr(ast, "match_case")
                    and isinstance(inner, ast.match_case)):
                for s in inner.body:
                    yield from self._walk(ctx, cls, method, s, locks, locked)

    @staticmethod
    def _with_holds_lock(stmt, locks) -> bool:
        for item in stmt.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                expr = expr.func
            attr = _self_attr_target(expr)
            if attr in locks:
                return True
        return False
