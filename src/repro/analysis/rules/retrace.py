"""FIG002 — retrace hazards around `jax.jit` dispatch signatures.

Zero-retrace serving rests on three structural facts that nothing at runtime
enforces until a trace-counter test happens to cover the broken path:

  * the engine's ``_STATIC`` table (kind -> static_argnames) must list
    exactly the keyword-only options of the matching ``_<kind>_impl`` body —
    a drifted entry either retraces per call (option became a traced value)
    or crashes on an unknown static name;
  * ``static_argnames`` handed to `jax.jit` must name real parameters of the
    jitted callable, and a parameter marked static must not default to an
    unhashable literal (list/dict/set) — both fail only at first dispatch;
  * a function closed over a plan and then jitted re-traces per plan object
    and pins the plan's buffers in jit's cache. Plans must pass *through*
    jit as pytree arguments (the engine's whole design); deliberate
    plan-closed benchmark helpers carry a suppression with their reason.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

_PLAN_BUILDERS = frozenset({"build_plan", "build_capacity_plan", "plan_for",
                            "refresh_plan"})


def _is_jit(ctx: FileContext, func: ast.AST) -> bool:
    dotted = ctx.resolve(func)
    return dotted is not None and (dotted == "jax.jit"
                                   or dotted.endswith(".jax.jit"))


def _jit_call(ctx: FileContext, node: ast.Call) -> bool:
    """True for ``jax.jit(...)`` and ``functools.partial(jax.jit, ...)``."""
    if _is_jit(ctx, node.func):
        return True
    dotted = ctx.resolve(node.func)
    return (dotted in ("functools.partial", "partial") and node.args
            and _is_jit(ctx, node.args[0]))


def _static_argnames(node: ast.Call) -> tuple[ast.keyword | None, list[str]]:
    for kw in node.keywords:
        if kw.arg == "static_argnames":
            names: list[str] = []
            if isinstance(kw.value, (ast.Tuple, ast.List, ast.Set)):
                for elt in kw.value.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value,
                                                                    str):
                        names.append(elt.value)
                    else:
                        return kw, []  # non-literal entry: not checkable
            elif isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                names.append(kw.value.value)
            else:
                return kw, []  # computed (e.g. self._STATIC[kind]): skip
            return kw, names
    return None, []


def _param_names(fn: ast.FunctionDef) -> set[str]:
    a = fn.args
    return {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}


def _kwonly_names(fn: ast.FunctionDef) -> set[str]:
    return {p.arg for p in fn.args.kwonlyargs}


def _unhashable_defaults(fn: ast.FunctionDef, static: set[str]) -> list[str]:
    a = fn.args
    out = []
    pos = a.posonlyargs + a.args
    for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if param.arg in static and isinstance(default,
                                              (ast.List, ast.Dict, ast.Set)):
            out.append(param.arg)
    for param, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None and param.arg in static and isinstance(
                default, (ast.List, ast.Dict, ast.Set)):
            out.append(param.arg)
    return out


def _free_names(fn: ast.AST) -> set[str]:
    """Names a function body loads but never binds — its closure surface.
    Approximate (no global/nonlocal handling): good enough to spot a
    captured plan."""
    bound: set[str] = set()
    loaded: set[str] = set()
    fns = [fn]
    while fns:
        f = fns.pop()
        if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            a = f.args
            bound |= {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}
            for p in (a.vararg, a.kwarg):
                if p is not None:
                    bound.add(p.arg)
        body = f.body if isinstance(f.body, list) else [f.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name):
                    if isinstance(node.ctx, ast.Load):
                        loaded.add(node.id)
                    else:
                        bound.add(node.id)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    bound.add(node.name)
    return loaded - bound


class _Scope:
    """One enclosing function: local defs, plan-ish names, jit calls."""

    def __init__(self, fn: ast.FunctionDef | None):
        self.fn = fn
        self.local_defs: dict[str, ast.FunctionDef] = {}
        self.planish: set[str] = set()


def _planish_names(fn: ast.FunctionDef, ctx: FileContext) -> set[str]:
    """Names in ``fn`` that look like FiGaRo plans: parameters or locals
    named/annotated so, or assigned from a plan builder."""
    out: set[str] = set()
    a = fn.args
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        ann = p.annotation
        ann_s = ast.unparse(ann) if ann is not None else ""
        if p.arg == "plan" or p.arg.endswith("_plan") or "FigaroPlan" in ann_s:
            out.add(p.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = ctx.resolve(node.value.func)
            base = callee.rsplit(".", 1)[-1] if callee else ""
            if base in _PLAN_BUILDERS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out.add(tgt.id)
    return out


class RetraceHazardRule(Rule):
    rule_id = "FIG002"
    severity = Severity.ERROR
    fix_hint = ("pass plans through jit as pytree arguments and keep "
                "static_argnames == the impl's keyword-only options "
                "(see core/engine.py:_STATIC)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._check_static_table(ctx)
        yield from self._check_jit_calls(ctx)

    # -- _STATIC <-> impl keyword sync --------------------------------------

    def _check_static_table(self, ctx: FileContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            table = None
            impls: dict[str, ast.FunctionDef] = {}
            for stmt in cls.body:
                if (isinstance(stmt, ast.Assign)
                        and any(isinstance(t, ast.Name) and t.id == "_STATIC"
                                for t in stmt.targets)
                        and isinstance(stmt.value, ast.Dict)):
                    table = stmt.value
                elif isinstance(stmt, ast.FunctionDef) and \
                        stmt.name.endswith("_impl") and \
                        stmt.name.startswith("_"):
                    impls[stmt.name[1:-len("_impl")]] = stmt
            if table is None or not impls:
                continue
            for key, value in zip(table.keys, table.values):
                if not (isinstance(key, ast.Constant)
                        and isinstance(key.value, str)):
                    continue
                kind = key.value
                declared = set()
                if isinstance(value, (ast.Tuple, ast.List)):
                    declared = {e.value for e in value.elts
                                if isinstance(e, ast.Constant)}
                impl = impls.get(kind)
                if impl is None:
                    yield self.finding(
                        ctx, key,
                        f"_STATIC lists kind {kind!r} but "
                        f"{cls.name} has no _{kind}_impl method")
                    continue
                actual = _kwonly_names(impl)
                missing = sorted(actual - declared)
                extra = sorted(declared - actual)
                if missing:
                    yield self.finding(
                        ctx, key,
                        f"_STATIC[{kind!r}] is missing impl keyword(s) "
                        f"{missing} — they would dispatch as traced values "
                        f"and retrace per call")
                if extra:
                    yield self.finding(
                        ctx, key,
                        f"_STATIC[{kind!r}] names {extra} which "
                        f"_{kind}_impl does not accept")

    # -- jit call sites ------------------------------------------------------

    def _check_jit_calls(self, ctx: FileContext) -> Iterator[Finding]:
        # Decorated defs: @functools.partial(jax.jit, static_argnames=...)
        # and @jax.jit-with-kwargs forms.
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in fn.decorator_list:
                if isinstance(dec, ast.Call) and _jit_call(ctx, dec):
                    yield from self._check_static_names(ctx, dec, fn)
        # Call-form jits inside a function scope: jax.jit(local_fn, ...).
        for scope_fn in ast.walk(ctx.tree):
            if not isinstance(scope_fn, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                continue
            local_defs = {stmt.name: stmt for stmt in ast.walk(scope_fn)
                          if isinstance(stmt, ast.FunctionDef)
                          and stmt is not scope_fn}
            planish = _planish_names(scope_fn, ctx)
            for node in ast.walk(scope_fn):
                if not (isinstance(node, ast.Call)
                        and _is_jit(ctx, node.func) and node.args):
                    continue
                target = node.args[0]
                inner = None
                if isinstance(target, ast.Name):
                    inner = local_defs.get(target.id)
                elif isinstance(target, ast.Lambda):
                    inner = target
                if inner is None:
                    continue
                if isinstance(inner, ast.FunctionDef):
                    yield from self._check_static_names(ctx, node, inner)
                captured = sorted(_free_names(inner) & planish)
                if captured:
                    yield self.finding(
                        ctx, node,
                        f"jitted closure captures plan value(s) "
                        f"{captured} — each plan object traces its own "
                        f"executable and pins its buffers in jit's cache; "
                        f"pass the plan as a pytree argument instead")

    def _check_static_names(self, ctx: FileContext, call: ast.Call,
                            fn: ast.FunctionDef | ast.Lambda
                            ) -> Iterator[Finding]:
        kw, names = _static_argnames(call)
        if kw is None or not names or isinstance(fn, ast.Lambda):
            return
        params = _param_names(fn)
        unknown = sorted(set(names) - params)
        if unknown:
            yield self.finding(
                ctx, call,
                f"static_argnames {unknown} are not parameters of "
                f"{fn.name}() — jit raises at first dispatch")
        bad_defaults = _unhashable_defaults(fn, set(names))
        for name in bad_defaults:
            yield self.finding(
                ctx, call,
                f"static parameter {name!r} of {fn.name}() defaults to an "
                f"unhashable literal — jit's static-arg hashing fails at "
                f"first dispatch")
