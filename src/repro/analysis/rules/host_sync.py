"""FIG009 — host synchronization reachable from a traced context.

The paper's retrace/latency story assumes the jitted hot path never blocks on
device values: one dispatch, one async computation. A ``np.asarray``,
``float()``/``int()``/``.item()``/``.tolist()``/``.block_until_ready()`` or
``jax.device_get`` applied to a *traced* value anywhere transitively inside
an engine ``_<kind>_impl``, a ``jax.jit``/``pallas_call`` argument, or a
``shard_map`` body either crashes at trace time (ConcretizationTypeError) or
— worse — silently hides behind a rarely-taken branch until a TPU run hits
it. Per-file rules cannot see this: the helper doing the sync is typically
modules away from the jit boundary.

This rule is purely a consumer of figaro-flow: `callgraph` marks the
traced-context region, `dataflow` runs the taint fixpoint and records every
sync sink applied to a traced-tainted value; each sink becomes a finding
carrying the root→site call chain as ``traced_context``.

Trace-time constants never fire: kwonly/`static_argnames` parameters,
closure variables of a traced function, metadata (``x.shape``/``x.dtype``/
``plan.spec``), and ``np.shape``-style metadata calls are all concrete in
the dataflow lattice.
"""

from __future__ import annotations

from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity


class HostSyncRule(Rule):
    rule_id = "FIG009"
    severity = Severity.ERROR
    fix_hint = ("compute the value before the dispatch boundary (host side) "
                "or keep the traced path pure jax.numpy; if the sync is "
                "deliberate trace-time work on a static value, make the "
                "parameter static so the dataflow sees a constant")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        return iter(())  # whole-program rule: see check_program

    def check_program(self, program) -> Iterator[Finding]:
        flow = program.dataflow()
        for sink in flow.sinks:
            fi = program.graph.functions[sink.qname]
            chain = tuple(q.split(":", 1)[1]
                          for q in program.traced_chain(sink.qname))
            root = program.graph.roots.get(
                program.traced_chain(sink.qname)[0]
                if program.traced_chain(sink.qname) else sink.qname)
            via = f" (traced via {' -> '.join(chain)})" if len(chain) > 1 \
                else ""
            kind = root.kind if root is not None else "jit"
            yield self.finding(
                fi.ctx, sink.node,
                f"`{sink.op}` on traced value `{sink.expr}` inside "
                f"`{fi.short}` — host sync reachable from a {kind} "
                f"region{via}",
                traced_context=chain)
