"""FIG008 — figaro-plan (`src/repro/planner/`) must stay jax-free.

The planner's statistics and cost model run at ingest time on the host:
`stats_for` is called from `TableSet.join`, `Replanner.proposal` from every
`ds.append`. Pulling `jax` / `jax.numpy` in there would (a) trace host-side
bookkeeping — every schema change would silently retrace a "cost model"
executable — and (b) drag a jax import into the analysis CI job, which runs
without jax on purpose. The planner is also deliberately decoupled from the
repo's runtime modules (it duck-types `Relation` / `Database` / `JoinTree`),
so `repro.data.relational` can import it for ``root="auto"`` without a
cycle; an import of any `repro.*` module outside the planner itself is
flagged for the same reason.

Suppression: a future planner module that legitimately needs a runtime type
for `typing` only should guard it under ``if TYPE_CHECKING:`` (exempt) rather
than suppressing the rule.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: the path fragment that scopes the rule (planner package sources only).
_SCOPE = "repro/planner/"

#: module roots that must never be imported from planner code.
_FORBIDDEN_ROOTS = ("jax", "jaxlib")

#: repro imports the planner may use: itself (relative imports resolve to
#: these) — nothing else; the planner duck-types the core containers.
_ALLOWED_REPRO = ("repro.planner",)


def _type_checking_spans(tree: ast.AST) -> list[tuple[int, int]]:
    """Line spans of ``if TYPE_CHECKING:`` bodies (typing-only imports are
    erased at runtime and cannot drag jax in)."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, ast.If):
            test = node.test
            name = test.id if isinstance(test, ast.Name) else \
                test.attr if isinstance(test, ast.Attribute) else None
            if name == "TYPE_CHECKING":
                last = node.body[-1]
                spans.append((node.lineno, getattr(last, "end_lineno",
                                                   last.lineno)))
    return spans


class JaxFreePlannerRule(Rule):
    rule_id = "FIG008"
    severity = Severity.ERROR
    fix_hint = ("keep planner cost/stats code pure numpy+stdlib — it runs at "
                "ingest time, never inside a trace; duck-type core containers "
                "instead of importing repro runtime modules")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if _SCOPE not in ctx.path.replace("\\", "/"):
            return
        exempt = _type_checking_spans(ctx.tree)

        def exempted(node: ast.AST) -> bool:
            return any(lo <= node.lineno <= hi for lo, hi in exempt)

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative: stays inside the planner package
                    continue
                mods = [node.module] if node.module else []
            else:
                continue
            if exempted(node):
                continue
            for mod in mods:
                root = mod.split(".")[0]
                if root in _FORBIDDEN_ROOTS:
                    yield self.finding(
                        ctx, node,
                        f"planner module imports `{mod}` — figaro-plan runs "
                        f"at ingest time and must stay jax-free")
                elif root == "repro" and not any(
                        mod == p or mod.startswith(p + ".")
                        for p in _ALLOWED_REPRO):
                    yield self.finding(
                        ctx, node,
                        f"planner module imports runtime module `{mod}` — "
                        f"duck-type core containers instead (keeps the "
                        f"planner cycle-free and jax-free)")
