"""FIG003 — hardcoded narrowing dtype literals where dtype must derive
from inputs.

The paper's accuracy claim (errors on par with database size, not join size)
survives only because the pipeline never silently narrows: data rides in the
caller's I/O dtype end to end, accumulators widen via the one approved idiom

    acc = jnp.float64 if x.dtype == jnp.float64 else jnp.float32

and join counts accumulate in float64 no matter what (float32 rounds exact
counts past 2^24 — the PR 3 bug). Inside ``core/`` and ``kernels/`` this rule
flags every narrowing float literal (``float32`` / ``float16`` /
``bfloat16``) in a function *body*, with three deliberate outs:

  * keyword defaults in a signature (``dtype=jnp.float32`` is the documented
    I/O policy surface — the caller chooses);
  * the accumulator idiom above (an IfExp whose branches are both dtype
    attributes, and dtype literals inside comparisons — those are reads);
  * ``float64`` and integer dtypes (widest — never a narrowing drift).

In ``core/counts.py`` even the outs are closed: any sub-f64 float literal is
an error (count accumulation narrower than f64).
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

_NARROWING = frozenset({"float32", "float16", "bfloat16"})
_DTYPE_MODULES = ("jax.numpy.", "numpy.", "jax.")


def _in_scope(path: str) -> bool:
    return ("/core/" in path or "/kernels/" in path
            or path.startswith(("core/", "kernels/")))


def _narrowing_dtype(ctx: FileContext, node: ast.AST) -> str | None:
    """"jax.numpy.float32" for a resolved narrowing dtype literal, else None."""
    if not isinstance(node, ast.Attribute) or node.attr not in _NARROWING:
        return None
    dotted = ctx.resolve(node)
    if dotted and dotted.startswith(_DTYPE_MODULES):
        return dotted
    return None


def _is_dtype_attr(ctx: FileContext, node: ast.AST) -> bool:
    if not isinstance(node, ast.Attribute):
        return False
    dotted = ctx.resolve(node)
    return bool(dotted) and dotted.startswith(_DTYPE_MODULES)


class DtypeDriftRule(Rule):
    rule_id = "FIG003"
    severity = Severity.ERROR
    fix_hint = ("derive the dtype from the input (x.dtype) or widen via the "
                "accumulator idiom `jnp.float64 if x.dtype == jnp.float64 "
                "else jnp.float32`")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not _in_scope(ctx.path):
            return
        counts_file = ctx.path.endswith("core/counts.py")
        allowed = set() if counts_file else self._allowed_nodes(ctx)
        for node in ast.walk(ctx.tree):
            dotted = _narrowing_dtype(ctx, node)
            if dotted is None or id(node) in allowed:
                continue
            if counts_file:
                yield self.finding(
                    ctx, node,
                    f"count accumulation uses `{dotted}` — counts must "
                    f"accumulate in float64 (float32 is exact only to 2^24)",
                    fix_hint="use jnp.float64 / np.float64 for all count "
                             "arithmetic")
            else:
                yield self.finding(
                    ctx, node,
                    f"hardcoded narrowing dtype `{dotted}` in a function "
                    f"body — the I/O-dtype policy derives dtypes from "
                    f"inputs")

    def _allowed_nodes(self, ctx: FileContext) -> set[int]:
        """ids of dtype-literal nodes sitting in an approved context."""
        allowed: set[int] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                # Signature defaults: the caller-facing dtype policy.
                a = node.args
                for default in list(a.defaults) + [d for d in a.kw_defaults
                                                   if d is not None]:
                    for sub in ast.walk(default):
                        allowed.add(id(sub))
            elif isinstance(node, ast.IfExp):
                # The accumulator idiom: both branches dtype attributes.
                if (_is_dtype_attr(ctx, node.body)
                        and _is_dtype_attr(ctx, node.orelse)):
                    allowed.add(id(node.body))
                    allowed.add(id(node.orelse))
            elif isinstance(node, ast.Compare):
                # `x.dtype == jnp.float64` and friends: reads, not drift.
                for sub in [node.left] + list(node.comparators):
                    for s in ast.walk(sub):
                        allowed.add(id(s))
        return allowed
