"""FIG011 — donated buffer read again after an engine dispatch.

`FigaroEngine` (PR 1) donates the data argument of every dispatch
(``donate_argnums=(1,)``) when constructed with ``donate_data=True`` — on
backends with real donation the input buffers are *invalidated* by the call.
The engine carries a runtime guard for plan-owned buffers, but a caller-owned
buffer re-read after its dispatch is only caught when the backend actually
donates (TPU), i.e. never in this container's CPU CI. This rule turns the
guard into a compile-time proof over the AST:

  * a dispatch call (``engine.r0/qr/svd/pca/least_squares/_dispatch``) whose
    receiver is *provably donating* — a local/module name assigned
    ``FigaroEngine(...)`` without ``donate_data=False`` — and whose data
    argument is a plain name;
  * followed by any load of that name along some path: a later statement
    without an intervening rebind/del, or — the classic benchmark bug — the
    dispatch sits in a loop that never rebinds the buffer, so iteration two
    re-dispatches the consumed slab.

Receivers built with ``donate_data=False``, from ``default_engine()`` /
``default_session()`` (both non-donating by construction), or not resolvable
to a donating constructor are skipped: the rule proves real bugs, it does
not guess.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: dispatch method -> index of the donated data argument in call.args.
_DATA_ARG = {"r0": 1, "qr": 1, "svd": 1, "pca": 1, "least_squares": 2,
             "_dispatch": 2}

#: Constructors/factories that yield a NON-donating engine.
_NON_DONATING = frozenset({"default_engine", "default_session"})


def _donating_names(fn: ast.AST, tree: ast.Module) -> set[str]:
    """Names bound (in this function or at module level) to a donating
    `FigaroEngine(...)` — `donate_data=False` and known non-donating
    factories disqualify."""
    out: set[str] = set()
    scopes: list[ast.AST] = [fn]
    scopes.extend(s for s in tree.body if isinstance(s, ast.Assign))
    for scope in scopes:
        for node in ast.walk(scope):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                continue
            callee = node.value.func
            cname = callee.attr if isinstance(callee, ast.Attribute) \
                else (callee.id if isinstance(callee, ast.Name) else "")
            name = node.targets[0].id
            if cname == "FigaroEngine":
                donate = True
                for kw in node.value.keywords:
                    if kw.arg == "donate_data" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is False:
                        donate = False
                if donate:
                    out.add(name)
                else:
                    out.discard(name)
            elif cname in _NON_DONATING:
                out.discard(name)
    return out


def _data_name(call: ast.Call, kind: str) -> ast.Name | None:
    for kw in call.keywords:
        if kw.arg == "data":
            return kw.value if isinstance(kw.value, ast.Name) else None
    idx = _DATA_ARG[kind]
    if len(call.args) > idx and isinstance(call.args[idx], ast.Name):
        arg = call.args[idx]
        return arg if not isinstance(arg, ast.Starred) else None
    return None


def _bind_lines(fn: ast.AST, name: str) -> list[int]:
    """Lines where ``name`` is (re)bound or deleted — a rebind between the
    dispatch and a later read means the read sees a fresh buffer."""
    out: list[int] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for e in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                          else [t]):
                    if isinstance(e, ast.Name) and e.id == name:
                        out.append(node.lineno)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            t = node.target
            for e in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                      else [t]):
                if isinstance(e, ast.Name) and e.id == name:
                    out.append(node.lineno)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    out.append(node.lineno)
    return sorted(out)


class DonationRule(Rule):
    rule_id = "FIG011"
    severity = Severity.ERROR
    fix_hint = ("rebind the buffer before reuse (fresh batch per dispatch), "
                "copy it first (`jnp.array(x)`), or build the engine with "
                "donate_data=False if the caller must keep its inputs")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, fn)

    def _check_function(self, ctx: FileContext, fn) -> Iterator[Finding]:
        donating = _donating_names(fn, ctx.tree)
        if not donating:
            return
        loops = _loop_map(fn)
        for call in ast.walk(fn):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr in _DATA_ARG):
                continue
            recv = call.func.value
            if not (isinstance(recv, ast.Name) and recv.id in donating):
                continue
            data = _data_name(call, call.func.attr)
            if data is None:
                continue
            yield from self._check_reuse(ctx, fn, loops, call, recv.id, data)

    def _check_reuse(self, ctx, fn, loops, call: ast.Call, engine: str,
                     data: ast.Name) -> Iterator[Finding]:
        name = data.id
        binds = _bind_lines(fn, name)
        call_end = getattr(call, "end_lineno", call.lineno)
        site = f"`{engine}.{call.func.attr}(...)`"

        # Path 1 — loop body that never rebinds the buffer: iteration 2
        # dispatches (and therefore reads) the already-donated slab.
        for loop in loops.get(id(call), ()):
            loop_end = getattr(loop, "end_lineno", loop.lineno)
            if not any(loop.lineno <= b <= loop_end for b in binds):
                yield self.finding(
                    ctx, call,
                    f"`{name}` is dispatched through {site}'s donated data "
                    f"position inside a loop that never rebinds it — the "
                    f"buffer is consumed on iteration 1 and re-read on "
                    f"iteration 2")
                return  # one finding per call site is enough

        # Path 2 — straight-line read after the dispatch without a rebind.
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Name) and node.id == name
                    and isinstance(node.ctx, ast.Load)
                    and node.lineno > call_end):
                continue
            if any(call_end < b <= node.lineno for b in binds):
                continue
            yield self.finding(
                ctx, call,
                f"`{name}` is read at line {node.lineno} after being passed "
                f"through {site}'s donated data position — donation "
                f"invalidates the buffer on dispatch")
            return


def _loop_map(fn: ast.AST) -> dict[int, list[ast.AST]]:
    """id(call) -> enclosing For/While loops, innermost last."""
    out: dict[int, list[ast.AST]] = {}

    def walk(node: ast.AST, stack: list[ast.AST]) -> None:
        if isinstance(node, ast.Call):
            out[id(node)] = list(stack)
        push = isinstance(node, (ast.For, ast.AsyncFor, ast.While))
        if push:
            stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            walk(child, stack)

    walk(fn, [])
    return out
