"""FIG010 — side effects inside a traced context.

A jitted body runs its Python exactly once per trace; any side effect inside
it — ``self.attr = ...``, mutating a module global or closure container,
``print`` — executes at *trace* time, not per call. The symptom is a counter
that stops counting once the executable is cached, a log line that appears
once then never again, or (with donation/async in play) a data race between
the tracing thread and the host path. figaro-flow's traced-context marking
makes the check direct: scan every traced function for effectful statements.

Exemptions, in order of principle:

  * Writes lexically inside a ``with self.<lock>`` / ``with <module_lock>``
    region are *deliberate trace-time bookkeeping*: the engine's trace
    counters (`FigaroEngine._bump`) and the retrace sanitizer's event log
    (`retrace.note_trace`) run once per compilation by design, under their
    locks. Lock attributes come from FIG005's `_lock_attrs`; module-level
    locks are names bound to ``threading.Lock/RLock/Condition`` (or the
    sanitizer's ``san_lock``) at module scope.
  * An explicit allowlist pins the engine's lock-guarded counter chain by
    qualified name — the documented escape hatch the tentpole issue calls
    for, kept tiny on purpose.
  * Subscript stores whose base is function-local (parameters included) are
    fine: Pallas ref writes (``out_ref[...] = x``) and local accumulator
    dicts are the traced computation itself, not an escaping effect.
  * ``self`` writes inside ``__init__``/``__post_init__``/``__new__``
    initialize a freshly constructed object, not shared state — constructing
    a host object at trace time is the *caller's* effect, caught where the
    object escapes.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity
from .lock_discipline import _lock_attrs
from .thread_escape import _MUTATORS

#: Trace-time bookkeeping that is lock-guarded AND deliberate: the engine's
#: per-kind trace counters and the retrace sanitizer's note/finding chain.
_ALLOWLIST = frozenset({
    "repro.core.engine:FigaroEngine._bump",
    "repro.sanitizer.retrace:note_trace",
    "repro.sanitizer._state:SanitizerState.add_finding",
})


def _root_name(node: ast.AST) -> ast.Name | None:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node if isinstance(node, ast.Name) else None


def _local_names(fn: ast.AST) -> set[str]:
    """Names bound in this function's own scope (params, assignments, loop
    and with targets, comprehension targets, nested def names) — excluding
    nested function bodies, which are their own traced functions."""
    out: set[str] = set()
    a = fn.args
    for p in (a.posonlyargs + a.args + a.kwonlyargs
              + ([a.vararg] if a.vararg else [])
              + ([a.kwarg] if a.kwarg else [])):
        out.add(p.arg)
    globals_decl: set[str] = set()

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.add(child.name)
                continue
            if isinstance(child, ast.Lambda):
                continue
            if isinstance(child, (ast.Global, ast.Nonlocal)):
                globals_decl.update(child.names)
            if isinstance(child, ast.Name) and isinstance(child.ctx,
                                                          ast.Store):
                out.add(child.id)
            walk(child)

    walk(fn)
    return out - globals_decl


class TraceEffectsRule(Rule):
    rule_id = "FIG010"
    severity = Severity.ERROR
    fix_hint = ("hoist the side effect out of the traced region (do it in "
                "the host-side dispatcher), return the value instead of "
                "mutating shared state, or — for deliberate trace-time "
                "bookkeeping — guard it with the owning lock")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        return iter(())  # whole-program rule: see check_program

    def check_program(self, program) -> Iterator[Finding]:
        graph = program.graph
        for qname in sorted(graph.traced):
            if qname in _ALLOWLIST:
                continue
            fi = graph.functions[qname]
            mod = graph.modules[fi.module]
            self_locks = _lock_attrs(fi.ctx, fi.cls) \
                if fi.cls is not None else set()
            scan = _EffectScanner(fi, self_locks, mod.module_locks,
                                  _local_names(fi.node))
            chain = tuple(q.split(":", 1)[1]
                          for q in program.traced_chain(qname))
            via = f" (traced via {' -> '.join(chain)})" if len(chain) > 1 \
                else ""
            for node, what in scan.effects:
                yield self.finding(
                    fi.ctx, node,
                    f"`{fi.short}` {what} inside a traced context — the "
                    f"effect runs once per trace, not per call{via}",
                    traced_context=chain)


class _EffectScanner:
    """Lexical walk with a lock-held flag, FIG005/FIG006-style."""

    def __init__(self, fi, self_locks: set[str], module_locks: set[str],
                 local: set[str]) -> None:
        self.fi = fi
        self.self_locks = self_locks
        self.module_locks = module_locks
        self.local = local
        # In a constructor, `self` IS the fresh local object.
        self.own_self = fi.node.name in ("__init__", "__post_init__",
                                         "__new__")
        self.effects: list[tuple[ast.AST, str]] = []
        for stmt in fi.node.body:
            self._walk(stmt, locked=False)

    def _walk(self, stmt: ast.stmt, locked: bool) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            holds = locked or self._holds_lock(stmt)
            for inner in stmt.body:
                self._walk(inner, holds)
            return
        self._check_stmt(stmt, locked)
        for inner in ast.iter_child_nodes(stmt):
            if isinstance(inner, ast.stmt):
                self._walk(inner, locked)
            elif isinstance(inner, ast.ExceptHandler) or (
                    hasattr(ast, "match_case")
                    and isinstance(inner, ast.match_case)):
                for s in inner.body:
                    self._walk(s, locked)

    def _holds_lock(self, stmt) -> bool:
        for item in stmt.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                expr = expr.func
            if isinstance(expr, ast.Attribute) \
                    and isinstance(expr.value, ast.Name) \
                    and expr.value.id == "self" \
                    and expr.attr in self.self_locks:
                return True
            if isinstance(expr, ast.Name) and expr.id in self.module_locks:
                return True
        return False

    def _check_stmt(self, stmt: ast.stmt, locked: bool) -> None:
        if locked:
            return
        targets: list[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for tgt in targets:
            for t in (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                      else [tgt]):
                self._check_target(t)
        for node in ast.walk(stmt) if isinstance(stmt, ast.Expr) else ():
            if isinstance(node, ast.Call):
                self._check_call(node)
        # Calls buried in non-Expr statements (e.g. `x = log(print(y))`)
        # still matter for print/mutators:
        if not isinstance(stmt, ast.Expr):
            for node in _own_exprs(stmt):
                if isinstance(node, ast.Call):
                    self._check_call(node)

    def _check_target(self, t: ast.AST) -> None:
        if isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
                and t.value.id == "self":
            if not self.own_self:
                self.effects.append((t, f"writes `self.{t.attr}`"))
            return
        if isinstance(t, ast.Name) and t.id not in self.local:
            self.effects.append((t, f"writes global/closure name `{t.id}`"))
            return
        if isinstance(t, (ast.Subscript, ast.Attribute)):
            root = _root_name(t)
            if root is not None and root.id == "self":
                if not self.own_self:
                    self.effects.append((t, "writes through `self`"))
            elif root is not None and root.id not in self.local:
                self.effects.append(
                    (t, f"mutates global/closure container `{root.id}`"))

    def _check_call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Name) and func.id == "print":
            self.effects.append((node, "calls print()"))
            return
        if isinstance(func, ast.Attribute) and func.attr in _MUTATORS:
            recv = func.value
            if isinstance(recv, ast.Attribute) \
                    and isinstance(recv.value, ast.Name) \
                    and recv.value.id == "self":
                self.effects.append(
                    (node, f"mutates `self.{recv.attr}` (.{func.attr})"))
                return
            root = _root_name(recv)
            if root is not None and root.id != "self" \
                    and root.id not in self.local:
                self.effects.append(
                    (node,
                     f"mutates global/closure `{root.id}` (.{func.attr})"))


def _own_exprs(stmt: ast.stmt):
    """Expressions evaluated by this statement itself (child statements and
    deferred bodies excluded)."""
    stack = [c for c in ast.iter_child_nodes(stmt)
             if isinstance(c, ast.expr) and not isinstance(c, ast.Lambda)]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr) \
                    and not isinstance(child, ast.Lambda):
                stack.append(child)
