"""Rule registry: one module per rule family, aggregated here."""

from __future__ import annotations

from ..framework import Rule
from .compat_pin import CompatPinRule
from .donation import DonationRule
from .dtype_drift import DtypeDriftRule
from .host_sync import HostSyncRule
from .jaxfree import JaxFreePlannerRule
from .lock_discipline import LockDisciplineRule
from .pallas_kernel import PallasKernelRule
from .retrace import RetraceHazardRule
from .san_routing import SanRoutingRule
from .slab_layout import SlabLayoutRule
from .thread_escape import ThreadEscapeRule
from .trace_effects import TraceEffectsRule

__all__ = ["all_rules", "CompatPinRule", "RetraceHazardRule",
           "DtypeDriftRule", "PallasKernelRule", "LockDisciplineRule",
           "ThreadEscapeRule", "SanRoutingRule", "JaxFreePlannerRule",
           "HostSyncRule", "TraceEffectsRule", "DonationRule",
           "SlabLayoutRule"]


def all_rules() -> list[Rule]:
    """Fresh rule instances (rules may keep per-run state)."""
    return [CompatPinRule(), RetraceHazardRule(), DtypeDriftRule(),
            PallasKernelRule(), LockDisciplineRule(), ThreadEscapeRule(),
            SanRoutingRule(), JaxFreePlannerRule(), HostSyncRule(),
            TraceEffectsRule(), DonationRule(), SlabLayoutRule()]
