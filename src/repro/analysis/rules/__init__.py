"""Rule registry: one module per rule family, aggregated here."""

from __future__ import annotations

from ..framework import Rule
from .compat_pin import CompatPinRule
from .dtype_drift import DtypeDriftRule
from .jaxfree import JaxFreePlannerRule
from .lock_discipline import LockDisciplineRule
from .pallas_kernel import PallasKernelRule
from .retrace import RetraceHazardRule
from .san_routing import SanRoutingRule
from .thread_escape import ThreadEscapeRule

__all__ = ["all_rules", "CompatPinRule", "RetraceHazardRule",
           "DtypeDriftRule", "PallasKernelRule", "LockDisciplineRule",
           "ThreadEscapeRule", "SanRoutingRule", "JaxFreePlannerRule"]


def all_rules() -> list[Rule]:
    """Fresh rule instances (rules may keep per-run state)."""
    return [CompatPinRule(), RetraceHazardRule(), DtypeDriftRule(),
            PallasKernelRule(), LockDisciplineRule(), ThreadEscapeRule(),
            SanRoutingRule(), JaxFreePlannerRule()]
