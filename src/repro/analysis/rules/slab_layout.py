"""FIG012 — symbolic slab-layout consistency.

The R₀ slab layout is pure integer arithmetic spread across three modules:
`build_plan` lays out columns (prefix sums over ``num_data_cols``) and rows
(emission order: per node the ``m`` scaled-tail rows then the ``K``
generalized-tail rows), `plan_cache.bucket_spec` *re-derives* the row layout
after pow2 capacity bucketing, and `PlanSpec.__post_init__` re-derives the
band table. A stale copy of any of these invariants — an ``out_row0`` that
forgets the ``m`` offset, a row bump that drops ``K``, a band built from the
wrong field — produces overlapping or gapped bands that only surface as
numerically wrong R₀ entries, far from the layout code. This rule proves the
invariants by abstract interpretation over the AST shapes:

  * **row partition** — in any loop assigning ``replace(..., tail_row0=...,
    out_row0=...)``: ``tail_row0`` is exactly the running accumulator,
    ``out_row0`` is ``acc + <node>.m``, and the accumulator advances by
    ``<node>.m + <node>.K`` (same node expression) — so consecutive bands
    tile ``[0, r0_rows)`` with no overlap and no gap. ``r0_rows`` passed
    anywhere in the same function must be the final accumulator, and
    ``total_rows`` must be ``sum(<node>.m ...)``.
  * **column prefix** — a loop storing ``col_start[...]`` must store exactly
    the running accumulator (prefix-sum property: ``col0 + width <=
    num_cols`` for every node), and ``num_cols`` must be the final
    accumulator.
  * **pow2 bucketing** — ``next_pow2`` must be the canonical monotone
    ``1 << max(int(x) - 1, 0).bit_length()``; in functions that bucket with
    it, *every* capacity field among ``m``/``K``/``P`` passed to ``replace``
    must go through ``next_pow2`` (a single un-bucketed field breaks the
    cache-hit monotonicity argument).
  * **band contract** — ``SlabBand(kind="tail", ...)`` fields must come from
    ``tail_row0/m/col_start/n`` and ``kind="out"`` from
    ``out_row0/K/subtree_start/subtree_width``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity

#: SlabBand keyword -> required source attribute, per band kind.
_BAND_CONTRACT = {
    "tail": {"row0": "tail_row0", "rows": "m", "col0": "col_start",
             "width": "n"},
    "out": {"row0": "out_row0", "rows": "K", "col0": "subtree_start",
            "width": "subtree_width"},
}

_CAPACITY_FIELDS = ("m", "K", "P")


def _is_replace(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == "replace") or \
        (isinstance(f, ast.Name) and f.id == "replace")


def _kw(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _dump(node: ast.AST) -> str:
    return ast.dump(node)


def _is_sum_of_m(node: ast.expr) -> bool:
    """``sum(<x>.m for ...)`` (or listcomp equivalent)."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "sum" and node.args):
        return False
    gen = node.args[0]
    if isinstance(gen, (ast.GeneratorExp, ast.ListComp)):
        return isinstance(gen.elt, ast.Attribute) and gen.elt.attr == "m"
    return False


def _canonical_pow2(param: str) -> str:
    tmpl = ast.parse(f"1 << max(int({param}) - 1, 0).bit_length()",
                     mode="eval")
    return _dump(tmpl.body)


class SlabLayoutRule(Rule):
    rule_id = "FIG012"
    severity = Severity.ERROR
    fix_hint = ("keep the layout arithmetic canonical: tail_row0=acc, "
                "out_row0=acc + node.m, acc += node.m + node.K per node "
                "(r0_rows = final acc, total_rows = sum of node.m); "
                "col_start[x] = acc with num_cols = final acc; bucket every "
                "capacity field through next_pow2")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._check_band_calls(ctx)
        yield from self._check_pow2_def(ctx)
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_row_layout(ctx, fn)
                yield from self._check_col_prefix(ctx, fn)
                yield from self._check_pow2_use(ctx, fn)

    # -- band contract --------------------------------------------------

    def _check_band_calls(self, ctx: FileContext) -> Iterator[Finding]:
        for call in ast.walk(ctx.tree):
            if not (isinstance(call, ast.Call)
                    and ((isinstance(call.func, ast.Name)
                          and call.func.id == "SlabBand")
                         or (isinstance(call.func, ast.Attribute)
                             and call.func.attr == "SlabBand"))):
                continue
            kind = _kw(call, "kind")
            if not (isinstance(kind, ast.Constant)
                    and kind.value in _BAND_CONTRACT):
                continue
            contract = _BAND_CONTRACT[kind.value]
            for field, want in contract.items():
                val = _kw(call, field)
                # Only attribute-sourced fields are provable; names/ints are
                # the caller's business (e.g. synthetic bands in tests).
                if isinstance(val, ast.Attribute) and val.attr != want:
                    yield self.finding(
                        ctx, val,
                        f"SlabBand(kind=\"{kind.value}\") takes `{field}` "
                        f"from `.{val.attr}` — the {kind.value}-band "
                        f"contract requires `.{want}` (stale band layout)")

    # -- row partition ---------------------------------------------------

    def _check_row_layout(self, ctx: FileContext, fn) -> Iterator[Finding]:
        found_loop = False
        acc_name = None
        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            replace_call = None
            for stmt in ast.walk(loop):
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Call) \
                        and _is_replace(stmt.value) \
                        and _kw(stmt.value, "tail_row0") is not None \
                        and _kw(stmt.value, "out_row0") is not None:
                    replace_call = stmt.value
                    break
            if replace_call is None:
                continue
            found_loop = True
            tail = _kw(replace_call, "tail_row0")
            out = _kw(replace_call, "out_row0")

            if not isinstance(tail, ast.Name):
                yield self.finding(
                    ctx, tail,
                    "`tail_row0` must be the running row accumulator "
                    "(a plain name) — anything else breaks the band "
                    "partition proof")
                continue
            acc_name = tail.id

            # out_row0 == acc + <node>.m
            m_expr = None
            if (isinstance(out, ast.BinOp) and isinstance(out.op, ast.Add)
                    and isinstance(out.left, ast.Name)
                    and out.left.id == acc_name
                    and isinstance(out.right, ast.Attribute)
                    and out.right.attr == "m"):
                m_expr = out.right
            else:
                yield self.finding(
                    ctx, out,
                    f"`out_row0` must be `{acc_name} + <node>.m` (the K "
                    f"rows start right after the m tail rows) — this "
                    f"expression places the out band elsewhere")

            # acc += <node>.m + <node>.K with the SAME node expression
            bump = None
            for stmt in ast.walk(loop):
                if isinstance(stmt, ast.AugAssign) \
                        and isinstance(stmt.op, ast.Add) \
                        and isinstance(stmt.target, ast.Name) \
                        and stmt.target.id == acc_name:
                    bump = stmt
                    break
            if bump is None:
                yield self.finding(
                    ctx, loop,
                    f"row accumulator `{acc_name}` never advances inside "
                    f"the layout loop — every band would start at the same "
                    f"row")
                continue
            v = bump.value
            ok = (isinstance(v, ast.BinOp) and isinstance(v.op, ast.Add)
                  and isinstance(v.left, ast.Attribute) and v.left.attr == "m"
                  and isinstance(v.right, ast.Attribute)
                  and v.right.attr == "K"
                  and _dump(v.left.value) == _dump(v.right.value)
                  and (m_expr is None or _dump(v.left) == _dump(m_expr)))
            if not ok:
                yield self.finding(
                    ctx, bump,
                    f"row accumulator must advance by `<node>.m + <node>.K` "
                    f"per node (same node as `out_row0`) — this bump leaves "
                    f"the bands overlapping or gapped")

        if not found_loop or acc_name is None:
            return

        # r0_rows / total_rows derived from the finished layout.
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            r0 = _kw(call, "r0_rows")
            if r0 is not None and not (isinstance(r0, ast.Name)
                                       and r0.id == acc_name):
                yield self.finding(
                    ctx, r0,
                    f"`r0_rows` must be the final row accumulator "
                    f"`{acc_name}` — any other value desynchronizes the "
                    f"slab height from the band layout")
            tot = _kw(call, "total_rows")
            if tot is not None and not self._is_total_rows(fn, tot):
                yield self.finding(
                    ctx, tot,
                    "`total_rows` must be `sum(<node>.m ...)` over the "
                    "laid-out nodes (directly or via a local alias)")

    def _is_total_rows(self, fn, expr: ast.expr) -> bool:
        if _is_sum_of_m(expr):
            return True
        if isinstance(expr, ast.Name):  # one-level local alias
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and stmt.targets[0].id == expr.id:
                    return _is_sum_of_m(stmt.value)
        return False

    # -- column prefix ---------------------------------------------------

    def _check_col_prefix(self, ctx: FileContext, fn) -> Iterator[Finding]:
        acc_name = None
        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            store = None
            for stmt in ast.walk(loop):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Subscript) \
                        and isinstance(stmt.targets[0].value, ast.Name) \
                        and stmt.targets[0].value.id == "col_start":
                    store = stmt
                    break
            if store is None:
                continue
            bump_names = {
                s.target.id for s in ast.walk(loop)
                if isinstance(s, ast.AugAssign)
                and isinstance(s.target, ast.Name)}
            if not (isinstance(store.value, ast.Name)
                    and store.value.id in bump_names):
                yield self.finding(
                    ctx, store,
                    "`col_start[...]` must store the running column "
                    "accumulator (prefix-sum layout) — otherwise "
                    "`col0 + width <= num_cols` is unprovable")
                continue
            acc_name = store.value.id

        if acc_name is None:
            return
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == "num_cols":
                if not (isinstance(stmt.value, ast.Name)
                        and stmt.value.id == acc_name):
                    yield self.finding(
                        ctx, stmt,
                        f"`num_cols` must be the final column accumulator "
                        f"`{acc_name}` — the prefix-sum invariant "
                        f"`col_start[last] + width == num_cols` fails "
                        f"otherwise")

    # -- pow2 bucketing --------------------------------------------------

    def _check_pow2_def(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not (isinstance(fn, ast.FunctionDef)
                    and fn.name == "next_pow2"):
                continue
            body = [s for s in fn.body
                    if not (isinstance(s, ast.Expr)
                            and isinstance(s.value, ast.Constant)
                            and isinstance(s.value.value, str))]
            params = fn.args.args
            ok = (len(body) == 1 and isinstance(body[0], ast.Return)
                  and body[0].value is not None and len(params) == 1
                  and _dump(body[0].value)
                  == _canonical_pow2(params[0].arg))
            if not ok:
                yield self.finding(
                    ctx, fn,
                    "`next_pow2` must be the canonical "
                    "`1 << max(int(x) - 1, 0).bit_length()` — monotone, "
                    "and exact on powers of two; a variant breaks the "
                    "capacity-bucketing cache-hit proof")

    def _check_pow2_use(self, ctx: FileContext, fn) -> Iterator[Finding]:
        calls_pow2 = any(
            isinstance(c, ast.Call) and (
                (isinstance(c.func, ast.Name) and c.func.id == "next_pow2")
                or (isinstance(c.func, ast.Attribute)
                    and c.func.attr == "next_pow2"))
            for c in ast.walk(fn))
        if not calls_pow2:
            return
        for call in ast.walk(fn):
            if not (isinstance(call, ast.Call) and _is_replace(call)):
                continue
            for field in _CAPACITY_FIELDS:
                val = _kw(call, field)
                if val is None:
                    continue
                bucketed = isinstance(val, ast.Call) and (
                    (isinstance(val.func, ast.Name)
                     and val.func.id == "next_pow2")
                    or (isinstance(val.func, ast.Attribute)
                        and val.func.attr == "next_pow2"))
                if not bucketed:
                    yield self.finding(
                        ctx, val,
                        f"capacity field `{field}` is set without "
                        f"`next_pow2(...)` in a bucketing function — one "
                        f"un-bucketed field breaks pow2 monotonicity "
                        f"(spec_fits may flap between hits and misses)")
