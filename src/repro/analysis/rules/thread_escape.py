"""FIG006 — cross-thread escape: shared mutable attrs must be READ under
the lock too.

FIG005 checks *writes*; the bugs it structurally cannot see are unlocked
**reads** of shared mutable state — a ``stats()`` that reads two counters
outside the lock can observe a torn pair, and an unlocked
``if self._threads is not None`` double-check races the locked writer. This
rule closes that gap for the same class population FIG005 covers (classes
whose ``__init__`` creates a lock attribute):

every attribute of such a class that is **mutable** (written or mutated
outside ``__init__``) must be read/mutated only

  * lexically inside a ``with self.<lock>`` region (any of the class's
    locks, matching FIG005's approximation — the runtime sanitizer checks
    the *right* lock), or
  * in a private method whose every in-class call site is lock-held
    (a small interprocedural fixed point: ``_evict_lru`` is only called
    from ``_dispatch``'s locked region, so its accesses count as locked), or
  * via an attribute that is exempt: immutable (only ever assigned in
    ``__init__``), constructed from a thread-safe factory
    (``queue.Queue``, ``threading.Event`` / ``Semaphore``, locks), or
    explicitly annotated in a class-level ``_san_atomic`` tuple (the same
    annotation the runtime race detector honours).

Methods whose bound reference escapes (``Thread(target=self._loop)``) are
thread entries and never inherit a caller's lock. Writes are *not*
re-reported here — they stay FIG005's finding.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from ..framework import FileContext, Finding, Rule, Severity
from .lock_discipline import (_EXEMPT_METHODS, _LOCK_FACTORIES,
                              _lock_attrs, _self_attr_target)

#: Constructors whose instances are internally synchronized — attributes
#: bound to one of these in __init__ may be used lock-free. Locks are listed
#: too: the lock attributes themselves are never findings.
_THREADSAFE_FACTORIES = _LOCK_FACTORIES | frozenset({
    "Queue", "LifoQueue", "PriorityQueue", "SimpleQueue",
    "Event", "Semaphore", "BoundedSemaphore", "Barrier", "local",
    "finalize",
})

#: Method names that mutate their receiver in place — `self.x.append(...)`
#: on a plain container is a mutation of shared state.
_MUTATORS = frozenset({
    "add", "append", "appendleft", "extend", "insert", "pop", "popleft",
    "popitem", "remove", "discard", "clear", "update", "setdefault",
    "move_to_end", "sort", "reverse", "subtract",
})


@dataclasses.dataclass
class _Access:
    method: str
    attr: str
    kind: str          # "read" | "mutcall"
    locked: bool       # lexically, at the access site
    node: ast.AST


@dataclasses.dataclass
class _ClassFacts:
    locks: set[str]
    methods: set[str]
    atomic: set[str]
    init_factories: dict[str, str]          # attr -> factory base name
    mutated_outside_init: set[str]
    accesses: list[_Access]
    call_sites: dict[str, list[tuple[bool, str]]]  # callee -> (locked, caller)
    thread_entries: set[str]


def _base_callee(ctx: FileContext, call: ast.Call) -> str:
    dotted = ctx.resolve(call.func)
    return dotted.rsplit(".", 1)[-1] if dotted else ""


def _atomic_attrs(cls: ast.ClassDef) -> set[str]:
    """Class-level ``_san_atomic = ("attr", ...)`` literal annotation."""
    out: set[str] = set()
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "_san_atomic"
                for t in stmt.targets):
            if isinstance(stmt.value, (ast.Tuple, ast.List, ast.Set)):
                out |= {e.value for e in stmt.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
    return out


def _init_factories(ctx: FileContext, cls: ast.ClassDef) -> dict[str, str]:
    out: dict[str, str] = {}
    for stmt in cls.body:
        if not (isinstance(stmt, ast.FunctionDef)
                and stmt.name == "__init__"):
            continue
        for node in ast.walk(stmt):
            value = getattr(node, "value", None)
            if not (isinstance(node, (ast.Assign, ast.AnnAssign))
                    and isinstance(value, ast.Call)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            base = _base_callee(ctx, value)
            for tgt in targets:
                attr = _self_attr_target(tgt)
                if attr is not None and attr not in out:
                    out[attr] = base
    return out


def _iter_own_exprs(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Expression nodes evaluated BY this statement (child statements and
    deferred bodies — nested defs, lambdas — excluded; comprehensions run
    eagerly, so their subtrees are included)."""
    stack = [c for c in ast.iter_child_nodes(stmt)
             if not isinstance(c, (ast.stmt, ast.ExceptHandler,
                                   ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda))
             and not (hasattr(ast, "match_case")
                      and isinstance(c, ast.match_case))]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.Lambda, ast.stmt)):
                continue
            stack.append(child)


class _MethodScanner:
    """One pass over a method body, FIG005-style lexical lock tracking."""

    def __init__(self, ctx: FileContext, facts: _ClassFacts,
                 method: ast.FunctionDef) -> None:
        self.ctx = ctx
        self.facts = facts
        self.method = method.name
        self.in_init = method.name in _EXEMPT_METHODS
        for stmt in method.body:
            self._walk(stmt, locked=False)

    def _walk(self, stmt: ast.stmt, locked: bool) -> None:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            holds = locked or self._with_holds_lock(stmt)
            for item in stmt.items:
                self._scan_expr_tree(item.context_expr, locked)
            for inner in stmt.body:
                self._walk(inner, holds)
            return
        self._record_writes(stmt)
        for expr in [stmt]:
            self._scan_stmt_exprs(expr, locked)
        for inner in ast.iter_child_nodes(stmt):
            if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue  # deferred bodies: their own thread story
            if isinstance(inner, ast.stmt):
                self._walk(inner, locked)
            elif isinstance(inner, ast.ExceptHandler) or (
                    hasattr(ast, "match_case")
                    and isinstance(inner, ast.match_case)):
                for s in inner.body:
                    self._walk(s, locked)

    def _with_holds_lock(self, stmt) -> bool:
        for item in stmt.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                expr = expr.func
            attr = _self_attr_target(expr)
            if attr in self.facts.locks:
                return True
        return False

    def _record_writes(self, stmt: ast.stmt) -> None:
        """Attrs written/augmented by this statement — FIG005's territory;
        here they only mark the attr as mutable."""
        targets: list[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, ast.AugAssign):
            targets = [stmt.target]
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        for tgt in targets:
            for t in (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                      else [tgt]):
                attr = _self_attr_target(t)
                if attr is not None and not self.in_init:
                    self.facts.mutated_outside_init.add(attr)

    # -- expression scanning -------------------------------------------------

    def _scan_stmt_exprs(self, stmt: ast.stmt, locked: bool) -> None:
        consumed = self._write_value_nodes(stmt)
        for node in _iter_own_exprs(stmt):
            self._visit_expr(node, locked, consumed)

    def _scan_expr_tree(self, expr: ast.AST, locked: bool) -> None:
        stack, consumed = [expr], set()
        while stack:
            node = stack.pop()
            self._visit_expr(node, locked, consumed)
            for child in ast.iter_child_nodes(node):
                if not isinstance(child, (ast.Lambda, ast.stmt)):
                    stack.append(child)

    @staticmethod
    def _write_value_nodes(stmt: ast.stmt) -> set[int]:
        """The ``self.attr`` Load nodes that are really write receivers —
        ``self._jitted[key] = fn`` loads `_jitted` to store into it; that is
        FIG005's write, not a FIG006 read."""
        out: set[int] = set()
        targets: list[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.Delete):
            targets = list(stmt.targets)
        for tgt in targets:
            for t in (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                      else [tgt]):
                if isinstance(t, ast.Subscript):
                    t = t.value
                if isinstance(t, ast.Attribute):
                    out.add(id(t))
        return out

    def _visit_expr(self, node: ast.AST, locked: bool,
                    consumed: set[int]) -> None:
        facts = self.facts
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            recv = node.func.value
            if isinstance(recv, ast.Name) and recv.id == "self" \
                    and node.func.attr in facts.methods:
                # self.method(...) — a call site, not a state access.
                facts.call_sites.setdefault(node.func.attr, []).append(
                    (locked, self.method))
                consumed.add(id(node.func))
                return
            attr = _self_attr_target(recv)
            if attr is not None and node.func.attr in _MUTATORS:
                consumed.add(id(node.func))
                consumed.add(id(recv))
                if not self.in_init:
                    facts.mutated_outside_init.add(attr)
                    facts.accesses.append(_Access(
                        self.method, attr, "mutcall", locked, node))
                return
        if isinstance(node, ast.Attribute) and id(node) not in consumed \
                and isinstance(node.ctx, ast.Load):
            attr = _self_attr_target(node)
            if attr is None:
                return
            if attr in facts.methods:
                # A bound-method reference escaping (Thread target etc.):
                # that method can run on any thread, unlocked.
                facts.thread_entries.add(attr)
                return
            if not self.in_init:
                facts.accesses.append(_Access(
                    self.method, attr, "read", locked, node))


def _collect(ctx: FileContext, cls: ast.ClassDef) -> _ClassFacts | None:
    locks = _lock_attrs(ctx, cls)
    if not locks:
        return None
    methods = {m.name for m in cls.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    facts = _ClassFacts(
        locks=locks, methods=methods, atomic=_atomic_attrs(cls),
        init_factories=_init_factories(ctx, cls),
        mutated_outside_init=set(), accesses=[], call_sites={},
        thread_entries=set())
    for method in cls.body:
        if isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _MethodScanner(ctx, facts, method)
    return facts


def _locked_methods(facts: _ClassFacts) -> set[str]:
    """Fixed point: private methods whose every in-class call site runs with
    a lock held (lexically, or from an already-locked method)."""
    locked: set[str] = set()
    changed = True
    while changed:
        changed = False
        for name in facts.methods:
            if not name.startswith("_") or name.startswith("__"):
                continue  # public/dunder methods are callable from anywhere
            if name in locked or name in facts.thread_entries:
                continue
            sites = facts.call_sites.get(name)
            if not sites:
                continue
            if all(lex or caller in locked for lex, caller in sites):
                locked.add(name)
                changed = True
    return locked


class ThreadEscapeRule(Rule):
    rule_id = "FIG006"
    severity = Severity.ERROR
    fix_hint = ("read the attribute under its owning lock (`with "
                "self._lock:`), make it immutable (assign only in __init__), "
                "bind it to a thread-safe type (queue.Queue, Event, "
                "Semaphore), or annotate it in a class-level `_san_atomic` "
                "tuple if the lock-free access is intentional")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            facts = _collect(ctx, cls)
            if facts is None:
                continue
            locked_methods = _locked_methods(facts)
            if self.program is not None:
                # The fixed point above assumes a private method's callers
                # are all in-class. figaro-flow makes that a real query:
                # any `X.method` reference outside the class (another
                # module poking the helper) voids the locked-helper
                # exemption for that method.
                locked_methods = {
                    m for m in locked_methods
                    if not self.program.external_method_refs(cls, m)}
            for acc in facts.accesses:
                if acc.locked or acc.method in locked_methods:
                    continue
                attr = acc.attr
                if attr in facts.locks or attr in facts.atomic:
                    continue
                if attr not in facts.mutated_outside_init:
                    continue  # immutable after construction: safe to read
                if facts.init_factories.get(attr) in _THREADSAFE_FACTORIES:
                    continue
                verb = ("reads" if acc.kind == "read"
                        else "mutates (in place)")
                yield self.finding(
                    ctx, acc.node,
                    f"{cls.name}.{acc.method} {verb} shared mutable "
                    f"`self.{attr}` outside a `with self.<lock>` region "
                    f"(locks: {', '.join(sorted(facts.locks))}) — "
                    f"cross-thread escape")
