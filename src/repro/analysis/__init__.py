"""figaro-lint: repo-specific static analysis for the invariants the paper
reproduction lives or dies by.

The headline numerical claim — rounding errors tracking *database* size
rather than join size — and the headline performance claims — zero-retrace
plan refreshes, one executable per static signature — are invariants of the
implementation, not of any one function. Three of the last four PRs fixed
hand-found violations of exactly these invariants (float32 count overflow
past 2^24, a hardcoded-f32 kernel accumulator, a dtype-dropping
normalize_sign). This package encodes them as AST-based rules so CI catches
the next violation before a human has to:

  FIG001  compat-pin        version-sensitive JAX symbols (shard_map,
                            make_mesh, AxisType, AbstractMesh, axis_size)
                            imported anywhere outside repro/compat.py
  FIG002  retrace-hazard    `_STATIC` dispatch-flag sets drifting out of
                            sync with impl keyword lists, static_argnames
                            naming non-parameters or unhashable defaults,
                            jitted closures capturing plan objects
  FIG003  dtype-drift       hardcoded narrowing dtype literals in core/ and
                            kernels/ bodies (the I/O-dtype policy derives
                            from inputs), count accumulation narrower
                            than f64
  FIG004  pallas-kernel     pallas_call sites not routing interpret=
                            through kernels/_platform.resolve_interpret,
                            grids that floor-divide unpadded dims,
                            AUTOTUNE block sizes past the VMEM budget model
  FIG005  lock-discipline   mutable attributes of lock-owning classes
                            (AsyncFigaroServer, PlanHolder, FigaroEngine)
                            written outside a `with self._lock` region
  FIG006  thread-escape     shared mutable state read/mutated without the
                            owning lock from thread-reachable methods
  FIG007  san-routing       sanitizer findings bypassing the SanitizerState
                            registry/reporting chain
  FIG008  jaxfree-planner   jax imports leaking into the planner/analysis
                            layers that must stay stdlib-only
  FIG009  host-sync         np.asarray/float()/.item()/.tolist()/
                            .block_until_ready()/jax.device_get on a traced
                            value transitively reachable from a jit region
                            (figaro-flow: call graph + dataflow fixpoint)
  FIG010  trace-effects     self./global/closure writes, print, counter
                            bumps inside traced-context functions (lock-
                            guarded trace bookkeeping exempted)
  FIG011  donation          a buffer re-read after passing through the
                            engine's donated data position (straight-line
                            or loop re-dispatch)
  FIG012  slab-layout       symbolic proofs over PlanSpec/bucket_spec/
                            SlabBand arithmetic: row bands partition
                            capacity rows, column prefix sums close, pow2
                            bucketing stays canonical and total

FIG009–FIG011 ride on **figaro-flow** (`repro.analysis.callgraph` +
`repro.analysis.dataflow`): a whole-program call graph with jit-region
inference (engine `_<kind>_impl` bodies, `jax.jit`/`pallas_call` arguments,
`shard_map` bodies, transitively) and a per-function traced/concrete/host
taint summary composed to a fixpoint. Inspect the classification with

    python -m repro.analysis --report callgraph [--dot graph.dot] src/

Pure stdlib `ast` — no third-party imports, so the CLI runs in CI without
installing jax.  Run it:

    python -m repro.analysis [--baseline analysis_baseline.json] src/

Suppress a deliberate violation on its own line, with a reason:

    return jax.jit(fn)  # figaro-lint: disable=FIG002 -- plan-closed on purpose

or file-wide near the top of the module:

    # figaro-lint: disable-file=FIG003 -- f32 accumulate is the flash standard

See `repro.analysis.framework` for the rule API and `examples/quickstart.py`
section 9 for a walkthrough.
"""

from .baseline import Baseline, load_baseline  # noqa: F401
from .callgraph import CallGraph, Program  # noqa: F401
from .dataflow import Dataflow  # noqa: F401
from .framework import (Finding, Rule, Severity, analyze_paths,  # noqa: F401
                        analyze_source, load_program)
from .imports import ImportGraph, unused_report  # noqa: F401
from .rules import all_rules  # noqa: F401

__all__ = ["Finding", "Rule", "Severity", "analyze_paths", "analyze_source",
           "all_rules", "Baseline", "load_baseline", "ImportGraph",
           "unused_report", "CallGraph", "Program", "Dataflow",
           "load_program"]
