"""figaro-lint command line: `python -m repro.analysis [options] paths...`.

Exit status: 0 when every finding is baselined (or ``--warn-only``), 1 when
new findings exist, and 1 when the baseline has gone stale (entries whose
violation was fixed — the committed baseline must stay exact).

Common invocations:

    python -m repro.analysis src/                       # raw findings
    python -m repro.analysis --baseline analysis_baseline.json src/   # CI
    python -m repro.analysis --warn-only benchmarks/    # advisory sweep
    python -m repro.analysis --report unused            # dead-module report
    python -m repro.analysis --report callgraph src/    # figaro-flow graph
    python -m repro.analysis --report callgraph --dot g.dot src/
    python -m repro.analysis --write-baseline analysis_baseline.json src/
"""

from __future__ import annotations

import argparse
import json
import sys

from .baseline import empty_baseline, load_baseline, write_baseline
from .framework import analyze_paths, load_program
from .imports import unused_report
from .rules import all_rules


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="figaro-lint: AST checks for the repro tree's "
                    "compat/retrace/dtype/pallas/lock invariants.")
    p.add_argument("paths", nargs="*", default=None,
                   help="files or directories to analyze (default: src/)")
    p.add_argument("--baseline", metavar="FILE",
                   help="accepted-findings file; only NON-baselined findings "
                        "fail the run")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write the current findings to FILE (preserving "
                        "justifications from --baseline) and exit 0")
    p.add_argument("--json", action="store_true",
                   help="machine-readable output")
    p.add_argument("--report", choices=("findings", "unused", "callgraph"),
                   default="findings",
                   help="findings (default), the unused-module report, or "
                        "the figaro-flow call graph with traced/host "
                        "classification")
    p.add_argument("--dot", metavar="FILE",
                   help="with --report callgraph: also write the graph as "
                        "Graphviz DOT to FILE")
    p.add_argument("--warn-only", action="store_true",
                   help="report findings but always exit 0")
    p.add_argument("--root", default=None,
                   help="directory findings' paths are relative to "
                        "(default: cwd)")
    p.add_argument("--src-root", default="src",
                   help="package root for --report unused (default: src)")
    return p


def _run_findings(args) -> int:
    paths = args.paths or ["src"]
    findings = analyze_paths(paths, rules=all_rules(), root=args.root)
    baseline = load_baseline(args.baseline) if args.baseline \
        else empty_baseline()

    if args.write_baseline:
        write_baseline(args.write_baseline, findings, previous=baseline)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    new, baselined = baseline.split(findings)
    stale = baseline.stale(findings)

    if args.json:
        print(json.dumps({
            "findings": [f.to_json() for f in new],
            "baselined": len(baselined),
            "stale_baseline": [list(fp) for fp in stale],
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        if baselined:
            print(f"-- {len(baselined)} baselined finding(s) suppressed")
        for rule, path, message in stale:
            print(f"-- stale baseline entry (violation fixed — delete it): "
                  f"{rule} {path}: {message}")
        print(f"figaro-lint: {len(new)} finding(s)"
              + (f", {len(stale)} stale baseline entr"
                 + ("y" if len(stale) == 1 else "ies") if stale else ""))
    if args.warn_only:
        return 0
    return 1 if (new or stale) else 0


def _run_unused(args) -> int:
    report = unused_report(src_root=args.src_root)
    if args.json:
        print(json.dumps(report, indent=2))
        return 0
    print(f"import-graph roots: {', '.join(report['roots'])}")
    for cls in ("facade", "entrypoint", "external-only", "orphan"):
        mods = [m for m, i in report["modules"].items()
                if i["class"] == cls]
        if not mods:
            continue
        print(f"\n{cls} ({len(mods)}):")
        for m in mods:
            extra = ""
            if cls == "external-only":
                refs = report["modules"][m].get("referenced_by", [])
                extra = f"  <- {', '.join(refs[:2])}" + \
                        (" ..." if len(refs) > 2 else "")
            print(f"  {m}{extra}")
    orphans = report["orphans"]
    print(f"\n{len(orphans)} orphan module(s)"
          + (" — dead code, safe to delete" if orphans else ""))
    return 0


def _run_callgraph(args) -> int:
    paths = args.paths or ["src"]
    program = load_program(paths, root=args.root)
    graph = program.graph
    if args.json:
        print(json.dumps(graph.to_json(), indent=2))
    else:
        print(graph.render_text())
    if args.dot:
        with open(args.dot, "w", encoding="utf-8") as fh:
            fh.write(graph.render_dot())
        if not args.json:
            print(f"-- DOT graph written to {args.dot}")
    return 0


def main(argv: list[str] | None = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.report == "unused":
        return _run_unused(args)
    if args.report == "callgraph":
        return _run_callgraph(args)
    return _run_findings(args)


if __name__ == "__main__":
    sys.exit(main())
