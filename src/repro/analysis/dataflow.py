"""figaro-flow dataflow: per-function taint summaries to a fixpoint.

Forward abstract interpretation over the functions `callgraph` marked
*traced-context*. The lattice per value:

  * **traced** — derived from a jit/pallas/shard_map argument: a tracer (or
    kernel ref) at trace time. Calling ``np.asarray`` / ``float()`` /
    ``.item()`` on it forces a host sync under trace — FIG009's sink.
  * **concrete** — a trace-time constant: static (kwonly/`static_argnames`)
    parameters, closure variables of a traced function (closed over *before*
    tracing), metadata (``x.shape``, ``x.dtype``, ``plan.spec``), results of
    shape-only calls (``len``, ``np.shape``, ``np.result_type``).
  * **host-escaping** — was traced, then passed through a sync sink; the sink
    itself is the finding, downstream uses are not re-reported.

A value's abstract state is ``AVal(traced, deps, host)`` where ``deps`` are
the *parameter names* the value inherits taint from — so one local pass per
function yields a reusable summary (params → returns), and the driver
composes summaries over the call graph: call sites push concrete taint into
callee parameter sets, return taint flows back through ``deps``, repeated to
a (monotone, hence terminating) fixpoint.

Precision choices are driven by the real tree: tuple targets of
``zip``/``enumerate`` map taint elementwise (``for sp, ix, d in
zip(plan.spec.nodes, plan.index, data)`` keeps ``sp`` concrete), a
subscript-store of a traced value taints the containing local, and unknown
calls (``jnp.*``) join their argument taints.
"""

from __future__ import annotations

import ast
import dataclasses

from .callgraph import CallGraph, FunctionInfo, _last_component

#: Attribute reads that yield trace-time constants even on a tracer/pytree:
#: array metadata, and the repo's plan convention (`plan.spec` is static aux
#: data of the FigaroPlan pytree — index/data leaves are the traced half).
_META_ATTRS = frozenset({
    "shape", "dtype", "ndim", "size", "itemsize", "aval", "sharding", "spec",
})

#: numpy functions that only touch metadata — not host syncs.
_NP_META = frozenset({
    "shape", "ndim", "size", "dtype", "result_type", "promote_types",
    "can_cast", "issubdtype", "isscalar", "iinfo", "finfo", "index_exp",
})

#: Builtins that return trace-time constants for any argument.
_CONCRETE_BUILTINS = frozenset({
    "len", "range", "isinstance", "issubclass", "type", "repr", "id",
    "callable", "hasattr",
})

#: Builtins that force a concrete value out of a tracer: host sync.
_SYNC_BUILTINS = frozenset({"float", "int", "bool", "complex"})

#: Method calls that block on device values.
_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})


@dataclasses.dataclass(frozen=True)
class AVal:
    traced: bool = False
    deps: frozenset = frozenset()
    host: bool = False


_CONCRETE = AVal()


def _join(*vals: AVal) -> AVal:
    return AVal(traced=any(v.traced for v in vals),
                deps=frozenset().union(*(v.deps for v in vals)),
                host=any(v.host for v in vals))


@dataclasses.dataclass(frozen=True)
class Sink:
    qname: str          # traced-context function containing the sink
    node: ast.AST
    op: str             # "np.asarray", "float()", ".item()", ...
    expr: str           # offending expression, unparsed (truncated)


@dataclasses.dataclass
class DataflowResult:
    #: function qname -> parameter names proven traced at some call site.
    param_traced: dict[str, set[str]]
    #: function qname -> summary of its return value.
    returns: dict[str, AVal]
    #: every host-sync sink found in a traced-context function.
    sinks: list[Sink]

    def returns_class(self, qname: str) -> str:
        ret = self.returns.get(qname)
        if ret is None:
            return "concrete"
        traced = ret.traced or any(d in self.param_traced.get(qname, ())
                                   for d in ret.deps)
        if ret.host:
            return "host-escaping"
        return "traced" if traced else "concrete"


class Dataflow:
    """Fixpoint driver: local passes over every traced-context function."""

    _MAX_SWEEPS = 20   # taint is monotone; real depth is the call-chain depth

    def __init__(self, graph: CallGraph) -> None:
        self.graph = graph
        self.param_traced: dict[str, set[str]] = {}
        self.returns: dict[str, AVal] = {}

    def run(self) -> DataflowResult:
        domain = [q for q in self.graph.traced if q in self.graph.functions]
        for q in domain:
            self.param_traced.setdefault(q, set())
        for q, root in self.graph.roots.items():
            fi = self.graph.functions.get(q)
            if fi is None:
                continue
            params = fi.params()
            if fi.is_method():
                params = params[1:]
            self.param_traced[q] |= {p for p in params if p not in root.static}
            self.param_traced[q] |= {p for p in fi.kwonly()
                                     if p not in root.static
                                     and root.kind != "engine-impl"}
        sinks: list[Sink] = []
        for _ in range(self._MAX_SWEEPS):
            changed = False
            sinks = []
            for q in domain:
                fn_pass = _FnPass(self, self.graph.functions[q])
                fn_pass.run()
                sinks.extend(fn_pass.sinks)
                changed |= fn_pass.changed
            if not changed:
                break
        return DataflowResult(param_traced=self.param_traced,
                              returns=self.returns, sinks=sinks)


class _FnPass:
    """One forward pass over one function body. The body is executed twice so
    loop-carried taint (an accumulator assigned late, read early) converges;
    env updates are joins, so the second iteration is monotone."""

    def __init__(self, df: Dataflow, fi: FunctionInfo) -> None:
        self.df = df
        self.graph = df.graph
        self.fi = fi
        self.mod = df.graph.modules[fi.module]
        self.env: dict[str, AVal] = {}
        self.ret = _CONCRETE
        self.sinks: list[Sink] = []
        self.changed = False

    def run(self) -> None:
        a = self.fi.node.args
        mine = self.df.param_traced.setdefault(self.fi.qname, set())
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg in ("self", "cls"):
                self.env[p.arg] = _CONCRETE
            else:
                self.env[p.arg] = AVal(traced=p.arg in mine,
                                       deps=frozenset({p.arg}))
        for p in (a.vararg, a.kwarg):
            if p is not None:
                self.env[p.arg] = AVal(traced=p.arg in mine,
                                       deps=frozenset({p.arg}))
        for _ in range(2):
            self.sinks = []
            self.ret = _CONCRETE
            for stmt in self.fi.node.body:
                self._exec(stmt)
        old = self.df.returns.get(self.fi.qname, _CONCRETE)
        new = _join(old, self.ret)
        if new != old:
            self.df.returns[self.fi.qname] = new
            self.changed = True

    def _is_traced(self, aval: AVal) -> bool:
        mine = self.df.param_traced.get(self.fi.qname, set())
        return aval.traced or any(d in mine for d in aval.deps)

    # -- statements ----------------------------------------------------------

    def _exec(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested defs are their own dataflow functions
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.ret = _join(self.ret, self._ev(stmt.value))
            return
        if isinstance(stmt, ast.Assign):
            val = self._ev(stmt.value)
            for tgt in stmt.targets:
                self._assign(tgt, val, stmt.value)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._assign(stmt.target, self._ev(stmt.value), stmt.value)
            return
        if isinstance(stmt, ast.AugAssign):
            val = self._ev(stmt.value)
            if isinstance(stmt.target, ast.Name):
                self.env[stmt.target.id] = _join(
                    self.env.get(stmt.target.id, _CONCRETE), val)
            else:
                self._assign(stmt.target, val, stmt.value)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._assign_iter_target(stmt.target, stmt.iter)
            for s in stmt.body + stmt.orelse:
                self._exec(s)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                v = self._ev(item.context_expr)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars, v, item.context_expr)
            for s in stmt.body:
                self._exec(s)
            return
        if isinstance(stmt, ast.If) or isinstance(stmt, ast.While):
            self._ev(stmt.test)
            for s in stmt.body + stmt.orelse:
                self._exec(s)
            return
        if isinstance(stmt, ast.Try):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                self._exec(s)
            for handler in stmt.handlers:
                for s in handler.body:
                    self._exec(s)
            return
        if isinstance(stmt, ast.Expr):
            self._ev(stmt.value)
            return
        # Raise/Assert/Delete/Global/...: evaluate any child expressions so
        # sinks inside them are still seen.
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._ev(child)

    def _assign(self, tgt: ast.AST, val: AVal, src: ast.AST | None) -> None:
        if isinstance(tgt, ast.Name):
            self.env[tgt.id] = val
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            elems = self._elements(src, len(tgt.elts)) if src is not None \
                else None
            for i, elt in enumerate(tgt.elts):
                self._assign(elt, elems[i] if elems else val, None)
        elif isinstance(tgt, (ast.Subscript, ast.Attribute)):
            # Storing a traced value INTO a container taints the container —
            # `out[i] = segment_sum(...)` makes `out` traced.
            base = tgt.value
            while isinstance(base, (ast.Subscript, ast.Attribute)):
                base = base.value
            if isinstance(base, ast.Name):
                self.env[base.id] = _join(
                    self.env.get(base.id, _CONCRETE), val)
        elif isinstance(tgt, ast.Starred):
            self._assign(tgt.value, val, None)

    def _assign_iter_target(self, tgt: ast.AST, it: ast.expr) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            elems = self._elements(it, len(tgt.elts))
            if elems is not None:
                for i, elt in enumerate(tgt.elts):
                    self._assign(elt, elems[i], None)
                return
        self._assign(tgt, self._ev(it), None)

    def _elements(self, src: ast.AST,
                  count: int) -> list[AVal] | None:
        """Elementwise avals for tuple targets of zip()/enumerate()."""
        if not isinstance(src, ast.Call) or not isinstance(src.func, ast.Name):
            return None
        if src.func.id == "zip":
            vals = [self._ev(a) for a in src.args]
            if len(vals) < count:
                vals += [_CONCRETE] * (count - len(vals))
            return vals[:count]
        if src.func.id == "enumerate" and src.args:
            inner = self._elements(src.args[0], count - 1)
            if inner is not None:
                return [_CONCRETE] + inner
            return [_CONCRETE] + [self._ev(src.args[0])] * (count - 1)
        return None

    # -- expressions ---------------------------------------------------------

    def _ev(self, node: ast.AST) -> AVal:
        if isinstance(node, ast.Name):
            # Unbound names are module globals or closure variables — both
            # are trace-time constants of a traced function (closed over or
            # imported before tracing).
            return self.env.get(node.id, _CONCRETE)
        if isinstance(node, ast.Constant):
            return _CONCRETE
        if isinstance(node, ast.Attribute):
            base = self._ev(node.value)
            if node.attr in _META_ATTRS:
                return _CONCRETE
            return base
        if isinstance(node, ast.Subscript):
            return _join(self._ev(node.value), self._ev(node.slice))
        if isinstance(node, ast.Call):
            return self._ev_call(node)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return _join(_CONCRETE, *[self._ev(e) for e in node.elts])
        if isinstance(node, ast.Dict):
            parts = [self._ev(v) for v in node.values if v is not None]
            parts += [self._ev(k) for k in node.keys if k is not None]
            return _join(_CONCRETE, *parts)
        if isinstance(node, (ast.BinOp,)):
            return _join(self._ev(node.left), self._ev(node.right))
        if isinstance(node, ast.BoolOp):
            return _join(*[self._ev(v) for v in node.values])
        if isinstance(node, ast.UnaryOp):
            return self._ev(node.operand)
        if isinstance(node, ast.Compare):
            return _join(self._ev(node.left),
                         *[self._ev(c) for c in node.comparators])
        if isinstance(node, ast.IfExp):
            self._ev(node.test)
            return _join(self._ev(node.body), self._ev(node.orelse))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            for gen in node.generators:
                self._assign_iter_target(gen.target, gen.iter)
                for cond in gen.ifs:
                    self._ev(cond)
            if isinstance(node, ast.DictComp):
                return _join(self._ev(node.key), self._ev(node.value))
            return self._ev(node.elt)
        if isinstance(node, ast.Lambda):
            # Inlined into the enclosing traced function: params of a lambda
            # handed to vmap/scan receive traced slices.
            for p in node.args.args + node.args.kwonlyargs:
                self.env.setdefault(p.arg, AVal(traced=True))
            self._ev(node.body)
            return _CONCRETE
        if isinstance(node, ast.Starred):
            return self._ev(node.value)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self._ev(child)
            return _CONCRETE
        if isinstance(node, ast.NamedExpr):
            val = self._ev(node.value)
            self._assign(node.target, val, node.value)
            return val
        parts = [self._ev(c) for c in ast.iter_child_nodes(node)
                 if isinstance(c, ast.expr)]
        return _join(_CONCRETE, *parts)

    def _ev_call(self, node: ast.Call) -> AVal:
        args = [self._ev(a) for a in node.args]
        kwargs = {kw.arg: self._ev(kw.value) for kw in node.keywords}
        func = node.func

        # Method-style sync sinks: `x.item()`, `.tolist()`,
        # `.block_until_ready()` on a traced receiver.
        if isinstance(func, ast.Attribute) and func.attr in _SYNC_METHODS:
            recv = self._ev(func.value)
            if self._is_traced(recv):
                self._sink(node, f".{func.attr}()", func.value)
                return AVal(host=True)
            return recv

        callee = self.graph.resolve_callable(self.fi, self.mod, func)
        if callee is not None and callee in self.graph.functions:
            return self._ev_program_call(node, callee, args, kwargs)

        dotted = self.graph.dotted(self.mod, func) or ""
        head = dotted.split(".", 1)[0]
        last = _last_component(dotted)
        joined = _join(_CONCRETE, *args, *kwargs.values())

        if head == "numpy":
            if last in _NP_META:
                return _CONCRETE
            if self._is_traced(joined):
                self._sink(node, f"np.{last}", node)
                return AVal(host=True)
            return _CONCRETE
        if dotted == "jax.device_get":
            if self._is_traced(joined):
                self._sink(node, "jax.device_get", node)
                return AVal(host=True)
            return _CONCRETE
        if isinstance(func, ast.Name):
            if func.id in _SYNC_BUILTINS and args \
                    and self._is_traced(args[0]):
                self._sink(node, f"{func.id}()", node.args[0])
                return AVal(host=True)
            if func.id in _CONCRETE_BUILTINS:
                return _CONCRETE
            if func.id == "getattr" and len(node.args) >= 2 \
                    and isinstance(node.args[1], ast.Constant) \
                    and node.args[1].value in _META_ATTRS:
                return _CONCRETE
        # Unknown call (jnp.*, jax.lax.*, external libs): taint flows
        # arguments -> result, no sync implied. A method call's receiver is
        # an argument too (`x.sum()` is as traced as x).
        if isinstance(func, ast.Attribute):
            joined = _join(joined, self._ev(func.value))
        return joined

    def _ev_program_call(self, node: ast.Call, callee: str,
                         args: list[AVal],
                         kwargs: dict[str | None, AVal]) -> AVal:
        cf = self.graph.functions[callee]
        params = cf.params()
        if cf.is_method() and isinstance(node.func, ast.Attribute):
            params = params[1:]
        mapped: dict[str, AVal] = {}
        for i, aval in enumerate(args):
            if isinstance(node.args[i], ast.Starred):
                # *data: every remaining positional param sees the splat.
                for p in params[i:]:
                    mapped[p] = _join(mapped.get(p, _CONCRETE), aval)
                break
            if i < len(params):
                mapped[params[i]] = aval
        valid = set(params) | set(cf.kwonly())
        for name, aval in kwargs.items():
            if name in valid:
                mapped[name] = aval
        callee_traced = self.df.param_traced.setdefault(callee, set())
        for pname, aval in mapped.items():
            if self._is_traced(aval) and pname not in callee_traced:
                callee_traced.add(pname)
                self.changed = True
        ret = self.df.returns.get(callee, _CONCRETE)
        traced = ret.traced or any(
            self._is_traced(mapped[d]) for d in ret.deps if d in mapped)
        return AVal(traced=traced, host=ret.host)

    def _sink(self, node: ast.AST, op: str, expr: ast.AST) -> None:
        try:
            text = ast.unparse(expr)
        except Exception:   # pragma: no cover - unparse is total on 3.9+
            text = "<expr>"
        if len(text) > 60:
            text = text[:57] + "..."
        self.sinks.append(Sink(qname=self.fi.qname, node=node, op=op,
                               expr=text))
