"""figaro-flow: the whole-program call graph and jit-region inference.

figaro-lint's FIG001–FIG008 are per-file; the invariants the paper's claims
ride on are not. A helper three calls below `_qr_impl` that syncs to host, or
a utility that mutates module state under `jit`, is invisible to any one-file
rule. This module builds the cross-file layer those rules run on:

  * `Program`   — every `FileContext` of one analysis run plus the lazily
    built call graph / dataflow; the driver hands it to `Rule.check_program`.
  * `CallGraph` — functions indexed by qualified name (``module:Class.method``
    / ``module:outer.<locals>.inner``), call edges resolved through
    module-level names, ``self.method`` dispatch, module-level instances
    (``STATE = SanitizerState()``), local function bindings (including
    ``functools.partial``), and import aliases — absolute aliases from
    `FileContext.aliases`, relative imports resolved by reusing
    `imports.ImportGraph._from_base`.
  * jit-region inference — every function transitively reachable from an
    engine ``_<kind>_impl`` body, a ``jax.jit`` / ``pl.pallas_call`` argument
    (call or decorator form, `functools.partial` unwrapped), or a
    ``shard_map`` body is marked *traced-context*, with the root→function
    chain kept for finding attribution.

Resolution is best-effort and sound-for-the-repo rather than general Python:
a name that cannot be resolved statically simply contributes no edge. Pure
stdlib, like everything under `repro.analysis`.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, Iterator

from .framework import FileContext
from .imports import ImportGraph

#: Engine dispatch-impl methods are jit roots by contract: `_make_jitted`
#: wraps `_<kind>_impl` in `jax.jit` with the kind's `_STATIC` kwonly names.
_IMPL_RE = re.compile(r"^_\w+_impl$")

#: Lock factories (mirrors rules/lock_discipline._LOCK_FACTORIES without the
#: import cycle risk — the rules package imports this module's consumers).
_LOCK_FACTORY_NAMES = frozenset({"Lock", "RLock", "Condition", "san_lock"})


def module_name_of(path: str) -> str:
    """Dotted module name for a repo-relative posix path.

    ``src/repro/core/engine.py`` → ``repro.core.engine``; paths outside a
    ``src/`` layout (tests, fixtures in temp dirs) map structurally the same
    way, which is all cross-file resolution needs.
    """
    parts = [p for p in path.split("/") if p and p != "."]
    if parts and parts[0] == "src":
        parts = parts[1:]
    if not parts:
        return "<module>"
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    elif parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    return ".".join(parts) if parts else "<module>"


@dataclasses.dataclass
class ClassInfo:
    name: str
    qname: str                      # "repro.core.engine:FigaroEngine"
    node: ast.ClassDef
    methods: dict[str, str]         # method name -> function qname


@dataclasses.dataclass
class FunctionInfo:
    qname: str
    module: str
    name: str
    ctx: FileContext
    node: ast.FunctionDef | ast.AsyncFunctionDef
    cls: ast.ClassDef | None        # enclosing class, if a method
    parent: str | None              # enclosing function qname, if nested
    local_defs: dict[str, str] = dataclasses.field(default_factory=dict)
    bindings: dict[str, str] = dataclasses.field(default_factory=dict)
    calls: list[ast.Call] = dataclasses.field(default_factory=list)
    assigns: list[ast.Assign] = dataclasses.field(default_factory=list)

    @property
    def short(self) -> str:
        return self.qname.split(":", 1)[1]

    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    def kwonly(self) -> list[str]:
        return [p.arg for p in self.node.args.kwonlyargs]

    def is_method(self) -> bool:
        ps = self.params()
        return self.cls is not None and bool(ps) and ps[0] in ("self", "cls")


@dataclasses.dataclass
class ModuleInfo:
    name: str
    ctx: FileContext
    functions: dict[str, str] = dataclasses.field(default_factory=dict)
    classes: dict[str, ClassInfo] = dataclasses.field(default_factory=dict)
    instances: dict[str, str] = dataclasses.field(default_factory=dict)
    aliases: dict[str, str] = dataclasses.field(default_factory=dict)
    globals_: set[str] = dataclasses.field(default_factory=set)
    module_locks: set[str] = dataclasses.field(default_factory=set)
    calls: list[ast.Call] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class Root:
    qname: str
    kind: str                       # "engine-impl" | "jax.jit" | ...
    static: frozenset[str] = frozenset()


class CallGraph:
    """Functions, edges, roots, and the traced-context closure."""

    def __init__(self) -> None:
        self.functions: dict[str, FunctionInfo] = {}
        self.modules: dict[str, ModuleInfo] = {}
        self.edges: dict[str, set[str]] = {}
        self.roots: dict[str, Root] = {}
        #: qname -> call chain from a root (root first, self last).
        self.traced: dict[str, tuple[str, ...]] = {}

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, contexts: Iterable[FileContext]) -> "CallGraph":
        graph = cls()
        for ctx in contexts:
            graph._index_module(ctx)
        graph._resolve_relative_aliases()
        for mod in graph.modules.values():
            graph._resolve_module(mod)
        graph._mark_traced()
        return graph

    def _index_module(self, ctx: FileContext) -> None:
        mod = ModuleInfo(name=module_name_of(ctx.path), ctx=ctx,
                         aliases=dict(ctx.aliases))
        self.modules[mod.name] = mod
        for stmt in ctx.tree.body:
            for tgt in _assign_names(stmt):
                mod.globals_.add(tgt)
            value = getattr(stmt, "value", None)
            if isinstance(stmt, ast.Assign) and isinstance(value, ast.Call):
                base = _last_component(ctx.resolve(value.func) or "")
                for t in stmt.targets:
                    if isinstance(t, ast.Name) \
                            and base in _LOCK_FACTORY_NAMES:
                        mod.module_locks.add(t.id)
        _Indexer(self, mod).visit_body(ctx.tree.body)
        # Module-level instances: NAME = ClassName(...) — resolved after all
        # classes of this module are indexed.
        for stmt in ctx.tree.body:
            value = getattr(stmt, "value", None)
            if isinstance(stmt, ast.Assign) and isinstance(value, ast.Call) \
                    and isinstance(value.func, ast.Name) \
                    and value.func.id in mod.classes:
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        mod.instances[t.id] = mod.classes[value.func.id].qname

    def _resolve_relative_aliases(self) -> None:
        """`from ._state import STATE` → alias STATE → dotted name, reusing
        imports.ImportGraph's relative-import climbing."""
        packages = set()
        for name, mod in self.modules.items():
            if mod.ctx.path.endswith("__init__.py"):
                packages.add(name)
            parts = name.split(".")
            for i in range(1, len(parts)):
                packages.add(".".join(parts[:i]))
        ig = ImportGraph(src_root="", edges={}, packages=packages,
                         modules={m: i.ctx.path
                                  for m, i in self.modules.items()})
        for mod in self.modules.values():
            for node in ast.walk(mod.ctx.tree):
                if not (isinstance(node, ast.ImportFrom) and node.level):
                    continue
                base = ig._from_base(mod.name, node)
                if base is None:
                    continue
                for a in node.names:
                    if a.name != "*":
                        mod.aliases[a.asname or a.name] = f"{base}.{a.name}"

    def _resolve_module(self, mod: ModuleInfo) -> None:
        fns = [f for f in self.functions.values() if f.module == mod.name]
        for fi in fns:                       # bindings before edges: children
            for assign in fi.assigns:        # look bindings up in parents
                self._record_binding(fi, mod, assign)
        for fi in fns:
            self.edges.setdefault(fi.qname, set())
            for call in fi.calls:
                self._record_call(fi, mod, call)
        for call in mod.calls:               # module level: roots only
            self._detect_call_root(None, mod, call)

    def _record_binding(self, fi: FunctionInfo, mod: ModuleInfo,
                        assign: ast.Assign) -> None:
        if len(assign.targets) != 1 \
                or not isinstance(assign.targets[0], ast.Name):
            return
        target = self.resolve_callable(fi, mod, assign.value,
                                       use_bindings=False)
        if target is not None:
            fi.bindings[assign.targets[0].id] = target

    def _record_call(self, fi: FunctionInfo, mod: ModuleInfo,
                     call: ast.Call) -> None:
        callee = self.resolve_callable(fi, mod, call.func)
        if callee is not None:
            self.edges[fi.qname].add(callee)
        # A program-function reference handed to any call (jax.vmap, scan,
        # functools.reduce, a leaf_qr= kwarg...) is conservatively an edge:
        # the receiver may invoke it from the caller's context.
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            ref = self.resolve_callable(fi, mod, arg)
            if ref is not None:
                self.edges[fi.qname].add(ref)
        self._detect_call_root(fi, mod, call)

    # -- name resolution -----------------------------------------------------

    def dotted(self, mod: ModuleInfo, node: ast.AST) -> str | None:
        """Alias-expanded dotted chain (absolute AND relative imports)."""
        parts = _dotted_parts(node)
        if parts is None:
            return None
        head = mod.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])

    def resolve_callable(self, fi: FunctionInfo | None, mod: ModuleInfo,
                         node: ast.AST, *,
                         use_bindings: bool = True) -> str | None:
        """Function qname a callee/function-reference expression names."""
        node = self._unwrap_partial(mod, node)
        if isinstance(node, ast.Name):
            scope = fi
            while scope is not None:
                if node.id in scope.local_defs:
                    return scope.local_defs[node.id]
                if use_bindings and node.id in scope.bindings:
                    return scope.bindings[node.id]
                scope = self.functions.get(scope.parent) \
                    if scope.parent else None
            if node.id in mod.functions:
                return mod.functions[node.id]
            if node.id in mod.classes:
                return self._class_init(mod.classes[node.id])
            dotted = mod.aliases.get(node.id)
            return self._resolve_dotted(dotted) if dotted else None
        if isinstance(node, ast.Attribute):
            base = node.value
            if isinstance(base, ast.Name) and base.id in ("self", "cls") \
                    and fi is not None and fi.cls is not None:
                info = self._class_of(fi)
                return info.methods.get(node.attr) if info else None
            if isinstance(base, ast.Name):
                if base.id in mod.classes:
                    return mod.classes[base.id].methods.get(node.attr)
                if base.id in mod.instances:
                    cls_q = mod.instances[base.id]
                    info = self._class_by_qname(cls_q)
                    return info.methods.get(node.attr) if info else None
            dotted = self.dotted(mod, node)
            return self._resolve_dotted(dotted) if dotted else None
        return None

    def _unwrap_partial(self, mod: ModuleInfo, node: ast.AST) -> ast.AST:
        if isinstance(node, ast.Call) and node.args:
            dotted = self.dotted(mod, node.func) or ""
            if _last_component(dotted) == "partial":
                return self._unwrap_partial(mod, node.args[0])
        return node

    def _resolve_dotted(self, dotted: str) -> str | None:
        parts = dotted.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = self.modules.get(".".join(parts[:cut]))
            if mod is None:
                continue
            rest = parts[cut:]
            if len(rest) == 1:
                if rest[0] in mod.functions:
                    return mod.functions[rest[0]]
                if rest[0] in mod.classes:
                    return self._class_init(mod.classes[rest[0]])
            elif len(rest) == 2:
                if rest[0] in mod.classes:
                    return mod.classes[rest[0]].methods.get(rest[1])
                if rest[0] in mod.instances:
                    info = self._class_by_qname(mod.instances[rest[0]])
                    if info is not None:
                        return info.methods.get(rest[1])
            return None
        return None

    def _class_init(self, info: ClassInfo) -> str | None:
        return info.methods.get("__init__") \
            or info.methods.get("__post_init__")

    def _class_of(self, fi: FunctionInfo) -> ClassInfo | None:
        if fi.cls is None:
            return None
        mod = self.modules[fi.module]
        for info in mod.classes.values():
            if info.node is fi.cls:
                return info
        return None

    def _class_by_qname(self, qname: str) -> ClassInfo | None:
        mod = self.modules.get(qname.split(":", 1)[0])
        if mod is None:
            return None
        for info in mod.classes.values():
            if info.qname == qname:
                return info
        return None

    # -- jit-region roots ----------------------------------------------------

    def _detect_call_root(self, fi: FunctionInfo | None, mod: ModuleInfo,
                          call: ast.Call) -> None:
        dotted = self.dotted(mod, call.func) or ""
        last = _last_component(dotted)
        if dotted == "jax.jit" or (last == "jit" and "jax" in dotted):
            if call.args:
                self._add_root(fi, mod, call.args[0], "jax.jit",
                               _static_argnames(call))
        elif last == "pallas_call":
            if call.args:
                static = self._partial_kwarg_names(mod, call.args[0])
                self._add_root(fi, mod, call.args[0], "pallas_call", static)
        elif last == "shard_map":
            target = call.args[0] if call.args else next(
                (kw.value for kw in call.keywords if kw.arg == "f"), None)
            if target is not None:
                self._add_root(fi, mod, target, "shard_map", frozenset())

    def _partial_kwarg_names(self, mod: ModuleInfo,
                             node: ast.AST) -> frozenset[str]:
        """Keywords bound by `functools.partial(body, kw=...)` are trace-time
        constants of the kernel body, not traced refs."""
        if isinstance(node, ast.Call) and _last_component(
                self.dotted(mod, node.func) or "") == "partial":
            return frozenset(kw.arg for kw in node.keywords if kw.arg)
        return frozenset()

    def _add_root(self, fi: FunctionInfo | None, mod: ModuleInfo,
                  target: ast.AST, kind: str, static: frozenset[str]) -> None:
        qname = self.resolve_callable(fi, mod, target)
        if qname is not None and qname not in self.roots:
            self.roots[qname] = Root(qname, kind, static)

    def _detect_def_roots(self) -> None:
        for fi in self.functions.values():
            mod = self.modules[fi.module]
            if fi.cls is not None and _IMPL_RE.match(fi.name) \
                    and fi.qname not in self.roots:
                # Engine contract: every kwonly arg of an impl is a _STATIC
                # dispatch flag, hashable and concrete at trace time.
                self.roots[fi.qname] = Root(fi.qname, "engine-impl",
                                            frozenset(fi.kwonly()))
            for dec in fi.node.decorator_list:
                expr = dec
                static: frozenset[str] = frozenset()
                if isinstance(dec, ast.Call):
                    dotted = self.dotted(mod, dec.func) or ""
                    if _last_component(dotted) == "partial" and dec.args:
                        expr = dec.args[0]
                        static = _static_argnames(dec)
                    else:
                        expr = dec.func
                        static = _static_argnames(dec)
                dotted = self.dotted(mod, expr) or ""
                if dotted == "jax.jit" or (
                        _last_component(dotted) == "jit" and "jax" in dotted):
                    if fi.qname not in self.roots:
                        self.roots[fi.qname] = Root(fi.qname, "jax.jit",
                                                    static)

    def _mark_traced(self) -> None:
        self._detect_def_roots()
        queue = [q for q in self.roots if q in self.functions]
        for q in queue:
            self.traced[q] = (q,)
        while queue:
            src = queue.pop()
            for dst in sorted(self.edges.get(src, ())):
                if dst not in self.traced and dst in self.functions:
                    self.traced[dst] = self.traced[src] + (dst,)
                    queue.append(dst)

    # -- reports -------------------------------------------------------------

    def render_text(self) -> str:
        lines = [f"figaro-flow call graph: {len(self.functions)} function(s),"
                 f" {sum(len(e) for e in self.edges.values())} edge(s),"
                 f" {len(self.roots)} jit root(s),"
                 f" {len(self.traced)} traced-context function(s)"]
        for mname in sorted(self.modules):
            fns = sorted((f for f in self.functions.values()
                          if f.module == mname), key=lambda f: f.qname)
            if not fns:
                continue
            lines.append(f"\n{mname}  ({self.modules[mname].ctx.path})")
            for fi in fns:
                mark = "host"
                if fi.qname in self.roots:
                    mark = f"traced root [{self.roots[fi.qname].kind}]"
                elif fi.qname in self.traced:
                    chain = " -> ".join(
                        q.split(":", 1)[1] for q in self.traced[fi.qname])
                    mark = f"traced via {chain}"
                lines.append(f"  {fi.short:40s} {mark}")
                for dst in sorted(self.edges.get(fi.qname, ())):
                    lines.append(f"    -> {dst}")
        return "\n".join(lines)

    def render_dot(self) -> str:
        def nid(q: str) -> str:
            return '"' + q.replace('"', "'") + '"'
        lines = ["digraph figaro_flow {", "  rankdir=LR;",
                 '  node [shape=box, fontsize=10];']
        for q, fi in sorted(self.functions.items()):
            if q in self.roots:
                style = 'style=filled, fillcolor="#d95f02"'
            elif q in self.traced:
                style = 'style=filled, fillcolor="#fdcdac"'
            else:
                style = 'style=filled, fillcolor="#eeeeee"'
            lines.append(f"  {nid(q)} [{style}];")
        for src in sorted(self.edges):
            for dst in sorted(self.edges[src]):
                lines.append(f"  {nid(src)} -> {nid(dst)};")
        lines.append("}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "functions": {
                q: {
                    "path": fi.ctx.path,
                    "line": fi.node.lineno,
                    "traced": q in self.traced,
                    "root": self.roots[q].kind if q in self.roots else None,
                    "chain": list(self.traced.get(q, ())),
                    "calls": sorted(self.edges.get(q, ())),
                }
                for q, fi in sorted(self.functions.items())
            },
            "roots": sorted(self.roots),
        }


class _Indexer:
    """Pass 1: index functions/classes and attach each Call/Assign to its
    innermost enclosing function. Lambdas do not open a scope — their body
    belongs to the enclosing def, which is how the engine's
    ``body = lambda p, d: impl(p, d, **options)`` stays attributed."""

    def __init__(self, graph: CallGraph, mod: ModuleInfo) -> None:
        self.graph = graph
        self.mod = mod
        self.cls_stack: list[ast.ClassDef] = []
        self.fn_stack: list[FunctionInfo] = []
        self.name_stack: list[str] = []

    def visit_body(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self._visit(stmt)

    def _visit(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._visit_function(node)
            return
        if isinstance(node, ast.ClassDef):
            self._visit_class(node)
            return
        if isinstance(node, ast.Call):
            (self.fn_stack[-1].calls if self.fn_stack
             else self.mod.calls).append(node)
        elif isinstance(node, ast.Assign) and self.fn_stack:
            self.fn_stack[-1].assigns.append(node)
        for child in ast.iter_child_nodes(node):
            self._visit(child)

    def _visit_function(self, node) -> None:
        scope = ".".join(self.name_stack + [node.name]) if self.name_stack \
            else node.name
        qname = f"{self.mod.name}:{scope}"
        parent = self.fn_stack[-1] if self.fn_stack else None
        fi = FunctionInfo(
            qname=qname, module=self.mod.name, name=node.name,
            ctx=self.mod.ctx, node=node,
            cls=self.cls_stack[-1] if self.cls_stack and not parent else None,
            parent=parent.qname if parent else None)
        self.graph.functions[qname] = fi
        if parent is not None:
            parent.local_defs[node.name] = qname
        elif self.cls_stack:
            for info in self.mod.classes.values():
                if info.node is self.cls_stack[-1]:
                    info.methods[node.name] = qname
        else:
            self.mod.functions[node.name] = qname
        for dec in node.decorator_list:      # decorators evaluate outside
            self._visit(dec)
        self.fn_stack.append(fi)
        self.name_stack.append(node.name)
        for stmt in node.body:
            self._visit(stmt)
        self.name_stack.pop()
        self.fn_stack.pop()

    def _visit_class(self, node: ast.ClassDef) -> None:
        if self.fn_stack:                    # class defined inside a function:
            for stmt in node.body:           # treat methods as nested defs
                self._visit(stmt)
            return
        scope = ".".join(self.name_stack + [node.name]) if self.name_stack \
            else node.name
        info = ClassInfo(name=node.name, qname=f"{self.mod.name}:{scope}",
                         node=node, methods={})
        self.mod.classes[node.name] = info
        for dec in node.decorator_list:
            self._visit(dec)
        self.cls_stack.append(node)
        self.name_stack.append(node.name)
        for stmt in node.body:
            self._visit(stmt)
        self.name_stack.pop()
        self.cls_stack.pop()


class Program:
    """One analysis run's whole-program view: every parsed file, the call
    graph, and (on demand) the dataflow fixpoint."""

    def __init__(self, contexts: Iterable[FileContext]) -> None:
        self.files: dict[str, FileContext] = {c.path: c for c in contexts}
        self.graph = CallGraph.build(self.files.values())
        self._dataflow = None

    def dataflow(self):
        if self._dataflow is None:
            from .dataflow import Dataflow
            self._dataflow = Dataflow(self.graph).run()
        return self._dataflow

    def functions_in(self, path: str) -> Iterator[FunctionInfo]:
        for fi in self.graph.functions.values():
            if fi.ctx.path == path:
                yield fi

    def traced_chain(self, qname: str) -> tuple[str, ...]:
        return self.graph.traced.get(qname, ())

    def external_method_refs(self, owner: ast.ClassDef,
                             method: str) -> list[tuple[str, int]]:
        """(path, line) of `X.method` attribute references OUTSIDE the owning
        class — the call-graph query behind FIG006's helper exemption: a
        private method referenced from anywhere else can run without the
        class's own locked callers."""
        out: list[tuple[str, int]] = []
        for ctx in self.files.values():
            for cls, node in _attr_refs(ctx.tree, method):
                if cls is owner:
                    continue
                if isinstance(node.value, ast.Name) \
                        and node.value.id in ("self", "cls") \
                        and cls is not None and _has_method(cls, method):
                    continue  # another class's own method of the same name
                out.append((ctx.path, node.lineno))
        return out


def _attr_refs(tree: ast.Module,
               attr: str) -> Iterator[tuple[ast.ClassDef | None,
                                            ast.Attribute]]:
    """Attribute nodes with the given attr, paired with the enclosing class."""
    def walk(node: ast.AST, cls: ast.ClassDef | None):
        if isinstance(node, ast.ClassDef):
            cls = node
        if isinstance(node, ast.Attribute) and node.attr == attr:
            yield cls, node
        for child in ast.iter_child_nodes(node):
            yield from walk(child, cls)
    yield from walk(tree, None)


def _has_method(cls: ast.ClassDef, name: str) -> bool:
    return any(isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
               and m.name == name for m in cls.body)


def _assign_names(stmt: ast.stmt) -> Iterator[str]:
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    for tgt in targets:
        for t in (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                  else [tgt]):
            if isinstance(t, ast.Name):
                yield t.id


def _dotted_parts(node: ast.AST) -> list[str] | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return parts[::-1]


def _last_component(dotted: str) -> str:
    return dotted.rsplit(".", 1)[-1] if dotted else ""


def _static_argnames(call: ast.Call) -> frozenset[str]:
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return frozenset({v.value})
        if isinstance(v, (ast.Tuple, ast.List)):
            return frozenset(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return frozenset()
