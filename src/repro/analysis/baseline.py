"""Baseline handling: the committed list of accepted (justified) findings.

The analyzer's contract with CI is differential: `analysis_baseline.json`
records every finding the team has explicitly accepted, each with a
justification, and the CI job fails on any finding NOT in that file. A clean
tree commits an empty baseline; a deliberate violation either carries an
in-source suppression comment (preferred — the reason lives next to the
code) or a baseline entry (for findings in files the team cannot edit).

Matching is by fingerprint (rule, path, message) — line numbers drift with
unrelated edits and would churn the file. ``--write-baseline`` regenerates
the file from the current tree, preserving justifications of entries that
still match.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable

from .framework import Finding

BASELINE_VERSION = 1


@dataclasses.dataclass
class Baseline:
    entries: dict[tuple[str, str, str], str]  # fingerprint -> justification

    def covers(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.entries

    def split(self, findings: Iterable[Finding]
              ) -> tuple[list[Finding], list[Finding]]:
        """(new, baselined) partition of ``findings``."""
        new, old = [], []
        for f in findings:
            (old if self.covers(f) else new).append(f)
        return new, old

    def stale(self, findings: Iterable[Finding]) -> list[tuple[str, str, str]]:
        """Baseline entries no longer matched by any finding — fixed
        violations whose entries should be deleted (the baseline must stay
        exact, or it can mask a regression with the same message)."""
        live = {f.fingerprint() for f in findings}
        return sorted(fp for fp in self.entries if fp not in live)

    def to_json(self) -> dict:
        return {
            "version": BASELINE_VERSION,
            "findings": [
                {"rule": rule, "path": path, "message": message,
                 "justification": just}
                for (rule, path, message), just in sorted(self.entries.items())
            ],
        }


def empty_baseline() -> Baseline:
    return Baseline(entries={})


def load_baseline(path: str) -> Baseline:
    with open(path, encoding="utf-8") as fh:
        raw = json.load(fh)
    if raw.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{raw.get('version')!r} (expected "
                         f"{BASELINE_VERSION})")
    entries = {}
    for e in raw.get("findings", []):
        entries[(e["rule"], e["path"], e["message"])] = \
            e.get("justification", "")
    return Baseline(entries=entries)


def write_baseline(path: str, findings: Iterable[Finding],
                   previous: Baseline | None = None) -> Baseline:
    """Regenerate the baseline from the current findings, carrying forward
    justifications that still apply; new entries get a TODO marker so review
    can spot unjustified acceptances."""
    prev = previous.entries if previous is not None else {}
    entries = {}
    for f in findings:
        fp = f.fingerprint()
        entries[fp] = prev.get(fp, "TODO: justify or fix")
    baseline = Baseline(entries=entries)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(baseline.to_json(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return baseline
