"""Version-compat shims for the pinned JAX.

The codebase targets the current jax.sharding surface (``AxisType``,
``jax.make_mesh(..., axis_types=...)``, top-level ``jax.shard_map``,
keyword-style ``AbstractMesh``); the container pins an older JAX where those
spellings differ or don't exist.  Everything version-sensitive is funneled
through this module so the rest of the tree imports one stable API:

  AxisType             the real enum when available, else a stand-in Enum
  make_mesh            jax.make_mesh, dropping ``axis_types`` when unsupported
  make_abstract_mesh   AbstractMesh under both calling conventions
  shard_map            jax.shard_map or jax.experimental.shard_map.shard_map
"""

from __future__ import annotations

import enum
from typing import Sequence

import jax

__all__ = ["AxisType", "make_mesh", "make_abstract_mesh", "shard_map",
           "axis_size"]


try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _HAS_AXIS_TYPE = True
except ImportError:  # pinned jax: meshes are implicitly fully-Auto
    _HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType (older JAX is all-Auto)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates JAX versions without ``axis_types``."""
    kwargs = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPE and axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kwargs)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def make_abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
                       *, axis_types=None):
    """AbstractMesh across the constructor change: new JAX takes
    ``(shapes, names, axis_types=...)``, the pinned one ``(((name, size), ...))``."""
    from jax.sharding import AbstractMesh
    try:
        if axis_types is not None:
            return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                                axis_types=axis_types)
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """`shard_map` with the replication-check kwarg normalized across JAX
    versions: pre-0.7 spells it ``check_rep``, newer JAX renamed it to
    ``check_vma``. Callers may pass either; the unsupported spelling is
    translated rather than exploding on the pinned version."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)
    except TypeError:
        if "check_rep" in kwargs:
            kwargs["check_vma"] = kwargs.pop("check_rep")
        elif "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        else:
            raise
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` with a psum(1) fallback for JAX versions
    predating it (inside shard_map/pmap collectives only)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.numpy as jnp
    return jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
