"""Exact join statistics, collected at ingest and maintained incrementally.

The cost model (`repro.planner.cost`) needs, per relation: row count ``m``,
data-column count ``n``, the distinct full-join-key count ``K`` (the number of
generalized-head/tail rows the relation emits — orientation-independent), and
per-edge distinct counts / fan-outs for diagnostics. All of these are *exact*,
not sampled: we keep the sorted unique key rows of every tracked projection,
so an append merges ``r`` new rows in O((U + r) log r) without rescanning the
relation, and incremental stats equal a from-scratch recollection bit for bit.

Pure numpy + stdlib by design (lint rule FIG008): statistics run at ingest
time on the host and must never be pulled into a jax trace. The module is
duck-typed against `repro.core.relation` (``rel.keys`` / ``rel.key_attrs`` /
``rel.num_rows`` / ``rel.num_data_cols``; ``db.relations``) rather than
importing it, which also keeps `repro.data.relational` free to import the
planner without a cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

__all__ = ["RelationStats", "DatabaseStats", "stats_for", "normalize_edges"]

# Attribute hung on a Database instance to cache stats per edge set.
_CACHE_ATTR = "_figaro_plan_stats"


def _unique_rows(rows: np.ndarray) -> np.ndarray:
    """Sorted unique rows of a [r, k] int array (k may be 0)."""
    if rows.shape[0] == 0 or rows.shape[1] == 0:
        return rows[: min(rows.shape[0], 1)].copy()
    return np.unique(rows, axis=0)


def normalize_edges(edges: Iterable[tuple[str, str]]) -> tuple[tuple[str, str], ...]:
    """Canonical undirected edge set: endpoints sorted, edges sorted, deduped."""
    return tuple(sorted({tuple(sorted((a, b))) for a, b in edges}))


@dataclasses.dataclass
class RelationStats:
    """Exact statistics of one relation over a set of tracked key projections."""

    name: str
    key_attrs: tuple[str, ...]
    num_data_cols: int
    num_rows: int
    # Tracked projection -> sorted unique key rows [U, len(attrs)].
    uniques: dict[tuple[str, ...], np.ndarray]

    @property
    def distinct_keys(self) -> int:
        """K_i: distinct full join keys (gen-head/tail row count of the node)."""
        return int(self.uniques[self.key_attrs].shape[0])

    def distinct(self, attrs: Sequence[str]) -> int:
        return int(self.uniques[tuple(attrs)].shape[0])

    def fan_out(self, attrs: Sequence[str]) -> float:
        """Average rows per distinct value of ``attrs`` — the downward fan-out
        when ``attrs`` are the attributes shared with the parent."""
        d = self.distinct(attrs)
        return self.num_rows / d if d else float(self.num_rows)

    def update(self, keys: np.ndarray) -> None:
        """Merge appended key rows (``[r, len(key_attrs)]``, key-attr order)."""
        keys = np.asarray(keys, dtype=np.int64)
        if keys.ndim == 1:
            keys = keys[None, :]
        if keys.shape[1] != len(self.key_attrs):
            raise ValueError(
                f"{self.name}: appended keys have {keys.shape[1]} columns, "
                f"expected {len(self.key_attrs)}")
        self.num_rows += int(keys.shape[0])
        pos = {a: i for i, a in enumerate(self.key_attrs)}
        for attrs, table in self.uniques.items():
            proj = keys[:, [pos[a] for a in attrs]]
            self.uniques[attrs] = _unique_rows(
                np.concatenate([table, proj], axis=0))

    @staticmethod
    def collect(rel, track: Iterable[tuple[str, ...]]) -> "RelationStats":
        """Collect from a `Relation`-like object; always tracks the full key."""
        key_attrs = tuple(rel.key_attrs)
        keys = np.asarray(rel.keys, dtype=np.int64)
        pos = {a: i for i, a in enumerate(key_attrs)}
        uniques: dict[tuple[str, ...], np.ndarray] = {}
        for attrs in {key_attrs} | {tuple(t) for t in track}:
            uniques[attrs] = _unique_rows(keys[:, [pos[a] for a in attrs]])
        return RelationStats(
            name=rel.name,
            key_attrs=key_attrs,
            num_data_cols=int(rel.num_data_cols),
            num_rows=int(rel.num_rows),
            uniques=uniques,
        )


@dataclasses.dataclass
class DatabaseStats:
    """Per-relation stats plus the undirected join-edge structure they track.

    Orientation-independent on purpose: ``m``, ``n``, ``K`` and per-edge
    distinct counts do not change when the tree is re-rooted, so one stats
    object scores *every* orientation and survives adaptive re-rooting.
    """

    relations: dict[str, RelationStats]
    edges: tuple[tuple[str, str], ...]  # normalized undirected
    shared: dict[tuple[str, str], tuple[str, ...]]  # per normalized edge

    @staticmethod
    def collect(db, edges: Iterable[tuple[str, str]]) -> "DatabaseStats":
        edges = normalize_edges(edges)
        rels: Mapping[str, object] = db.relations
        shared: dict[tuple[str, str], tuple[str, ...]] = {}
        track: dict[str, list[tuple[str, ...]]] = {n: [] for n in rels}
        for a, b in edges:
            ra, rb = rels[a], rels[b]
            attrs = tuple(x for x in ra.key_attrs if x in rb.key_attrs)
            shared[(a, b)] = attrs
            if attrs:
                track[a].append(attrs)
                track[b].append(tuple(x for x in rb.key_attrs if x in attrs))
        stats = {n: RelationStats.collect(rels[n], track[n]) for n in rels}
        return DatabaseStats(relations=stats, edges=edges, shared=shared)

    def shared_attrs(self, a: str, b: str) -> tuple[str, ...]:
        """Join attributes of undirected edge {a, b}, in a's attr order."""
        key = tuple(sorted((a, b)))
        attrs = self.shared[key]
        return tuple(x for x in self.relations[a].key_attrs if x in attrs)

    def edge_fan_out(self, child: str, parent: str) -> float:
        """Downward fan-out of ``child`` under ``parent``: average child rows
        per distinct parent-shared key (1.0 means key-preserving)."""
        return self.relations[child].fan_out(self.shared_attrs(child, parent))

    def update(self, name: str, keys: np.ndarray) -> None:
        """Fold an append's key rows into ``name``'s stats, incrementally."""
        if name not in self.relations:
            raise ValueError(
                f"unknown relation {name!r}; have {sorted(self.relations)}")
        self.relations[name].update(keys)


def stats_for(db, edges: Iterable[tuple[str, str]]) -> DatabaseStats:
    """Stats for (db, edges), cached on the Database per normalized edge set.

    The cache rides on the instance (plain attribute), so repeated planning
    calls — rank, explain, re-root checks — reuse one collection pass. Callers
    that append rows must route the new keys through `DatabaseStats.update` to
    keep the cached object exact.
    """
    key = normalize_edges(edges)
    cache = getattr(db, _CACHE_ATTR, None)
    if cache is None:
        cache = {}
        try:
            setattr(db, _CACHE_ATTR, cache)
        except (AttributeError, TypeError):  # frozen/slotted db: skip caching
            return DatabaseStats.collect(db, key)
    if key not in cache:
        cache[key] = DatabaseStats.collect(db, key)
    return cache[key]
