"""Adaptive re-rooting policy: when appends shift the cost ranking, propose
a better root — with hysteresis so alternating appends cannot flap.

The policy is deliberately plain host Python (FIG008): the facade consults it
after each append, outside any trace. It owns the *decision* only; the
mechanics of swapping the live plan (drain the async servers, rebuild, install)
belong to `repro.api.JoinDataset` + `repro.core.plan_cache.PlanHolder`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .cost import OrientationCost, orientation_cost
from .orient import enumerate_roots
from .stats import DatabaseStats

__all__ = ["Replanner"]


@dataclasses.dataclass
class Replanner:
    """Tracks exact stats under appends and proposes hysteresis-gated re-roots.

    ``hysteresis`` is the relative margin the challenger must win by:
    a re-root is proposed only when ``best.total * (1 + hysteresis) <
    current.total``. After a switch the old root would itself need to get
    ``(1 + hysteresis)`` cheaper again to win back, so two orientations whose
    costs oscillate by less than the margin settle on one of them instead of
    flapping (asserted in tests/test_planner.py).
    """

    stats: DatabaseStats
    names: tuple[str, ...]
    edges: tuple[tuple[str, str], ...]
    current_root: str
    hysteresis: float = 0.5
    appended_rows: dict[str, int] = dataclasses.field(default_factory=dict)

    def note_append(self, name: str, keys: np.ndarray) -> None:
        """Fold an append's key rows into the stats (exactly, incrementally)."""
        keys = np.asarray(keys)
        rows = 1 if keys.ndim == 1 else int(keys.shape[0])
        self.appended_rows[name] = self.appended_rows.get(name, 0) + rows
        self.stats.update(name, keys)

    def ranking(self) -> list[OrientationCost]:
        ranked = [orientation_cost(self.stats, parent)
                  for _, parent in enumerate_roots(self.names, self.edges)]
        ranked.sort(key=lambda oc: (oc.total, oc.root))
        return ranked

    def proposal(self) -> str | None:
        """Root to re-root onto, or None to stay put."""
        ranked = self.ranking()
        best = ranked[0]
        if best.root == self.current_root:
            return None
        current = next(oc for oc in ranked if oc.root == self.current_root)
        if best.total * (1.0 + self.hysteresis) < current.total:
            return best.root
        return None

    def on_reroot(self, root: str) -> None:
        """Record that the dataset now runs rooted at ``root``."""
        self.current_root = root
