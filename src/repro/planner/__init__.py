"""figaro-plan: cost-based join-tree orientation planning.

The paper's runtime hinges on which relation roots the join tree (Table 2
reports up to 394x between orientations of one schema), yet the result R0 is
orientation-invariant up to signs. This package picks the orientation for the
user:

  * `stats` — exact per-relation cardinalities, per-join-key distinct counts
    and fan-out estimates, collected at ingest and updated incrementally on
    append (pure numpy, never inside a jax trace — lint rule FIG008).
  * `cost` — the paper's complexity model per rooted orientation: rotation
    work is Sum_i rows_i x carried-width_i, and only non-root nodes pay the
    second (projection) head/tail pass, which is what makes the root choice
    matter.
  * `orient` — enumerate every rooted orientation of the acyclic join graph,
    rank by estimated cost, `choose_root`.
  * `explain` — human-readable candidate ranking (backs `ds.explain()`).
  * `replan` — `Replanner`: tracks appended key volume and proposes a re-root
    when growth shifts the cost ranking past a hysteresis threshold.

Everything here is numpy + stdlib on purpose: planning runs at ingest time on
the host, and a traced cost model would silently retrace per schema.
"""

from .cost import NodeCost, OrientationCost, orientation_cost, plan_cost
from .explain import explain_text
from .orient import (choose_root, enumerate_roots, orient_edges,
                     rank_orientations, validate_names)
from .replan import Replanner
from .stats import DatabaseStats, RelationStats, stats_for

__all__ = [
    "DatabaseStats",
    "RelationStats",
    "stats_for",
    "NodeCost",
    "OrientationCost",
    "orientation_cost",
    "plan_cost",
    "choose_root",
    "enumerate_roots",
    "orient_edges",
    "rank_orientations",
    "validate_names",
    "explain_text",
    "Replanner",
]
