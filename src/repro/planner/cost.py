"""The paper's complexity model, specialized to the repo's Algorithm 2 engine.

Per node ``i`` the engine (`repro.core.figaro.figaro_r0`) does:

  1. a head/tail rotation pass over the relation's own ``[m_i, n_i]`` block
     (first-pass Givens work — every scan pass touches the data a small
     constant number of times, `ROTATION_PASSES`);
  2. a gather of the children's carried heads into the ``[K_i, w_i]`` Data
     matrix, where ``K_i`` is the distinct-full-key count and ``w_i`` the
     node's *subtree data-column width* (own columns + all descendants');
  3. **non-root only**: a second, generalized head/tail pass over that
     ``[K_i, w_i]`` matrix to project away the parent-shared key.

Step 3 is the orientation lever: the root skips it, so rooting the tree at
the relation whose subtree-weighted ``K_i * w_i`` mass is largest removes the
single biggest projection pass. A naive "sum over all nodes of rows x width"
misranks real schemas (it charges the root for a pass it never runs); the
root exclusion below is what makes predicted cost track measured runtime in
``benchmarks/join_tree_effect.py``.

Pure numpy-free arithmetic on host ints (FIG008: no jax here).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from .stats import DatabaseStats

__all__ = ["ROTATION_PASSES", "NodeCost", "OrientationCost",
           "orientation_cost", "plan_cost", "subtree_widths"]

# Each head/tail scan pass reads+rotates+writes its block: ~3 touches per
# element. A constant factor — it cannot change a ranking, but it keeps the
# absolute numbers within sight of element-touch counts for `explain()`.
ROTATION_PASSES = 3


@dataclasses.dataclass(frozen=True)
class NodeCost:
    """Per-node cost breakdown under one orientation."""

    name: str
    m: int  # rows
    n: int  # own data columns
    K: int  # distinct full join keys (gen-head/tail rows)
    width: int  # subtree data-column width w_i
    is_root: bool
    first_pass: float  # ROT * m * n
    gather: float  # K * (w - n): assembling children heads into Data
    project: float  # ROT * K * w for non-root, 0 for the root

    @property
    def total(self) -> float:
        return self.first_pass + self.gather + self.project


@dataclasses.dataclass(frozen=True)
class OrientationCost:
    """Estimated cost of one rooted orientation, with per-node breakdown."""

    root: str
    parent: Mapping[str, str | None]
    nodes: tuple[NodeCost, ...]
    total: float


def subtree_widths(parent: Mapping[str, str | None],
                   ncols: Mapping[str, int]) -> dict[str, int]:
    """w_i per node: own data columns + all descendants' (pure topology)."""
    widths = dict(ncols)
    # Children accumulate into ancestors; iterate leaves-up by repeatedly
    # folding nodes whose children are all folded.
    children: dict[str, list[str]] = {n: [] for n in parent}
    for n, p in parent.items():
        if p is not None:
            children[p].append(n)

    def width(n: str) -> int:
        return ncols[n] + sum(width(c) for c in children[n])

    return {n: width(n) for n in parent}


def orientation_cost(stats: DatabaseStats,
                     parent: Mapping[str, str | None]) -> OrientationCost:
    """Score one rooted orientation (``parent`` maps root -> None)."""
    roots = [n for n, p in parent.items() if p is None]
    if len(roots) != 1:
        raise ValueError(f"orientation needs exactly one root, got {roots}")
    root = roots[0]
    ncols = {n: st.num_data_cols for n, st in stats.relations.items()}
    widths = subtree_widths(parent, ncols)
    nodes = []
    for name in parent:
        st = stats.relations[name]
        m, n, K, w = st.num_rows, st.num_data_cols, st.distinct_keys, widths[name]
        is_root = name == root
        nodes.append(NodeCost(
            name=name, m=m, n=n, K=K, width=w, is_root=is_root,
            first_pass=float(ROTATION_PASSES * m * n),
            gather=float(K * (w - n)),
            project=0.0 if is_root else float(ROTATION_PASSES * K * w),
        ))
    nodes = tuple(sorted(nodes, key=lambda c: c.name))
    return OrientationCost(root=root, parent=dict(parent), nodes=nodes,
                           total=sum(c.total for c in nodes))


def plan_cost(tree) -> float:
    """Estimated cost of an existing `JoinTree`-like object (duck-typed:
    needs ``tree.db`` and ``tree.parent``)."""
    from .stats import stats_for

    edges = [(p, c) for c, p in tree.parent.items() if p is not None]
    stats = stats_for(tree.db, edges)
    return orientation_cost(stats, tree.parent).total
