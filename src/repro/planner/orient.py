"""Orientation enumeration and root choice over the acyclic join graph.

An undirected edge set over ``r`` relations has exactly ``r`` rooted
orientations (one per root — re-orienting edges away from it), so exhaustive
enumeration is O(r^2) in the tree size and always affordable at ingest time.
Eager name validation lives here too: the facade calls `validate_names` so an
unknown root or edge endpoint raises a `ValueError` naming the offender and
listing the ingested relations, instead of a bare `KeyError` deep inside tree
construction.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from .cost import OrientationCost, orientation_cost
from .stats import DatabaseStats, normalize_edges, stats_for

__all__ = ["validate_names", "orient_edges", "enumerate_roots",
           "rank_orientations", "choose_root"]


def validate_names(names: Iterable[str], edges: Sequence[tuple[str, str]],
                   root: str | None = None) -> None:
    """Raise ValueError if ``root`` or any edge endpoint is not in ``names``."""
    have = sorted(names)
    have_set = set(have)
    unknown = sorted({n for e in edges for n in e if n not in have_set})
    if root is not None and root not in have_set and root not in unknown:
        unknown.insert(0, root)
    if unknown:
        noun = "relation" if len(unknown) == 1 else "relations"
        raise ValueError(
            f"unknown {noun} {', '.join(map(repr, unknown))}; "
            f"ingested relations are {have}")


def orient_edges(names: Iterable[str], edges: Sequence[tuple[str, str]],
                 root: str) -> dict[str, str | None]:
    """Orient undirected ``edges`` away from ``root``: a parent map covering
    every name (root -> None). Raises ValueError on unknown names, on edges
    that do not form a spanning tree, and on disconnected relations."""
    names = list(names)
    validate_names(names, edges, root)
    adj: dict[str, list[str]] = {n: [] for n in names}
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    parent: dict[str, str | None] = {root: None}
    stack = [root]
    while stack:
        node = stack.pop()
        for nb in adj[node]:
            if nb not in parent:
                parent[nb] = node
                stack.append(nb)
    missing = sorted(set(names) - set(parent))
    if missing:
        raise ValueError(
            f"edges do not connect {missing} to root {root!r}; "
            "every ingested relation must be reachable through the join edges")
    return parent


def enumerate_roots(names: Iterable[str],
                    edges: Sequence[tuple[str, str]]) -> list[tuple[str, dict[str, str | None]]]:
    """All rooted orientations as ``(root, parent_map)``, one per relation."""
    names = list(names)
    return [(r, orient_edges(names, edges, r)) for r in names]


def rank_orientations(db, edges: Sequence[tuple[str, str]],
                      stats: DatabaseStats | None = None) -> list[OrientationCost]:
    """Every orientation scored and sorted cheapest-first (ties: root name,
    so the ranking — and therefore `choose_root` — is deterministic)."""
    if stats is None:
        stats = stats_for(db, normalize_edges(edges))
    ranked = [orientation_cost(stats, parent)
              for _, parent in enumerate_roots(db.names, edges)]
    ranked.sort(key=lambda oc: (oc.total, oc.root))
    return ranked


def choose_root(db, edges: Sequence[tuple[str, str]],
                stats: DatabaseStats | None = None) -> str:
    """The cheapest orientation's root under the cost model."""
    return rank_orientations(db, edges, stats)[0].root
