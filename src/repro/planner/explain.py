"""Human-readable orientation ranking — backs ``JoinDataset.explain()``."""

from __future__ import annotations

from typing import Sequence

from .cost import OrientationCost

__all__ = ["explain_text"]


def explain_text(ranking: Sequence[OrientationCost],
                 chosen: str | None = None,
                 current: str | None = None) -> str:
    """Render a ranked orientation table plus the winner's node breakdown.

    ``chosen`` marks the planner's pick (``*``), ``current`` the orientation a
    live dataset is actually running (``=``) — they differ after appends shift
    the estimates but before an adaptive re-root lands.
    """
    if not ranking:
        return "no orientations to rank"
    lines = ["join-tree orientations, cheapest first "
             "(cost ~ element touches; see repro.planner.cost):"]
    width = max(len(oc.root) for oc in ranking)
    for i, oc in enumerate(ranking):
        marks = ("*" if oc.root == chosen else " ") + \
                ("=" if oc.root == current else " ")
        ratio = oc.total / ranking[0].total if ranking[0].total else 1.0
        lines.append(f"  {marks}{i + 1}. root={oc.root:<{width}}  "
                     f"cost={oc.total:>12.0f}  ({ratio:.2f}x)")
    best = ranking[0]
    lines.append(f"  per-node breakdown for root={best.root}:")
    for nc in best.nodes:
        role = "root" if nc.is_root else f"child of {best.parent[nc.name]}"
        lines.append(
            f"    {nc.name:<{width}}  m={nc.m:<8d} K={nc.K:<8d} "
            f"w={nc.width:<4d} first={nc.first_pass:<10.0f} "
            f"gather={nc.gather:<10.0f} project={nc.project:<10.0f} [{role}]")
    if chosen is not None:
        lines.append(f"  * = planner choice ({chosen})")
    if current is not None:
        lines.append(f"  = = currently running ({current})")
    return "\n".join(lines)
