"""One façade for the join-factorization stack: `Session` / `JoinDataset`.

FiGaRo is one capability — QR/SVD/PCA/least-squares over a join without
materializing it — and this module is its one user-facing surface (exported
as ``repro.figaro``). A `Session` owns the compute configuration (engine,
dtype policy, mesh/sharding, bucketing defaults); a `JoinDataset` owns one
join's **plan lifecycle** (lazy capacity-plan build, online appends, stats)
and exposes the fluent compute methods::

    from repro import figaro

    sess = figaro.Session(mesh=mesh, headroom=64)     # compute config, once
    ds = sess.ingest(tables).join("Orders", edges)    # -> JoinDataset
    r = ds.qr()                                       # compiles lazily
    pca = ds.pca(k=3)
    beta, resid = ds.lsq("price", ridge=0.1)          # label by column name
    ds.append("Reviews", {"prod": keys}, rows)        # zero-retrace append
    ds.qr()                                           # launch-only
    server = ds.serve(kind="qr")                      # async pipelined server
    fut = server.submit(request)                      # -> FigaroFuture
    r = fut.result()                                  # submission-order answer

Everything underneath — `FigaroEngine` executable caching, plan-as-pytree
jit, `plan_cache` bucketing/refreshes, `shard_map` serving — is the machinery
of PRs 1-3; this module only decides *when* each piece runs.

Migration table (old call -> new call)
--------------------------------------

===================================================  ==========================================
legacy entry point                                   Session / JoinDataset
===================================================  ==========================================
``Database.from_arrays(t)`` + ``full_reduce``        ``sess.ingest(t).join(root, edges)``
  + ``JoinTree.from_edges`` + ``build_plan``
``join(root, edges)`` (hand-picked root)             ``join(edges, root="auto")`` (figaro-plan)
``figaro_qr(plan, dtype=...)``                       ``ds.qr(dtype=...)``
``figaro_qr_batched(plan, batch)``                   ``ds.qr(batch)`` (leading batch axis)
``svd_over_join(plan)``                              ``ds.svd()``
``pca_over_join(plan, k)``                           ``ds.pca(k=k)``
``least_squares_over_join(plan, label_col=j)``       ``ds.lsq(j)`` / ``ds.lsq("col_name")``
``build_capacity_plan(tree, headroom=h)``            ``Session(headroom=h).from_tree(tree)``
``refresh_plan(plan, {n: (keys, rows)})``            ``ds.append(n, keys, rows)``
``engine.qr(plan, b, batched=True, shard=mesh)``     ``Session(mesh=mesh)`` ... ``ds.qr(b)``
``make_figaro_server(plan, kind=..., mesh=...)``     ``ds.serve(kind=...)``
``server(batch)`` (blocking one-shot)                ``server.submit(...)`` -> `FigaroFuture`
``default_engine()``                                 ``default_session().engine``
===================================================  ==========================================

(``server(batch)`` still works — it is now ``submit(batch).result()`` over
the same async pipeline; prefer ``submit`` to let requests coalesce and
overlap, and use ``server.append(...)`` / ``ds.append(...)``
interchangeably — dataset and server share one plan holder.)

The legacy entry points still work — they are thin delegations onto the
module-level `default_session()` — but new code should start here: future
capabilities (delta-aware counts, randomized sketching front-ends, TPU
kernels) land as Session options and JoinDataset methods, the way async
serving (`train.async_serve`) landed behind ``ds.serve()``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import FigaroEngine, default_engine, plan_for
from repro.core.join_tree import FigaroPlan, JoinTree, build_plan
from repro.core.plan_cache import (PlanHolder, _append_rows, bucket_spec,
                                   build_capacity_plan, pad_data, pad_plan,
                                   spec_fits)
from repro.core.relation import Database, full_reduce
from repro.planner import (DatabaseStats, Replanner, choose_root,
                           explain_text, rank_orientations, validate_names)
from repro.planner.stats import normalize_edges
from repro.train.async_serve import SERVE_KINDS, validate_serve_kind

__all__ = ["Session", "TableSet", "JoinDataset", "default_session",
           "SERVE_KINDS"]

_UNSET = object()

# Per-kind dtype defaults when the session does not pin one — identical to
# the legacy module-level entry points (QR serves in float32 by default; the
# spectral/regression reads default to float64 like the paper's evaluation).
_KIND_DTYPES = {
    "r0": jnp.float32,
    "qr": jnp.float32,
    "svd": jnp.float64,
    "pca": jnp.float64,
    "least_squares": jnp.float64,
}

# serve() kind -> engine pipeline kind (for dtype policy resolution). The
# kind *list* itself is `SERVE_KINDS` (re-exported from
# `repro.train.async_serve` — one source of truth, one eager validator,
# shared with `make_figaro_server`).
_SERVE_ENGINE_KINDS = {"qr": "qr", "svd": "svd", "pca": "pca",
                       "lsq": "least_squares"}
assert tuple(_SERVE_ENGINE_KINDS) == SERVE_KINDS


class Session:
    """Owns the compute configuration of the join-factorization stack.

    One `Session` = one engine (executable cache + trace/eviction counters),
    one dtype policy, one mesh/sharding choice, and one bucketing default.
    Datasets made from it (`ingest(...).join(...)` / `from_tree(...)`)
    inherit that configuration; per-call keyword overrides always win.

    Parameters
    ----------
    engine:      a `FigaroEngine` to share (default: a fresh engine built
                 from ``donate_data`` / ``max_cached``). Sharing one engine
                 across sessions shares its executable cache.
    mesh:        a `jax.sharding.Mesh`; batched dispatches shard their
                 request-batch axis over ``mesh[shard_axis]`` (one executable
                 per (plan signature, mesh signature) answers the global
                 batch). ``None`` = single-device dispatch.
    dtype:       pin every pipeline to one dtype; ``None`` (default) keeps
                 the per-kind legacy defaults (qr/r0: float32, svd/pca/lsq:
                 float64).
    bucket:      ``True`` (default): datasets build **bucketed** capacity
                 plans (power-of-two node sizes) and ad-hoc plans are padded
                 into their buckets at dispatch, so near-miss shapes share
                 one executable. ``False``: capacities equal the exact live
                 sizes — bit-identical to the pre-Session exact path, but
                 every append regrows the plan (one retrace each).
    headroom:    extra row capacity per node reserved at plan build, so a
                 known append rate cannot immediately overflow a bucket.
    method, leaf_rows, panel, use_kernel, assembly:
                 pipeline defaults forwarded to every dispatch:
                 ``use_kernel=True`` routes each join-tree node through the
                 fused Pallas pass (`repro.kernels.node_fused`; compiled on
                 TPU/GPU, interpreted on CPU), ``assembly`` ("padded" |
                 "band") picks the R₀ materialization (`repro.core.figaro`).
                 Both are static options — part of the executable cache key.
    donate_data, max_cached:
                 forwarded to the engine constructor; combining either with
                 ``engine=`` raises (configure the engine directly instead).
                 Sessions default to non-donating engines (safe for repeated
                 dispatch of the same buffers); ``max_cached`` bounds the
                 per-kind executable cache (LRU, evictions counted).

    Capacity vs live size (the contract `JoinDataset` operates under)
    -----------------------------------------------------------------
    **Capacity** is static: each node's bucketed ``(rows, keys,
    parent-keys)`` plus the R₀ row layout are part of the plan's treedef and
    are baked into the compiled executable. **Live size** is dynamic: the
    live-row mask and the zeroed dead ``group_count`` slots are pytree
    *leaves*, so they change per dispatch without retracing. Dead rows carry
    Givens weight 0 and emit exactly-zero R₀ rows — a capacity plan computes
    exactly what the underlying exact plan computes.

    Compile-count contract
    ----------------------
    One compilation per (pipeline kind, plan signature, mesh signature,
    static options). ``ds.append(...)`` that stays within capacity keeps the
    signature — the next dispatch is launch-only, **zero retraces**
    (`ds.stats()` exposes the engine's per-kind trace counters so callers
    can assert this instead of guessing). An append that overflows a bucket
    regrows the capacities: exactly one retrace on the next dispatch, and
    ``ds.stats()["regrows"]`` counts it. With ``max_cached=``, evicted
    signatures recompile on next use (counted by both counters).
    """

    def __init__(self, *, engine: FigaroEngine | None = None, mesh=None,
                 shard_axis: str = "data", dtype=None, bucket: bool = True,
                 headroom: int = 0, method: str = "tsqr",
                 leaf_rows: int = 256, panel: int = 32,
                 use_kernel: bool = False, assembly: str = "padded",
                 donate_data: bool | None = None,
                 max_cached: int | None = None):
        if engine is not None and (max_cached is not None
                                   or donate_data is not None):
            raise ValueError("pass max_cached=/donate_data= to the engine's "
                             "constructor when supplying engine=")
        self.engine = engine if engine is not None else FigaroEngine(
            donate_data=bool(donate_data), max_cached=max_cached)
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.dtype = dtype
        self.bucket = bucket
        self.headroom = headroom
        self.method = method
        self.leaf_rows = leaf_rows
        self.panel = panel
        self.use_kernel = use_kernel
        self.assembly = assembly

    # -- dataset construction ------------------------------------------------

    def ingest(self, tables) -> "TableSet":
        """Wrap raw tables for the fluent chain: ``ingest(t).join(root, e)``.

        ``tables`` is either a ready `Database` or the
        ``{name: (key_columns, data_matrix, column_names)}`` mapping of
        `Database.from_arrays`.
        """
        if isinstance(tables, Database):
            return TableSet(self, tables)
        if isinstance(tables, dict):
            return TableSet(self, Database.from_arrays(tables))
        raise TypeError(
            f"ingest() expects a Database or a {{name: (keys, data, cols)}} "
            f"dict, got {type(tables).__name__}")

    def from_tree(self, tree: JoinTree) -> "JoinDataset":
        """A `JoinDataset` over an existing `JoinTree`."""
        if not isinstance(tree, JoinTree):
            raise TypeError(f"from_tree() expects a JoinTree, "
                            f"got {type(tree).__name__}")
        return JoinDataset(self, tree)

    # -- option resolution ---------------------------------------------------

    def _dtype_for(self, kind: str, override):
        if override is not None:
            return override
        if self.dtype is not None:
            return self.dtype
        return _KIND_DTYPES[kind]

    def _post_opts(self, kind: str, dtype, method, leaf_rows, panel,
                   use_kernel, assembly) -> dict:
        return dict(
            dtype=self._dtype_for(kind, dtype),
            method=self.method if method is None else method,
            leaf_rows=self.leaf_rows if leaf_rows is None else leaf_rows,
            panel=self.panel if panel is None else panel,
            use_kernel=self.use_kernel if use_kernel is None else use_kernel,
            assembly=self.assembly if assembly is None else assembly)

    @staticmethod
    def _is_batched(data, batched) -> bool:
        """A leading batch axis ([B, m_i, n_i] leaves) switches to the
        batched (vmapped) dispatch; per-node plan data is always 2-D."""
        if batched is not None:
            return batched
        if data is None:
            return False
        leaves = list(data)
        return bool(leaves) and np.ndim(leaves[0]) == 3

    def _shard_for(self, batched: bool):
        if not batched or self.mesh is None:
            return None
        return (self.mesh, self.shard_axis)

    def _dispatch_opts(self, data, batched, shard, bucket):
        batched = self._is_batched(data, batched)
        return dict(
            batched=batched,
            shard=self._shard_for(batched) if shard is _UNSET else shard,
            bucket=self.bucket if bucket is None else bucket)

    # -- plan-level compute (the legacy delegation surface) ------------------

    def r0(self, tree_or_plan, data=None, *, batched=None, shard=_UNSET,
           bucket=None, dtype=None, use_kernel=None, assembly=None):
        """R₀ of Algorithm 2 under this session's configuration."""
        return self.engine.r0(
            plan_for(tree_or_plan), data,
            dtype=self._dtype_for("r0", dtype),
            use_kernel=self.use_kernel if use_kernel is None else use_kernel,
            assembly=self.assembly if assembly is None else assembly,
            **self._dispatch_opts(data, batched, shard, bucket))

    def qr(self, tree_or_plan, data=None, *, batched=None, shard=_UNSET,
           bucket=None, dtype=None, method=None, leaf_rows=None, panel=None,
           use_kernel=None, assembly=None):
        """Upper-triangular R of the join's QR ([B, N, N] when batched)."""
        return self.engine.qr(
            plan_for(tree_or_plan), data,
            **self._post_opts("qr", dtype, method, leaf_rows, panel,
                              use_kernel, assembly),
            **self._dispatch_opts(data, batched, shard, bucket))

    def svd(self, tree_or_plan, data=None, *, k: int | None = None,
            batched=None, shard=_UNSET, bucket=None, dtype=None, method=None,
            leaf_rows=None, panel=None, use_kernel=None, assembly=None):
        """Singular values + right-singular vectors; ``k`` keeps the top-k."""
        s, vt = self.engine.svd(
            plan_for(tree_or_plan), data,
            **self._post_opts("svd", dtype, method, leaf_rows, panel,
                              use_kernel, assembly),
            **self._dispatch_opts(data, batched, shard, bucket))
        if k is not None:
            s, vt = s[..., :k], vt[..., :k, :]
        return s, vt

    def pca(self, tree_or_plan, data=None, *, k: int | None = None,
            center: bool = True, batched=None, shard=_UNSET, bucket=None,
            dtype=None, method=None, leaf_rows=None, panel=None,
            use_kernel=None, assembly=None):
        """PCA of the join matrix from R (+ factorized means)."""
        return self.engine.pca(
            plan_for(tree_or_plan), data, k=k, center=center,
            **self._post_opts("pca", dtype, method, leaf_rows, panel,
                              use_kernel, assembly),
            **self._dispatch_opts(data, batched, shard, bucket))

    def least_squares(self, tree_or_plan, label_col: int, data=None, *,
                      ridge: float = 0.0, batched=None, shard=_UNSET,
                      bucket=None, dtype=None, method=None, leaf_rows=None,
                      panel=None, use_kernel=None, assembly=None):
        """argmin_β ‖A[:, feats]·β − A[:, label]‖² over the join."""
        return self.engine.least_squares(
            plan_for(tree_or_plan), label_col, data, ridge=ridge,
            **self._post_opts("least_squares", dtype, method, leaf_rows,
                              panel, use_kernel, assembly),
            **self._dispatch_opts(data, batched, shard, bucket))

    def serve(self, tree_or_plan, *, kind: str = "qr", label_col=None,
              k=None, ridge: float = 0.0, dtype=None, method=None,
              leaf_rows=None, use_kernel=None, assembly=None, mesh=_UNSET,
              shard_axis=None, max_batch: int = 32, queue_depth: int = 2):
        """An async pipelined serving endpoint for one join structure (see
        `train.serve.make_figaro_server`): ``submit(request)`` returns a
        `FigaroFuture`, pending requests coalesce up to ``max_batch`` rows,
        and ``queue_depth`` batches pipeline through the engine (depth >= 2
        overlaps the next batch's H2D staging with the in-flight dispatch).
        Engine/mesh/dtype default to this session's configuration.
        ``tree_or_plan`` may also be a `plan_cache.PlanHolder` to share plan
        state (what `JoinDataset.serve` passes)."""
        from repro.train.serve import make_figaro_server

        validate_serve_kind(kind)
        target = tree_or_plan if isinstance(tree_or_plan, PlanHolder) \
            else plan_for(tree_or_plan)
        return make_figaro_server(
            target, kind=kind, label_col=label_col, k=k,
            ridge=ridge, engine=self.engine,
            dtype=self._dtype_for(_SERVE_ENGINE_KINDS[kind], dtype),
            method=self.method if method is None else method,
            leaf_rows=self.leaf_rows if leaf_rows is None else leaf_rows,
            use_kernel=self.use_kernel if use_kernel is None else use_kernel,
            assembly=self.assembly if assembly is None else assembly,
            mesh=self.mesh if mesh is _UNSET else mesh,
            shard_axis=self.shard_axis if shard_axis is None else shard_axis,
            max_batch=max_batch, queue_depth=queue_depth)

    def partitioned_qr(self, tree: JoinTree, num_parts: int, *, mesh=_UNSET,
                       dtype=None, method=None, use_kernel=None,
                       assembly=None):
        """Fact-partitioned multi-device QR (`distributed` layer) through
        this session's engine/mesh."""
        from repro.core.distributed import partitioned_figaro_qr

        return partitioned_figaro_qr(
            tree, num_parts, engine=self.engine,
            mesh=self.mesh if mesh is _UNSET else mesh,
            dtype=(dtype if dtype is not None else
                   self.dtype if self.dtype is not None else jnp.float64),
            method=self.method if method is None else method,
            use_kernel=self.use_kernel if use_kernel is None else use_kernel,
            assembly=self.assembly if assembly is None else assembly)


@dataclasses.dataclass
class TableSet:
    """Ingested tables awaiting a join choice: ``ingest(t).join(edges)``."""

    session: Session
    db: Database

    def join(self, *args, root: str | None = None, edges=None,
             reduce: bool = True, reroot: bool | None = None,
             hysteresis: float = 0.5) -> "JoinDataset":
        """Fix the join tree over ``edges`` (undirected pairs, any
        orientation) and return a `JoinDataset`.

        Accepted call shapes::

            join(edges)                    # root="auto": figaro-plan picks it
            join(edges, root="auto")       # same, explicit
            join(edges, root="Orders")     # hand-rooted
            join("Orders", edges)          # legacy positional order

        With ``root="auto"`` (or omitted) the planner
        (`repro.planner.choose_root`) enumerates every rooted orientation of
        the acyclic join graph and picks the cheapest under the paper's cost
        model; ``ds.explain()`` shows the ranking. The chosen tree is built
        through the same `JoinTree.from_edges` as a hand-rooted join, so when
        the planner picks the root you would have picked, the plan signature
        — and therefore the compiled executable — is identical: auto costs
        zero extra retraces.

        ``reroot`` enables adaptive re-rooting (defaults to on iff the root
        was auto-chosen): appends update the planner's exact statistics, and
        when growth makes another orientation cheaper by more than the
        ``hysteresis`` margin the dataset rebuilds on it at the next drain
        point (in-flight server futures still answer on the old plan).

        ``reduce`` drops dangling tuples first (`full_reduce`), which the
        FiGaRo pipeline requires of its inputs. Unknown relation names in
        ``root``/``edges`` raise `ValueError` here, eagerly, listing the
        ingested relations.
        """
        if len(args) == 2:  # legacy: join(root, edges)
            pos_root, pos_edges = args
        elif len(args) == 1:
            # join(edges) or join(edges, root=...) — a lone str is a root
            # (legacy partial form join("Orders", edges=...)).
            pos_root, pos_edges = (args[0], None) \
                if isinstance(args[0], str) else (None, args[0])
        elif len(args) == 0:
            pos_root, pos_edges = None, None
        else:
            raise TypeError(f"join() takes at most 2 positional arguments "
                            f"(root, edges), got {len(args)}")
        if pos_root is not None and root is not None:
            raise TypeError("join() got multiple values for 'root'")
        if pos_edges is not None and edges is not None:
            raise TypeError("join() got multiple values for 'edges'")
        root = pos_root if root is None else root
        edges = pos_edges if edges is None else edges
        if edges is None:
            raise TypeError("join() is missing 'edges'")
        edges = [tuple(e) for e in edges]
        auto = root is None or (root == "auto"
                                and "auto" not in self.db.relations)
        validate_names(self.db.names, edges, None if auto else root)
        db = full_reduce(self.db, edges) if reduce else self.db
        if auto:
            root = choose_root(db, edges)
        return JoinDataset(self.session, JoinTree.from_edges(db, root, edges),
                           edges=edges, auto=auto,
                           reroot=auto if reroot is None else reroot,
                           hysteresis=hysteresis)


class JoinDataset:
    """One join's plan lifecycle + fluent compute handle.

    The capacity plan is built lazily on first compute
    (`plan_cache.build_capacity_plan` under the session's
    ``bucket``/``headroom`` policy) and refreshed in place by
    ``append(...)`` (`plan_cache.refresh_plan`): appends that stay within
    the bucketed capacities keep the plan signature, so the next dispatch
    reuses the cached executable with **zero retraces** — ``stats()``
    surfaces the trace/eviction counters and per-node capacity vs live rows
    so callers can assert that instead of guessing.

    Compute methods (``qr`` / ``svd`` / ``pca`` / ``lsq`` and raw ``r0``)
    read everything off the factorized R. Passing ``data`` overrides the
    ingested tables' values: 2-D per-node leaves dispatch a single pipeline;
    a leading batch axis ([B, rows_i, n_i]) switches to the batched
    (vmapped) dispatch — sharded over the session's mesh when it has one.
    Request leaves sized to the *live* row counts are zero-padded up to
    capacity here; any other row count raises (a stale batch built before an
    ``append`` must be rebuilt, not silently zero-filled).
    """

    def __init__(self, session: Session, tree: JoinTree, *, edges=None,
                 auto: bool = False, reroot: bool = False,
                 hysteresis: float = 0.5):
        self._session = session
        self._tree = tree  # pre-plan only; once built, holder.plan owns it
        # The holder is the ONE plan state for this join: servers spawned by
        # `serve()` share it, so an append through either surface (dataset or
        # server) is visible to both — no silent plan fork.
        self._holder = PlanHolder(
            on_regrow=None if session.bucket else self._exact_regrow)
        # figaro-plan state: the undirected edge set (so every orientation
        # stays reachable), whether the root was auto-chosen, the adaptive
        # re-rooting policy, and warm capacity plans per alternative root.
        self._edges = normalize_edges(edges if edges is not None
                                      else tree.edges())
        self._auto = auto
        self._reroot_enabled = reroot
        self._hysteresis = hysteresis
        self._replanner: Replanner | None = None
        self._warm_plans: dict[str, FigaroPlan] = {}

    # -- plan lifecycle ------------------------------------------------------

    @property
    def tree(self) -> JoinTree:
        plan = self._holder.plan
        return plan.source_tree if plan is not None else self._tree

    @property
    def plan(self) -> FigaroPlan:
        """The capacity plan (built lazily on first access; shared — through
        a `plan_cache.PlanHolder` — with every server from `serve()`)."""
        plan = self._holder.plan
        if plan is None:
            if self._auto and self._holder.counters()[0] > 0:
                # Pre-plan appends may have shifted the ranking; nothing is
                # built yet, so re-choosing the root is free.
                best = choose_root(self._tree.db, self._edges)
                if best != self._tree.root:
                    self._tree = JoinTree.from_edges(
                        self._tree.db, best, list(self._edges))
            if self._session.bucket:
                plan = build_capacity_plan(
                    self._tree, headroom=self._session.headroom)
            else:
                plan = self._exact_capacity_plan(self._tree)
            self._holder.set(plan)
            if self._auto and self._session.bucket:
                self._warm_runner_up()
        return plan

    def _warm_runner_up(self) -> None:
        # Keep the second-cheapest orientation's capacity plan warm: pure
        # numpy ingest + bucketing, no compile — if appends later flip the
        # ranking, the re-root re-pads into this spec (when it still fits)
        # instead of re-deriving capacities from scratch.
        tree = self.tree
        ranking = rank_orientations(tree.db, self._edges)
        if len(ranking) < 2:
            return
        runner_up = ranking[1].root
        self._warm_plans[runner_up] = build_capacity_plan(
            JoinTree.from_edges(tree.db, runner_up, list(self._edges)),
            headroom=self._session.headroom)

    def _exact_capacity_plan(self, tree: JoinTree) -> FigaroPlan:
        # Exact capacities: bit-identical numerics to the exact plan, but
        # any append overflows and regrows (one retrace each).
        exact = build_plan(tree)
        plan = pad_plan(exact, exact.spec)
        plan.source_tree = tree
        plan.capacity_headroom = self._session.headroom
        return plan

    def _exact_regrow(self, new_plan: FigaroPlan) -> FigaroPlan:
        # Keep the session's bucket=False contract on regrow: refresh_plan
        # grows into power-of-two buckets, but this dataset's capacities must
        # stay exact (bit-identical path, one retrace per append).
        return self._exact_capacity_plan(new_plan.source_tree)

    def append(self, node: str, keys, rows) -> bool:
        """Append rows to one relation; returns True when the refresh stayed
        within the plan's capacities (next dispatch is launch-only).

        ``keys`` maps key-attribute name -> integer array, ``rows`` is a
        [rows, n_i] data matrix — the `plan_cache.refresh_plan` convention.
        Before the first compute the tables are simply grown (the capacity
        plan has not been built yet, so there is nothing to refresh). Once
        servers exist, the refresh first drains their in-flight work, and
        they serve the refreshed plan from the next dispatch on.

        With adaptive re-rooting on (``join(..., root="auto")``), each append
        also updates the planner's exact statistics; when growth makes a
        different orientation cheaper past the hysteresis margin, the dataset
        rebuilds on it right here — at a drain point, so requests already
        submitted to a live server are still answered on the old plan — and
        returns False (the new orientation's first dispatch compiles). Column
        layout follows the live tree: re-read ``ds.columns`` after appends
        rather than caching it.
        """
        if self._holder.plan is None:
            rels = dict(self._tree.db.relations)
            if node not in rels:
                raise KeyError(f"unknown relation {node!r}; "
                               f"have {sorted(rels)}")
            rels[node] = _append_rows(rels[node], keys, rows)
            self._tree = JoinTree(Database(rels), dict(self._tree.parent))
            self._holder.note_external_append(
                node, rows=int(np.atleast_2d(np.asarray(rows)).shape[0]))
            return True
        in_capacity = self._holder.refresh({node: (keys, rows)})
        if self._reroot_enabled:
            if self._replanner is None:
                # First post-plan append: collect stats now (they already
                # include the rows this refresh just ingested).
                self._replanner = self._make_replanner()
            else:
                self._replanner.note_append(node, self._key_rows(node, keys))
            proposal = self._replanner.proposal()
            if proposal is not None:
                self._reroot_to(proposal)
                in_capacity = False  # new orientation => new signature
        return in_capacity

    # -- figaro-plan: explain + adaptive re-rooting --------------------------

    def explain(self) -> str:
        """Human-readable ranking of every join-tree orientation under the
        planner's cost model (`repro.planner`), cheapest first, with the
        winner's per-node breakdown. ``*`` marks the planner's current pick,
        ``=`` the orientation this dataset is actually running — they can
        differ between an append that shifts the estimates and the re-root
        that follows (or permanently, for a hand-rooted join)."""
        rp = self._replanner
        ranking = rp.ranking() if rp is not None else \
            rank_orientations(self.tree.db, self._edges)
        return explain_text(ranking, chosen=ranking[0].root,
                            current=self.tree.root)

    def _key_rows(self, node: str, keys) -> np.ndarray:
        attrs = self.tree.db[node].key_attrs
        cols = [np.atleast_1d(np.asarray(keys[a], dtype=np.int64))
                for a in attrs]
        return np.stack(cols, axis=1) if cols else \
            np.zeros((1, 0), dtype=np.int64)

    def _make_replanner(self) -> Replanner:
        tree = self.tree
        return Replanner(
            stats=DatabaseStats.collect(tree.db, self._edges),
            names=tuple(tree.db.names), edges=self._edges,
            current_root=tree.root, hysteresis=self._hysteresis)

    def _reroot_to(self, root: str) -> None:
        """Rebuild the capacity plan on a new orientation and swap it in at a
        drain point (`PlanHolder.replace`). The displaced orientation's plan
        becomes the new warm alternative."""
        old = self._holder.plan
        tree = JoinTree.from_edges(old.source_tree.db, root,
                                   list(self._edges))
        if self._session.bucket:
            exact = build_plan(tree)
            warm = self._warm_plans.pop(root, None)
            cap = warm.spec if warm is not None \
                and spec_fits(exact.spec, warm.spec) \
                else bucket_spec(exact.spec, headroom=self._session.headroom)
            plan = pad_plan(exact, cap)
            plan.source_tree = tree
            plan.capacity_headroom = self._session.headroom
        else:
            plan = self._exact_capacity_plan(tree)
        self._holder.replace(plan)
        self._warm_plans[old.source_tree.root] = old
        if self._replanner is not None:
            self._replanner.on_reroot(root)

    def stats(self) -> dict:
        """Lifecycle + compile counters: per-node capacity vs live rows,
        appends/regrows, and the session engine's per-kind trace counts,
        eviction counts, and cache size. A zero-retrace append shows up as
        ``traces`` staying flat across dispatches. Appends made through a
        live server (``server.append``) are counted here too — the dataset
        and its servers share one plan holder."""
        engine = self._session.engine
        plan = self._holder.plan
        nodes = {}
        if plan is not None:
            for sp, ix in zip(plan.spec.nodes, plan.index):
                live = int(ix.row_mask.sum()) if ix.row_mask is not None \
                    else sp.m
                nodes[sp.name] = {"capacity_rows": sp.m, "live_rows": live}
        else:
            for name in self._tree.preorder():
                nodes[name] = {"capacity_rows": None,
                               "live_rows": self._tree.db[name].num_rows}
        appends, regrows = self._holder.counters()
        return {
            "plan_built": plan is not None,
            "appends": appends,
            "regrows": regrows,
            "root": self.tree.root,
            "auto_root": self._auto,
            "reroots": self._holder.reroot_count(),
            "append_volume": self._holder.append_volumes(),
            "nodes": nodes,
            "traces": self._session.engine.trace_counts(),
            "trace_count": engine.trace_count(),
            "evictions": engine.eviction_count(),
            "cached_executables": engine.cache_size(),
        }

    # -- column naming -------------------------------------------------------

    @property
    def columns(self) -> tuple[str, ...]:
        """Qualified global column names (``"Node.attr"``) in the plan's
        preorder column layout. Follows the *live* tree: an adaptive re-root
        changes the preorder, and with it the column order of R."""
        tree = self.tree
        return tuple(f"{name}.{a}" for name in tree.preorder()
                     for a in tree.db[name].data_attrs)

    def column_index(self, col) -> int:
        """Global column index of ``col``: an int (validated), a bare
        attribute name (must be unique across relations), or a qualified
        ``"Node.attr"``."""
        cols = self.columns
        if isinstance(col, (int, np.integer)):
            if not 0 <= int(col) < len(cols):
                raise IndexError(f"column index {col} out of range "
                                 f"[0, {len(cols)})")
            return int(col)
        if not isinstance(col, str):
            raise TypeError(f"column must be an int or str, "
                            f"got {type(col).__name__}")
        if "." in col:
            if col in cols:
                return cols.index(col)
            raise KeyError(f"unknown column {col!r}; have {list(cols)}")
        hits = [i for i, c in enumerate(cols) if c.split(".", 1)[1] == col]
        if not hits:
            raise KeyError(f"unknown column {col!r}; have {list(cols)}")
        if len(hits) > 1:
            raise KeyError(f"column name {col!r} is ambiguous: "
                           f"{[cols[i] for i in hits]} — qualify it")
        return hits[0]

    # -- compute -------------------------------------------------------------

    def _request_data(self, data):
        """Pad live-sized request leaves up to capacity (see class doc)."""
        if data is None:
            return None
        plan = self.plan
        data = tuple(data)
        if len(data) != len(plan.spec.nodes):
            raise ValueError(
                f"expected one data leaf per relation "
                f"({len(plan.spec.nodes)}: {list(plan.spec.names)}), "
                f"got {len(data)}")
        sizes = [(int(ix.row_mask.sum()) if ix.row_mask is not None
                  else sp.m, sp)
                 for sp, ix in zip(plan.spec.nodes, plan.index)]
        if all(np.shape(d)[-2] == sp.m for d, (_, sp) in zip(data, sizes)):
            return data  # already capacity-shaped: no host round trip
        for d, (live, sp) in zip(data, sizes):
            if np.shape(d)[-2] not in (live, sp.m):
                raise ValueError(
                    f"{sp.name}: request data has {np.shape(d)[-2]} rows; "
                    f"expected the live size ({live}) or the capacity "
                    f"({sp.m}) — rebuild request buffers after append()")
        return pad_data(data, plan.spec)

    def r0(self, data=None, **overrides):
        return self._session.r0(self.plan, self._request_data(data),
                                **overrides)

    def qr(self, data=None, **overrides):
        """R of the join's QR; ``data`` with a leading batch axis serves the
        whole batch in one (mesh-sharded, when configured) dispatch."""
        return self._session.qr(self.plan, self._request_data(data),
                                **overrides)

    def svd(self, data=None, *, k: int | None = None, **overrides):
        """(s, Vᵀ) of the join matrix; ``k`` keeps the top-k."""
        return self._session.svd(self.plan, self._request_data(data), k=k,
                                 **overrides)

    def pca(self, data=None, *, k: int | None = None, center: bool = True,
            **overrides):
        """`PCAResult` (components, explained variance, factorized mean)."""
        return self._session.pca(self.plan, self._request_data(data), k=k,
                                 center=center, **overrides)

    def lsq(self, y, data=None, *, ridge: float = 0.0, **overrides):
        """Closed-form linear regression of label column ``y`` (index, bare
        name, or ``"Node.attr"``) against all other columns."""
        return self._session.least_squares(
            self.plan, self.column_index(y), self._request_data(data),
            ridge=ridge, **overrides)

    def serve(self, kind: str = "qr", *, label_col=None, **kw):
        """An async pipelined serving endpoint over this dataset's capacity
        plan (`train.serve.make_figaro_server`): ``submit(request)`` returns
        a `FigaroFuture`; ``server(batch)`` blocks for its answer.

        The server shares this dataset's plan *holder*: ``server.append``
        and ``ds.append`` refresh one plan state (draining the server's
        in-flight work first), so ``ds.plan`` / ``ds.stats()`` and the
        served plan can never fork.
        """
        if label_col is not None:
            label_col = self.column_index(label_col)
        _ = self.plan  # build the capacity plan before sharing the holder
        return self._session.serve(self._holder, kind=kind,
                                   label_col=label_col, **kw)


_DEFAULT_SESSION: Session | None = None


def default_session() -> Session:
    """Process-wide `Session` behind the legacy module-level entry points
    (`figaro_qr`, `svd_over_join`, ...): shares `default_engine()`'s
    executable cache and keeps the pre-Session defaults (no bucketing, no
    mesh, per-kind dtypes)."""
    global _DEFAULT_SESSION
    if _DEFAULT_SESSION is None:
        _DEFAULT_SESSION = Session(engine=default_engine(), bucket=False)
    return _DEFAULT_SESSION
