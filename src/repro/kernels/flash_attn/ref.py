"""Pure-jnp oracle for the flash-attention kernel (materialized softmax)."""

from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, q_pos, k_pos, *, causal: bool,
                        window: int | None):
    """q: [H, Tq, hd], k/v: [H, Tk, hd]; positions [H, Tq] / [H, Tk]."""
    hd = q.shape[-1]
    acc_dtype = jnp.float64 if q.dtype == jnp.float64 else jnp.float32
    s = jnp.einsum("hqd,hkd->hqk", q.astype(acc_dtype),
                   k.astype(acc_dtype)) / jnp.sqrt(hd).astype(acc_dtype)
    ok = k_pos[:, None, :] >= 0
    if causal:
        ok &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > q_pos[:, :, None] - window
    s = jnp.where(ok, s, -jnp.inf)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,hkd->hqd", w, v.astype(acc_dtype)).astype(q.dtype)
