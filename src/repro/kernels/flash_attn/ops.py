"""Jitted public wrapper for the fused attention kernel.

Folds GQA batch/head layout ([B, T, Hkv, G, hd] -> [B*Hkv*G] kernel heads,
with K/V broadcast per group) and dispatches interpret mode off-accelerator
(`repro.kernels._platform`) — the validation mode of this container; pass
``interpret=`` explicitly to override.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels._platform import resolve_interpret

from .kernel import (DEFAULT_BLOCK_KV, DEFAULT_BLOCK_Q,
                     flash_attention_kernel)


def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool | None = None):
    """Fused GQA attention.

    Args:
      q: [B, Tq, Hq, hd]; k/v: [B, Tk, Hkv, hd] with Hq % Hkv == 0.
      q_pos: [Tq] int32 absolute positions; k_pos: [Tk] (−1 = padded slot).
    Returns [B, Tq, Hq, hd].
    """
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    # [B, T, Hkv, G, hd] -> [B*Hkv*G, T, hd]; kernel heads with shared KV are
    # adjacent, so K/V tiles repeat per group (broadcast at dispatch).
    qh = (q.reshape(b, tq, hkv, g, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * g, tq, hd))
    kh = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * hkv, tk, hd), g,
                    axis=0)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * hkv, tk, hd), g,
                    axis=0)
    qp = jnp.broadcast_to(q_pos[None], (b * hkv * g, tq)).astype(jnp.int32)
    kp = jnp.broadcast_to(k_pos[None], (b * hkv * g, tk)).astype(jnp.int32)
    out = flash_attention_kernel(qh, kh, vh, qp, kp, causal=causal,
                                 window=window, block_q=block_q,
                                 block_kv=block_kv,
                                 interpret=resolve_interpret(interpret))
    return (out.reshape(b, hkv, g, tq, hd).transpose(0, 3, 1, 2, 4)
            .reshape(b, tq, hq, hd))
