"""Pallas TPU kernel: fused blockwise (flash-style) attention forward.

This is the kernel the §Perf blockwise accounting models: one HBM pass over
Q/K/V with the [Tq, Tk] score matrix never materialized — scores live in a
VMEM tile, the softmax is the online (running max / running sum) form, and
the output accumulates in the dtype derived from the inputs (f64 for f64
inputs, f32 otherwise).

TPU mapping:
  grid = (batch*heads, q_blocks, kv_blocks) with the KV dimension innermost,
  so each (bh, q-block) walks KV blocks sequentially carrying the online-
  softmax state (m, l, acc) in VMEM scratch. Block shapes are MXU-aligned:
  the two matmuls per block are [bq, hd]x[hd, bk] and [bq, bk]x[bk, hd] with
  hd and bk multiples of 128 (lane dim) and bq a multiple of 8 (sublanes).
  Causality and padding are handled with position tiles and an additive
  mask; fully-masked KV blocks still run (grid shapes are static) but
  contribute exp(-inf)=0 — the production scheduler skips them by
  restricting the kv grid per q-block (the ``causal_skip`` fast path lowers
  a triangular grid when Tq == Tk).

GQA: Q heads of one KV head are folded into the q-block rows (the caller
reshapes [B, T, Hkv, G, hd] -> [B*Hkv, T*G? no — [B*Hkv*G] heads with the
same K/V block index map]), so K/V tiles are fetched once per KV head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, out_ref,
                  m_ref, l_ref, acc_ref, *, causal: bool,
                  window: int | None, scale: float, num_kv_blocks: int,
                  acc_dtype):
    kv_i = pl.program_id(2)  # innermost: sequential online-softmax carry

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(acc_dtype)              # [bq, hd]
    k = k_ref[0].astype(acc_dtype)              # [bk, hd]
    v = v_ref[0].astype(acc_dtype)              # [bk, hd]
    qp = qpos_ref[0]                            # [bq] int32
    kp = kpos_ref[0]                            # [bk] int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=acc_dtype) * scale
    ok = (kp[None, :] >= 0)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                         # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                      # [bq, bk]
    corr = jnp.exp(m_prev - m_new)              # [bq, 1]
    l_new = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_new = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=acc_dtype)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(kv_i == num_kv_blocks - 1)
    def _finish():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_attention_kernel(q, k, v, q_pos, k_pos, *, causal: bool,
                           window: int | None, block_q: int, block_kv: int,
                           interpret: bool):
    """q: [H, Tq, hd], k/v: [H, Tk, hd], q_pos [H, Tq], k_pos [H, Tk].

    Returns [H, Tq, hd]. H folds batch*kv_heads*group (caller's layout).
    """
    h, tq, hd = q.shape
    tk = k.shape[1]
    acc_dtype = jnp.float64 if q.dtype == jnp.float64 else jnp.float32
    scale = 1.0 / (hd ** 0.5)
    nq = -(-tq // block_q)
    nk = -(-tk // block_kv)
    pad_q = nq * block_q - tq
    pad_k = nk * block_kv - tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)

    grid = (h, nq, nk)
    kern = functools.partial(_flash_kernel, causal=causal, window=window,
                             scale=scale, num_kv_blocks=nk,
                             acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, block_kv), lambda bh, qi, ki: (bh, ki)),
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), acc_dtype),   # running max m
            pltpu.VMEM((block_q, 1), acc_dtype),   # running sum l
            pltpu.VMEM((block_q, hd), acc_dtype),  # output accumulator
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out[:, :tq]
