"""Pure-jnp oracle for the segmented-tail kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.heads_tails import segmented_cumsum


def segmented_tail_ref(data, wa, first, coef_a, coef_b):
    """out[r] = coef_a[r]·data[r] + coef_b[r]·(segmented exclusive Σ wa)[r]."""
    excl = segmented_cumsum(wa, first[:, 0] > 0) - wa
    return coef_a * data + coef_b * excl
