"""Jitted public wrapper for the segmented-tail kernel.

On TPU/GPU the Pallas kernel runs compiled; everywhere else it runs in
``interpret=True`` mode (the kernel body executed by XLA on CPU), which is the
validation mode this container uses. The platform policy lives in
`repro.kernels._platform`; pass ``interpret=`` explicitly to override it.
"""

from __future__ import annotations

from repro.kernels._platform import resolve_interpret

from .kernel import segmented_tail_kernel


def segmented_tail(data, wa, first, coef_a, coef_b, *,
                   block_rows: int = 256, block_cols: int = 256,
                   interpret: bool | None = None):
    """Segmented generalized-tail transform (see kernel.py).

    Args:
      data, wa: [m, n]
      first: [m] or [m,1] segment-start indicator
      coef_a, coef_b: [m] or [m,1]
      interpret: force interpreter mode on/off (None = off-accelerator only).
    Returns [m, n] tails (rows at segment starts are garbage — caller masks).
    """
    if first.ndim == 1:
        first = first[:, None]
    if coef_a.ndim == 1:
        coef_a = coef_a[:, None]
    if coef_b.ndim == 1:
        coef_b = coef_b[:, None]
    return segmented_tail_kernel(
        data, wa, first.astype(data.dtype), coef_a.astype(data.dtype),
        coef_b.astype(data.dtype),
        block_rows=block_rows, block_cols=block_cols,
        interpret=resolve_interpret(interpret))
