"""Pallas TPU kernel: segmented generalized-tail transform (FiGaRo inner loop).

Computes, in one HBM pass,   out[r, :] = coef_a[r]·data[r, :] + coef_b[r]·s_excl[r, :]
where ``s_excl`` is the *segmented exclusive* prefix sum of ``wa = v·data``
(segments restart wherever ``first`` is set). With the paper's coefficient
choice this is exactly the generalized tail ``T(A, v)`` of Definition 3.4 for
every key segment at once — i.e. the block effect of all Givens rotation
sequences of Lemma 3.5, fused with their scaling.

TPU mapping: grid = (col_blocks, row_blocks) with the row dimension innermost,
so each column stripe walks rows sequentially carrying the running segment
prefix in VMEM scratch; within a block the segmented scan is a Hillis–Steele
ladder (log₂ bm vector steps) on the VPU. The scan accumulates in the dtype
derived from the inputs (f64 for f64 data, f32 otherwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 256


def _shift_down(x: jnp.ndarray, off: int) -> jnp.ndarray:
    """Rows shifted down by `off` (row r reads r-off), zero-filled at the top."""
    pad = jnp.zeros((off,) + x.shape[1:], x.dtype)
    return jnp.concatenate([pad, x[: x.shape[0] - off]], axis=0)


def _segtail_kernel(data_ref, wa_ref, first_ref, ca_ref, cb_ref, out_ref,
                    carry_ref, *, block_rows: int, acc_dtype):
    i = pl.program_id(1)  # row block (innermost => sequential carry is valid)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    wa = wa_ref[...].astype(acc_dtype)          # [bm, bn]
    first = first_ref[...].astype(acc_dtype)    # [bm, 1]; 1.0 at segment starts

    # Segmented inclusive Hillis–Steele scan within the block:
    #   (f_a, x_a) ⊕ (f_b, x_b) = (f_a|f_b, x_b + (f_b ? 0 : x_a))
    x, f = wa, first
    off = 1
    while off < block_rows:
        x = x + (1.0 - f) * _shift_down(x, off)
        f = jnp.maximum(f, _shift_down(f, off))
        off *= 2
    # f is now "any segment start in this block up to r" — rows before the
    # first in-block boundary continue the previous block's segment.
    incl = x + (1.0 - f) * carry_ref[...]
    excl = incl - wa
    carry_ref[...] = incl[block_rows - 1:block_rows, :]

    out = (ca_ref[...].astype(acc_dtype) * data_ref[...].astype(acc_dtype)
           + cb_ref[...].astype(acc_dtype) * excl)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def segmented_tail_kernel(
    data: jnp.ndarray,   # [m, n]
    wa: jnp.ndarray,     # [m, n]  v·data
    first: jnp.ndarray,  # [m, 1]  1.0 at segment starts (f32/int ok)
    coef_a: jnp.ndarray,  # [m, 1]
    coef_b: jnp.ndarray,  # [m, 1]
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = data.shape
    acc_dtype = jnp.float64 if data.dtype == jnp.float64 else jnp.float32
    bm = min(block_rows, max(8, m))
    bn = min(block_cols, max(128, n))
    # Pad rows to the block grid; padded rows start their own (discarded)
    # segments so they cannot pollute the carry.
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    if mp != m or np_ != n:
        data = jnp.pad(data, ((0, mp - m), (0, np_ - n)))
        wa = jnp.pad(wa, ((0, mp - m), (0, np_ - n)))
        first = jnp.pad(first, ((0, mp - m), (0, 0)), constant_values=1.0)
        coef_a = jnp.pad(coef_a, ((0, mp - m), (0, 0)))
        coef_b = jnp.pad(coef_b, ((0, mp - m), (0, 0)))

    grid = (np_ // bn, mp // bm)
    row_spec = pl.BlockSpec((bm, bn), lambda j, i: (i, j))
    vec_spec = pl.BlockSpec((bm, 1), lambda j, i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_segtail_kernel, block_rows=bm,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[row_spec, row_spec, vec_spec, vec_spec, vec_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), data.dtype),
        scratch_shapes=[pltpu.VMEM((1, bn), acc_dtype)],
        interpret=interpret,
    )(data, wa, first, coef_a, coef_b)
    return out[:m, :n]
