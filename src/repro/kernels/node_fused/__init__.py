"""Fused per-node FiGaRo pass: mask + segmented head/tail + φ-scale + emit.

One Pallas kernel per head/tail pass of a join-tree node (two per node), one
HBM round-trip each — see `kernel.py` for the fusion, `ops.py` for the public
`fused_node_pass`, `ref.py` for the XLA reference the tests compare against.
"""

from .kernel import AUTOTUNE, choose_blocks, node_fused_kernel
from .ops import fused_node_pass
from .ref import fused_node_pass_ref

__all__ = [
    "AUTOTUNE",
    "choose_blocks",
    "node_fused_kernel",
    "fused_node_pass",
    "fused_node_pass_ref",
]
