"""Jitted public wrapper: one fused FiGaRo node pass, heads included.

`fused_node_pass` is the kernel-path unit `core.figaro.figaro_r0` calls twice
per join-tree node (HEADS_AND_TAILS and PROJECT_AWAY_JOIN_ATTRS). All the
[m, n]-sized work — live-row masking, the weighted segmented scan, the
generalized-tail formula, segment-start zeroing and √Φ emission scaling —
happens inside the single `node_fused` Pallas kernel (one HBM round-trip).
What stays in XLA is O(m)/O(K) vector work: the weight-norm scans that feed
the tail coefficients, and the head extraction, which gathers each segment's
**final** inclusive sum instead of re-reducing the matrix with `segment_sum`.

Interpret-mode policy comes from `repro.kernels._platform` (compiled on
TPU/GPU, interpreted elsewhere); pass ``interpret=`` explicitly to override.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.heads_tails import segmented_cumsum
from repro.kernels._platform import resolve_interpret

from .kernel import node_fused_kernel


def fused_node_pass(
    data: jnp.ndarray,        # [m, n] node rows, NOT pre-masked
    weights: jnp.ndarray,     # [m] Givens weight v (dead rows: 0)
    pos_in_seg: jnp.ndarray,  # [m] 0 at segment starts
    emit_scale: jnp.ndarray,  # [m] √Φ per row (0 allowed; starts auto-zeroed)
    last_of_seg: jnp.ndarray,  # [K] row index of each segment's last member
    seg_live: jnp.ndarray,    # [K] bool — live segment slots
    *,
    data_scale: jnp.ndarray | None = None,  # [m] row mask (None = ones)
    block_rows: int | None = None,
    block_cols: int | None = None,
    interpret: bool | None = None,
):
    """One fused head/tail pass over contiguous row segments.

    Returns:
      slab:  [m, n] — ``emit_scale·T(seg, v)`` rows, segment starts (and every
             masked row) exactly zero: the finished R₀ slab.
      heads: [K, n] — ``H(seg, v)`` per live segment, zeros on dead slots.
      norms: [K]    — ‖v_seg‖₂, zeros on dead slots.

    Dead capacity-slot contract (see `core.plan_cache`): dead rows carry
    ``weights == data_scale == 0`` and are never segment starts, dead segment
    slots have ``seg_live`` False and may point ``last_of_seg`` anywhere.
    """
    m = data.shape[0]
    dtype = data.dtype
    weights = weights.astype(dtype)
    first = (pos_in_seg == 0)
    if data_scale is None:
        data_scale = jnp.ones((m,), dtype)
    # Tail coefficients from [m] weight scans (cheap; every [m, n] op is in
    # the kernel). Same guarded formulas as `segmented_head_tail`: dead rows
    # (weight 0, never starts) get coef_a=1, coef_b=0 and a zeroed data row,
    # so their slab rows come out identically zero.
    w2 = weights * weights
    c_incl = segmented_cumsum(w2, first)
    c_excl = c_incl - w2
    c_excl_safe = jnp.where(pos_in_seg > 0, c_excl, 1.0)
    coef_a = jnp.sqrt(c_excl_safe / c_incl)
    coef_b = -weights / jnp.sqrt(c_excl_safe * c_incl)

    col = lambda v: v.astype(dtype)[:, None]
    # Fold the segment-start zeroing into the emission scale: a start row's
    # "tail" is garbage (it is the head's slot), so it must never emit.
    emit = emit_scale * (pos_in_seg > 0)
    slab, s_incl = node_fused_kernel(
        data, col(data_scale), col(weights), col(first), col(coef_a),
        col(coef_b), col(emit),
        block_rows=block_rows, block_cols=block_cols,
        interpret=resolve_interpret(interpret))

    # Heads by gather: the inclusive sums at a segment's last row ARE the
    # segment totals (dead trailing rows add weight-0 nothing).
    last = jnp.clip(last_of_seg, 0, m - 1)
    norms = jnp.sqrt(c_incl[last])
    heads = s_incl[last] / jnp.where(norms > 0, norms, 1.0)[:, None]
    heads = jnp.where(seg_live[:, None], heads, 0.0).astype(dtype)
    norms = jnp.where(seg_live, norms, 0.0).astype(dtype)
    return slab, heads, norms
