"""Pallas TPU kernel: one fused FiGaRo node pass (mask · scan · tail · φ · emit).

`figaro_r0` runs two head/tail passes per join-tree node; the unfused XLA path
spends each one as a chain of separate [m, n] ops — live-row mask multiply,
weighted segmented scan, generalized-tail formula, segment-start zeroing,
φ-weight scaling — every link a full HBM round-trip over the node. This kernel
fuses the whole pass:

    d       = data · data_scale             (live-row masking, in-kernel)
    wa      = d · weights
    s_incl  = segmented inclusive prefix sum of wa   (restart at `first`)
    s_excl  = s_incl − wa
    emitted = emit_scale · (coef_a · d + coef_b · s_excl)

and writes BOTH outputs of one pass in a single HBM trip: ``emitted`` — the
finished R₀ slab, with segment-start rows already zeroed and √Φ folded in
because ``emit_scale`` carries both — and ``s_incl``, from whose segment-final
rows the caller gathers the heads with O(m) index work (no second [m, n] pass).

TPU mapping follows `kernels/head_tail`: grid = (col_blocks, row_blocks) with
rows innermost, so each column stripe walks row blocks sequentially and hands
the running segment prefix forward through VMEM scratch; the in-block
segmented scan is a Hillis–Steele ladder (log₂ bm vector steps on the VPU).
Accumulation is f32 for ≤32-bit I/O and f64 for f64 I/O (f64 pipelines run in
interpret mode on this container, where the wider carry is free; on TPU
hardware the engine dispatches f32).

Grid/block sizing comes from the `AUTOTUNE` table, keyed by
``(backend, itemsize, width bound)``: narrow nodes take taller row blocks
(fewer carry hand-offs per stripe), wide nodes take wider column stripes
(fewer row walks), and f64 tiles halve the row block. TPU rows keep the live
set of four [bm, bn] tiles inside a ~2 MB VMEM budget; GPU (Triton) rows are
power-of-two tiles sized for a 256 KiB shared-memory/register budget, small
enough that even an f64 fall-through fits. Backends without their own rows
(CPU interpret mode) reuse the TPU shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _platform

# (backend, itemsize, width bound) -> (block_rows, block_cols). Buckets are
# checked in order; `None` is the catch-all bound each (backend, itemsize)
# group must end with.
AUTOTUNE: dict[tuple[str, int, int | None], tuple[int, int]] = {
    ("tpu", 4, 128): (512, 128),
    ("tpu", 4, 512): (256, 256),
    ("tpu", 4, None): (128, 512),
    ("tpu", 8, 128): (256, 128),
    ("tpu", 8, 512): (128, 256),
    ("tpu", 8, None): (64, 512),
    ("gpu", 4, 128): (128, 128),
    ("gpu", 4, 512): (64, 256),
    ("gpu", 4, None): (16, 512),
    ("gpu", 8, 128): (64, 128),
    ("gpu", 8, 512): (32, 256),
    ("gpu", 8, None): (16, 512),
}


def choose_blocks(n: int, dtype, backend: str | None = None) -> tuple[int, int]:
    """(block_rows, block_cols) for an n-wide node from the autotune table.

    ``backend`` defaults to the platform backend (trace-time constant via
    `_platform.backend`); backends without their own table rows — CPU
    interpret mode — reuse the tpu shapes.
    """
    if backend is None:
        backend = _platform.backend()
    if not any(be == backend for be, _, _ in AUTOTUNE):
        backend = "tpu"
    itemsize = 8 if jnp.dtype(dtype).itemsize >= 8 else 4
    for (be, isz, bound), blocks in AUTOTUNE.items():
        if be == backend and isz == itemsize \
                and (bound is None or n <= bound):
            return blocks
    raise AssertionError(
        "AUTOTUNE must end each (backend, itemsize) with a None bound")


def _shift_down(x: jnp.ndarray, off: int) -> jnp.ndarray:
    """Rows shifted down by `off` (row r reads r-off), zero-filled at the top."""
    pad = jnp.zeros((off,) + x.shape[1:], x.dtype)
    return jnp.concatenate([pad, x[: x.shape[0] - off]], axis=0)


def _node_fused_body(data_ref, dscale_ref, w_ref, first_ref, ca_ref, cb_ref,
                     es_ref, out_ref, sincl_ref, carry_ref, *,
                     block_rows: int, acc_dtype):
    i = pl.program_id(1)  # row block (innermost => sequential carry is valid)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    d = (data_ref[...].astype(acc_dtype)
         * dscale_ref[...].astype(acc_dtype))        # [bm, bn] masked rows
    wa = d * w_ref[...].astype(acc_dtype)
    first = first_ref[...].astype(acc_dtype)         # [bm, 1]; 1.0 at starts

    # Segmented inclusive Hillis–Steele scan within the block:
    #   (f_a, x_a) ⊕ (f_b, x_b) = (f_a|f_b, x_b + (f_b ? 0 : x_a))
    x, f = wa, first
    off = 1
    while off < block_rows:
        x = x + (1.0 - f) * _shift_down(x, off)
        f = jnp.maximum(f, _shift_down(f, off))
        off *= 2
    # f is now "any segment start in this block up to r" — rows before the
    # first in-block boundary continue the previous block's segment.
    incl = x + (1.0 - f) * carry_ref[...]
    excl = incl - wa
    carry_ref[...] = incl[block_rows - 1:block_rows, :]

    out = es_ref[...].astype(acc_dtype) * (
        ca_ref[...].astype(acc_dtype) * d + cb_ref[...].astype(acc_dtype) * excl)
    out_ref[...] = out.astype(out_ref.dtype)
    sincl_ref[...] = incl.astype(sincl_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def node_fused_kernel(
    data: jnp.ndarray,        # [m, n]
    data_scale: jnp.ndarray,  # [m, 1] row mask / pre-scale (1.0 = untouched)
    weights: jnp.ndarray,     # [m, 1] Givens weight v per row
    first: jnp.ndarray,       # [m, 1] 1.0 at segment starts
    coef_a: jnp.ndarray,      # [m, 1] tail coefficient √(c_excl/c_incl)
    coef_b: jnp.ndarray,      # [m, 1] tail coefficient −v/√(c_excl·c_incl)
    emit_scale: jnp.ndarray,  # [m, 1] √Φ · (pos>0): φ scaling + start zeroing
    *,
    block_rows: int | None = None,
    block_cols: int | None = None,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (emitted [m, n], s_incl [m, n]) — see module docstring."""
    m, n = data.shape
    if block_rows is None or block_cols is None:
        tuned = choose_blocks(n, data.dtype)
        block_rows = block_rows or tuned[0]
        block_cols = block_cols or tuned[1]
    acc_dtype = jnp.float64 if data.dtype == jnp.float64 else jnp.float32
    bm = min(block_rows, max(8, m))
    bn = min(block_cols, max(128, n))
    # Pad rows to the block grid; padded rows start their own segments with
    # data_scale/emit_scale 0, so they neither pollute the carry nor emit.
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    if mp != m or np_ != n:
        pad1 = ((0, mp - m), (0, 0))
        data = jnp.pad(data, ((0, mp - m), (0, np_ - n)))
        data_scale = jnp.pad(data_scale, pad1)
        weights = jnp.pad(weights, pad1)
        first = jnp.pad(first, pad1, constant_values=1.0)
        coef_a = jnp.pad(coef_a, pad1)
        coef_b = jnp.pad(coef_b, pad1)
        emit_scale = jnp.pad(emit_scale, pad1)

    grid = (np_ // bn, mp // bm)
    row_spec = pl.BlockSpec((bm, bn), lambda j, i: (i, j))
    vec_spec = pl.BlockSpec((bm, 1), lambda j, i: (i, 0))
    emitted, s_incl = pl.pallas_call(
        functools.partial(_node_fused_body, block_rows=bm, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[row_spec] + [vec_spec] * 6,
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), data.dtype),
                   jax.ShapeDtypeStruct((mp, np_), data.dtype)],
        scratch_shapes=[pltpu.VMEM((1, bn), acc_dtype)],
        interpret=interpret,
    )(data, data_scale, weights, first, coef_a, coef_b, emit_scale)
    return emitted[:m, :n], s_incl[:m, :n]
