"""Pure-XLA reference for the fused node pass (same contract as ops.py).

The reference is what `kernels_bench` and the parity tests compare the Pallas
kernel against, and what documents the kernel's semantics without Pallas
block/grid mechanics. It reuses `segmented_cumsum` (associative scan), so its
summation order matches the kernel's segmented scan — differences between the
two are genuine kernel bugs, not reassociation noise.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.heads_tails import segmented_cumsum


def fused_node_pass_ref(
    data: jnp.ndarray,
    weights: jnp.ndarray,
    pos_in_seg: jnp.ndarray,
    emit_scale: jnp.ndarray,
    last_of_seg: jnp.ndarray,
    seg_live: jnp.ndarray,
    *,
    data_scale: jnp.ndarray | None = None,
):
    """Reference (slab, heads, norms) — see `ops.fused_node_pass`."""
    m = data.shape[0]
    dtype = data.dtype
    weights = weights.astype(dtype)
    first = (pos_in_seg == 0)
    if data_scale is not None:
        data = data * data_scale.astype(dtype)[:, None]

    w2 = weights * weights
    wa = data * weights[:, None]
    c_incl = segmented_cumsum(w2, first)
    s_incl = segmented_cumsum(wa, first)
    c_excl = c_incl - w2
    s_excl = s_incl - wa
    c_excl_safe = jnp.where(pos_in_seg > 0, c_excl, 1.0)
    tails = (jnp.sqrt(c_excl_safe / c_incl)[:, None] * data
             - (weights / jnp.sqrt(c_excl_safe * c_incl))[:, None] * s_excl)
    emit = emit_scale * (pos_in_seg > 0)
    slab = emit.astype(dtype)[:, None] * tails

    last = jnp.clip(last_of_seg, 0, m - 1)
    norms = jnp.sqrt(c_incl[last])
    heads = s_incl[last] / jnp.where(norms > 0, norms, 1.0)[:, None]
    heads = jnp.where(seg_live[:, None], heads, 0.0).astype(dtype)
    norms = jnp.where(seg_live, norms, 0.0).astype(dtype)
    return slab, heads, norms
