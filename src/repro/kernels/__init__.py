"""Pallas TPU kernels (validated on CPU with interpret=True):

  node_fused/  fused per-node FiGaRo pass (mask·head/tail·φ·emit) — hot path
  head_tail/   segmented generalized head/tail — the unfused building block
  panel_qr/    Householder panel factorization — post-processing hot spot
  flash_attn/  fused GQA attention — serving-side mixer hot spot

Platform policy (compiled on TPU/GPU, interpreted elsewhere, explicit
``interpret=`` override) is shared via `_platform.py`.
"""
