"""Pallas TPU kernels (validated on CPU with interpret=True):

  head_tail/   segmented generalized head/tail — FiGaRo's inner loop
  panel_qr/    Householder panel factorization — post-processing hot spot
  linear_scan/ chunked diagonal linear RNN — Mamba/RWKV6 mixer hot spot
"""
