"""Pure-jnp oracle for the panel-QR kernel: `core.postprocess.householder_panel`."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.postprocess import householder_panel


def panel_qr_ref(a: jnp.ndarray):
    """(V unit-diagonal, beta, R_panel) — reference contract for the kernel."""
    v, beta, r = householder_panel(a)
    rows = jnp.arange(a.shape[0])[:, None]
    cols = jnp.arange(a.shape[1])[None, :]
    return v, beta, jnp.where(rows <= cols, r, 0.0)
