"""Pallas TPU kernel: Householder panel factorization (post-processing hot spot).

The paper finds post-processing (R₀ → R) dominates FiGaRo's runtime for wide
matrices (§8 Exp 1). Blocked Householder QR splits into (a) a *panel*
factorization — sequential over columns, latency-bound — and (b) a trailing
compact-WY update — pure matmuls that the MXU eats. This kernel does (a)
entirely in VMEM: one [m × nb] panel resident on-chip, nb Householder steps
without touching HBM, emitting unit-diagonal reflectors V, betas, and the
triangularized panel.

Column selection uses iota masks instead of dynamic lane slicing (TPU lane
dim is not cheaply dynamically indexable); each step is two VPU reductions +
one rank-1 update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _panel_kernel(a_ref, v_ref, beta_ref, r_ref, *, m: int, nb: int):
    # Accumulate in the I/O precision: f64 panels (the x64 post-processing
    # path) keep f64 Householder math; everything else runs the MXU-native
    # f32. A hardcoded f32 here silently cost ~1e-6 in the final R of an
    # otherwise-f64 pipeline.
    acc = jnp.float64 if a_ref.dtype == jnp.float64 else jnp.float32
    a = a_ref[...].astype(acc)  # [m, nb]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)

    def step(k, carry):
        a, vs, betas = carry
        colmask = (cols == k).astype(acc)        # [1, nb]
        col = jnp.sum(a * colmask, axis=1, keepdims=True)  # [m, 1]
        below = (rows >= k).astype(acc)
        x = col * below
        sigma2 = jnp.sum(x * x)
        sigma = jnp.sqrt(sigma2)
        at_k = (rows == k).astype(acc)
        xk = jnp.sum(x * at_k)
        sgn = jnp.where(xk >= 0, 1.0, -1.0)
        alpha = -sgn * sigma
        v = x - alpha * at_k
        vk = jnp.sum(v * at_k)
        safe = jnp.abs(vk) > 0.0
        v = jnp.where(safe, v / jnp.where(safe, vk, 1.0), v)  # unit diagonal
        vv = jnp.sum(v * v)
        beta = jnp.where(vv > 0, 2.0 / jnp.where(vv > 0, vv, 1.0), 0.0)
        w = jnp.sum(v * a, axis=0, keepdims=True)            # [1, nb] = vᵀA
        a = a - beta * v * w                                  # rank-1 update
        vs = vs + v * colmask                                 # store column k
        betas = betas + beta * colmask
        return a, vs, betas

    vs0 = jnp.zeros((m, nb), acc)
    betas0 = jnp.zeros((1, nb), acc)
    a, vs, betas = jax.lax.fori_loop(0, min(m, nb), step, (a, vs0, betas0))

    v_ref[...] = vs.astype(v_ref.dtype)
    beta_ref[...] = betas.astype(beta_ref.dtype)
    # Zero strictly-below-diagonal residue (numerical dust from the updates).
    upper = (rows <= cols).astype(acc)
    r_ref[...] = (a * upper).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_qr_kernel(a: jnp.ndarray, *, interpret: bool = False):
    """Factor one panel [m, nb] (entirely VMEM-resident).

    Returns (V [m, nb] unit-diagonal reflectors, beta [nb], R_panel [m, nb]).
    VMEM budget: 4 copies of the panel at the accumulation dtype (f64 for
    f64 panels, f32 otherwise) — keep m·nb ≲ 512·128 (f32) / 512·64 (f64).
    """
    m, nb = a.shape
    kern = functools.partial(_panel_kernel, m=m, nb=nb)
    spec = pl.BlockSpec((m, nb), lambda: (0, 0))
    bspec = pl.BlockSpec((1, nb), lambda: (0, 0))
    v, beta, r = pl.pallas_call(
        kern,
        grid=(),
        in_specs=[spec],
        out_specs=[spec, bspec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, nb), a.dtype),
            jax.ShapeDtypeStruct((1, nb), a.dtype),
            jax.ShapeDtypeStruct((m, nb), a.dtype),
        ],
        interpret=interpret,
    )(a)
    return v, beta[0], r
