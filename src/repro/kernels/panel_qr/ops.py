"""Jitted public wrapper for the panel-QR kernel.

Compiled on TPU/GPU, interpreted elsewhere (`repro.kernels._platform`);
pass ``interpret=`` explicitly to override the platform decision.
"""

from __future__ import annotations

from repro.kernels._platform import resolve_interpret

from .kernel import panel_qr_kernel


def panel_qr(a, *, interpret: bool | None = None):
    """Householder panel factorization: (V, beta, R_panel) for [m, nb] input."""
    return panel_qr_kernel(a, interpret=resolve_interpret(interpret))
