"""Jitted public wrapper for the panel-QR kernel (interpret=True off-TPU)."""

from __future__ import annotations

import jax

from .kernel import panel_qr_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def panel_qr(a):
    """Householder panel factorization: (V, beta, R_panel) for [m, nb] input."""
    return panel_qr_kernel(a, interpret=not _on_tpu())
