"""One platform policy for every Pallas kernel wrapper.

Each ``kernels/*/ops.py`` used to carry its own copy of ``_on_tpu()`` and the
``interpret=not _on_tpu()`` dispatch decision. This module is the single
source of truth:

  * `backend()`            — `jax.default_backend()` (cached; the backend
                             cannot change after the first dispatch).
  * `on_accelerator()`     — True on TPU **or GPU**: platforms where Pallas
                             lowers to a real kernel (Mosaic on TPU, Triton
                             on GPU) instead of the interpreter.
  * `resolve_interpret(x)` — the value every wrapper passes as
                             ``interpret=``: an explicit override wins
                             (``True``/``False``), ``None`` falls back to
                             interpret-off-accelerator. The override is how
                             tests force the interpreter on an accelerator
                             (numerics triage) or assert compiled lowering.
"""

from __future__ import annotations

import functools

import jax

__all__ = ["backend", "on_accelerator", "on_tpu", "resolve_interpret"]

_ACCELERATORS = ("tpu", "gpu")


@functools.lru_cache(maxsize=None)
def backend() -> str:
    """The default JAX backend name ("cpu" / "gpu" / "tpu")."""
    return jax.default_backend()


def on_tpu() -> bool:
    return backend() == "tpu"


def on_accelerator() -> bool:
    """True where Pallas compiles to a native kernel (TPU or GPU)."""
    return backend() in _ACCELERATORS


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Interpret-mode decision for a kernel dispatch.

    ``None`` (the default everywhere) = run compiled on an accelerator and
    interpreted elsewhere (CPU — the validation mode of this container). An
    explicit ``True``/``False`` is honored verbatim.
    """
    if interpret is not None:
        return bool(interpret)
    return not on_accelerator()
