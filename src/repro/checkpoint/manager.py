"""Fault-tolerant checkpointing: async, atomic, elastic.

* **Async**: `save` snapshots to host (device_get) then writes on a background
  thread — training never blocks on disk.
* **Atomic**: writes to ``step_XXXX.tmp`` then renames; a crash mid-write can
  never corrupt the latest checkpoint.
* **Elastic**: leaves are stored device-agnostic (one .npz keyed by pytree
  path); `restore` places them onto *whatever mesh exists at restart* via the
  target shardings — restart on 256 chips from a 512-chip checkpoint (or vice
  versa) reshards transparently.
* **Resumable data**: metadata records the step so the data pipeline can
  deterministically skip ahead (data/pipeline.py).
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sanitizer.threads import san_thread

__all__ = ["CheckpointManager"]


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, blocking: bool = False,
             extra_meta: dict | None = None) -> None:
        self.wait()  # at most one in-flight write
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        meta = {"step": int(step), "time": time.time(), **(extra_meta or {})}

        def write():
            flat = _flatten(host_state)
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp.npz")
            final = os.path.join(self.dir, f"step_{step:08d}.npz")
            np.savez(tmp, **flat)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, f"step_{step:08d}.json"),
                      "w") as f:
                json.dump(meta, f)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = san_thread(write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            for suffix in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.dir, f"step_{s:08d}{suffix}"))
                except FileNotFoundError:
                    pass

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.npz", fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``target`` (shape/dtype template).

        ``shardings``: optional pytree of NamedShardings for elastic placement
        onto the current mesh; defaults to single-device placement.
        """
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        data = np.load(path)
        flat_target, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(flat_target))
        leaves = []
        for (p, leaf), sh in zip(flat_target, shard_leaves):
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} "
                                 f"!= target {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target: Any, shardings: Any = None
                       ) -> tuple[int, Any] | None:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, target, shardings)
