"""Numerics sanitizer: sampled float64 shadow dispatch + NaN/Inf tripwires.

The paper's accuracy claim is that Figaro's rounding errors relative to
classical QR scale with the **database size** (sum of relation rows), not
the join output size. This module turns that claim into a runtime
assertion: on a sampled subset of engine dispatches it re-runs the same
request through the same plan in float64 (a *shadow* dispatch) and compares
a sign-invariant functional of the two results against the analytic budget

    rel_err  <=  eps(primary dtype) * slack * database_rows

mirroring `core.figaro.assembly_traffic`'s style of analytic accounting —
the model counts the work (one Givens chain per column, length ~ database
rows), not the constants, so ``slack`` carries the usual backward-stability
engineering factor.

Comparisons are sign/rotation-invariant per kind: QR and R₀ compare Gram
matrices RᵀR (R is unique only up to row signs), SVD compares singular
values, PCA compares explained variances, least-squares compares the
(unique) coefficient vector. Shadow dispatches are marked thread-local so
they never recurse, never bump the engine's trace counters, and never feed
the retrace sanitizer.

This module is the only sanitizer file that touches jax/numpy; it is
imported lazily from the engine so the jax-free analysis CI job can import
``repro.sanitizer``.
"""

from __future__ import annotations

import collections
import threading

import numpy as np

from ._state import STATE

_lock = threading.Lock()
_dispatch_counts: collections.Counter = collections.Counter()
_events: "collections.deque" = collections.deque(maxlen=256)


def reset() -> None:
    with _lock:
        _dispatch_counts.clear()
        _events.clear()


def events() -> list[dict]:
    with _lock:
        return [dict(e) for e in _events]


def _sample(kind: str) -> bool:
    """First dispatch of each kind always shadows; then every Nth."""
    with _lock:
        _dispatch_counts[kind] += 1
        n = _dispatch_counts[kind]
    every = max(int(STATE.sample_every), 1)
    return n == 1 or n % every == 0


def _x64_available() -> bool:
    import jax

    return bool(jax.config.jax_enable_x64)


class _Shadow:
    __slots__ = ("kind", "plan", "host", "options", "primary")

    def __init__(self, kind, plan, host, options, primary) -> None:
        self.kind = kind
        self.plan = plan
        self.host = host
        self.options = options
        self.primary = primary


def prepare_shadow(engine, kind: str, plan, data, options) -> _Shadow | None:
    """Called from ``FigaroEngine._dispatch`` *before* the jit call (data may
    be donated by it, so the host copy must happen here). Returns a shadow
    token, or None when this dispatch is not sampled / not shadowable."""
    if STATE.shadow_active():
        return None
    if not _sample(kind):
        return None
    primary = np.dtype(options.get("dtype", np.float32))
    if primary.kind != "f" or primary == np.dtype(np.float64):
        return None
    if not _x64_available():
        return None  # f64 would silently downcast: nothing to compare
    host = None
    if data is not None:
        host = tuple(np.asarray(d) for d in data)
    return _Shadow(kind, plan, host, dict(options), primary)


def database_rows(host, plan) -> int:
    """Σ relation rows — the paper's database size (each data leaf is
    [..., m_i, n_i]; the leading batch axis, if any, does not multiply the
    per-pipeline rotation count)."""
    leaves = host if host is not None else tuple(plan.data)
    return int(sum(int(np.shape(d)[-2]) for d in leaves)) or 1


def error_budget(primary: np.dtype, db_rows: int) -> float:
    return float(np.finfo(primary).eps) * STATE.numerics_slack * db_rows


def _comparable(kind: str, out) -> np.ndarray:
    """Sign/rotation-invariant functional of a dispatch result."""
    if kind.startswith(("r0", "qr")):
        r = np.asarray(out, dtype=np.float64)
        return np.matmul(np.swapaxes(r, -1, -2), r)  # Gram: RᵀR
    if kind.startswith("svd"):
        return np.asarray(out[0], dtype=np.float64)  # singular values
    if kind.startswith("pca"):
        return np.asarray(out.explained_variance, dtype=np.float64)
    if kind.startswith("least_squares"):
        return np.asarray(out[0], dtype=np.float64)  # beta
    raise ValueError(f"no numerics comparison for kind={kind!r}")


def relative_error(primary_out, shadow_out, kind: str) -> float:
    a = _comparable(kind, primary_out)
    b = _comparable(kind, shadow_out)
    denom = max(float(np.linalg.norm(b)), np.finfo(np.float64).tiny)
    return float(np.linalg.norm(a - b)) / denom


def _check_finite(kind: str, out) -> None:
    import jax

    for i, leaf in enumerate(jax.tree_util.tree_leaves(out)):
        arr = np.asarray(leaf)
        if arr.dtype.kind != "f":
            continue
        if not np.all(np.isfinite(arr)):
            bad = int(arr.size - np.count_nonzero(np.isfinite(arr)))
            STATE.add_finding(
                "numerics",
                f"non-finite values in kind={kind} output leaf {i} "
                f"({bad}/{arr.size} entries)",
                details={"kind": kind, "leaf": i, "bad": bad},
                dedupe_key=("numerics-nonfinite", kind, i),
            )


def after_dispatch(engine, shadow: _Shadow | None, out) -> None:
    """Called from ``_dispatch`` after the primary result (pre-pad-slicing,
    so shapes match the shadow's, whose inputs carry the same pad)."""
    if shadow is None:
        return
    _check_finite(shadow.kind, out)
    opts = dict(shadow.options)
    opts["dtype"] = np.dtype(np.float64)
    STATE.set_shadow(True)
    try:
        ref = engine._dispatch(shadow.kind, shadow.plan, shadow.host, **opts)
    finally:
        STATE.set_shadow(False)
    err = relative_error(out, ref, shadow.kind)
    db_rows = database_rows(shadow.host, shadow.plan)
    budget = error_budget(shadow.primary, db_rows)
    with _lock:
        _events.append({"kind": shadow.kind, "rel_err": err,
                        "budget": budget, "db_rows": db_rows,
                        "dtype": shadow.primary.name})
    if err > budget:
        STATE.add_finding(
            "numerics",
            f"kind={shadow.kind} {shadow.primary.name} error {err:.3e} "
            f"exceeds database-size budget {budget:.3e} "
            f"(eps*{STATE.numerics_slack:g}*{db_rows} rows)",
            details={"kind": shadow.kind, "rel_err": err, "budget": budget,
                     "db_rows": db_rows},
            dedupe_key=("numerics-budget", shadow.kind),
        )
