"""Lockset race detector: observed cross-thread access without the lock.

FIG005/FIG006 prove lock discipline *structurally*; this module upgrades the
check to an *observed* one, Eraser-style. Classes declare their shared
mutable attributes and owning locks with::

    @shared_state({"_plan": "_lock", "appends": "_lock"})
    class PlanHolder: ...

While the sanitizer is enabled, instrumented ``__getattribute__`` /
``__setattr__`` hooks are installed on every registered class. Each access
to a declared attribute records the accessing thread; once an instance has
been touched from two threads, any further access without the owning
``SanLock`` held on the current thread raises a ``race`` finding with the
call site. When the sanitizer is disabled the hooks are *removed* from the
classes, so the off-mode cost is literally zero — plain CPython attribute
lookup.

Attributes that are intentionally accessed lock-free (monotonic flags read
opportunistically, say) are listed in a class-level ``_san_atomic`` tuple
and simply not declared here; FIG006 honours the same annotation.
"""

from __future__ import annotations

import threading
import weakref

from ._state import STATE, trimmed_stack

_REGISTRY: list[type] = []
_hooks_installed = False

_obs_lock = threading.Lock()
#: instance -> {attr: set of thread idents that touched it}
_observed: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def reset_observations() -> None:
    with _obs_lock:
        _observed.clear()


def _check(obj, cls: type, name: str, kind: str) -> None:
    lock_attr = cls._san_shared[name]
    try:
        lock = object.__getattribute__(obj, lock_attr)
    except AttributeError:
        return  # mid-__init__, lock not created yet: single-threaded
    held = getattr(lock, "held_by_me", None)
    if held is None:
        return  # not a sanitizer lock: nothing to observe against
    ident = threading.get_ident()
    with _obs_lock:
        try:
            rec = _observed[obj]
        except KeyError:
            rec = _observed[obj] = {}
        threads = rec.setdefault(name, set())
        threads.add(ident)
        multi = len(threads) > 1
    if multi and not held():
        stack = trimmed_stack(skip=3)
        site = stack[-1] if stack else "?"
        STATE.add_finding(
            "race",
            f"{cls.__name__}.{name} {kind} from a second thread without "
            f"{lock_attr} held",
            stack=stack,
            details={"class": cls.__name__, "attr": name, "kind": kind,
                     "lock": lock_attr},
            dedupe_key=("race", cls.__name__, name, kind, site),
        )


def _make_hooks(cls: type):
    shared = frozenset(cls._san_shared)

    def __getattribute__(self, name):
        if name in shared and STATE.enabled:
            _check(self, cls, name, "read")
        return object.__getattribute__(self, name)

    def __setattr__(self, name, value):
        if name in shared and STATE.enabled:
            _check(self, cls, name, "write")
        object.__setattr__(self, name, value)

    return __getattribute__, __setattr__


def _install_cls(cls: type) -> None:
    if "__getattribute__" in cls.__dict__:
        return  # already installed
    getter, setter = _make_hooks(cls)
    cls.__getattribute__ = getter
    cls.__setattr__ = setter


def _uninstall_cls(cls: type) -> None:
    for name in ("__getattribute__", "__setattr__"):
        if name in cls.__dict__:
            delattr(cls, name)


def install() -> None:
    global _hooks_installed
    _hooks_installed = True
    for cls in _REGISTRY:
        _install_cls(cls)


def uninstall() -> None:
    global _hooks_installed
    _hooks_installed = False
    for cls in _REGISTRY:
        _uninstall_cls(cls)


def shared_state(attr_locks: dict[str, str]):
    """Class decorator declaring shared mutable attrs and their owning lock
    attribute. Instrumentation only bites while the sanitizer is enabled."""

    def deco(cls: type) -> type:
        cls._san_shared = dict(attr_locks)
        _REGISTRY.append(cls)
        if _hooks_installed:
            _install_cls(cls)
        return cls

    return deco
