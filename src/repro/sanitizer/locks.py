"""Sanitizer-aware lock wrappers and the lock-acquisition-order graph.

``san_lock`` / ``san_rlock`` / ``san_condition`` replace the raw
``threading`` factories in the serving stack (FIG007 enforces that every
lock in ``src/`` routes through them). When the sanitizer is disabled each
wrapper costs one attribute read per acquire; when enabled it maintains a
per-thread stack of held locks, records every *ordered pair* (held → newly
acquired) into a global lock-order graph, and flags a ``lock-order`` finding
the moment an edge closes a cycle — the classic potential-deadlock signal,
caught even when the interleaving never actually deadlocks.

The wrappers also expose ``held_by_me()`` so the race detector can check
"is the owning lock held on this thread?" without touching CPython
internals, and ``SanCondition.wait`` keeps the held-lock bookkeeping honest
across the release/reacquire that a condition wait performs.
"""

from __future__ import annotations

import threading
from typing import Iterator

from ._state import STATE, trimmed_stack

_graph_lock = threading.Lock()
#: name -> set of names acquired *while* `name` was held.
_ORDER_EDGES: dict[str, set[str]] = {}
#: (a, b) -> trimmed stack of the first time the edge was observed.
_EDGE_SITES: dict[tuple[str, str], tuple[str, ...]] = {}

_tls = threading.local()


def _held_stack() -> list:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = []
    return held


def reset_order_graph() -> None:
    with _graph_lock:
        _ORDER_EDGES.clear()
        _EDGE_SITES.clear()


def order_edges() -> dict[str, set[str]]:
    with _graph_lock:
        return {a: set(bs) for a, bs in _ORDER_EDGES.items()}


def _find_cycle(start: str, target: str) -> list[str] | None:
    """Path target -> ... -> start in the edge graph (caller just added the
    edge start -> target, so such a path closes a cycle)."""
    path = [target]
    seen = {target}

    def dfs(node: str) -> bool:
        for nxt in _ORDER_EDGES.get(node, ()):
            if nxt == start:
                path.append(start)
                return True
            if nxt not in seen:
                seen.add(nxt)
                path.append(nxt)
                if dfs(nxt):
                    return True
                path.pop()
        return False

    return path if dfs(target) else None


def _note_acquired(lock: "SanLock") -> None:
    held = _held_stack()
    for entry in held:
        if entry[0] is lock:          # reentrant re-acquire: no new edges
            entry[1] += 1
            return
    stack = None
    with _graph_lock:
        for other, _ in held:
            if other.name == lock.name:
                continue
            edges = _ORDER_EDGES.setdefault(other.name, set())
            if lock.name in edges:
                continue
            edges.add(lock.name)
            if stack is None:
                stack = trimmed_stack(skip=4)
            _EDGE_SITES[(other.name, lock.name)] = stack
            # `cycle` is the pre-existing path lock.name -> ... -> other.name,
            # in forward edge order; the new edge other.name -> lock.name
            # closes it.
            cycle = _find_cycle(other.name, lock.name)
            if cycle is not None:
                loop = [other.name] + cycle
                counter = _EDGE_SITES.get((cycle[0], cycle[1]), ()) \
                    if len(cycle) > 1 else ()
                STATE.add_finding(
                    "lock-order",
                    "lock acquisition cycle (potential deadlock): "
                    + " -> ".join(loop),
                    details={"cycle": loop, "counter_site": list(counter)},
                    dedupe_key=("lock-order", frozenset(loop)),
                )
    held.append([lock, 1])


def _note_released(lock: "SanLock") -> None:
    held = _held_stack()
    for i in range(len(held) - 1, -1, -1):
        if held[i][0] is lock:
            held[i][1] -= 1
            if held[i][1] == 0:
                del held[i]
            return


def _drop_all(lock: "SanLock") -> int:
    """Remove `lock` from the held stack entirely (condition wait releases
    every recursion level); returns the count to restore afterwards."""
    held = _held_stack()
    for i in range(len(held) - 1, -1, -1):
        if held[i][0] is lock:
            count = held[i][1]
            del held[i]
            return count
    return 0


def _restore(lock: "SanLock", count: int) -> None:
    if count:
        _held_stack().append([lock, count])


def held_locks() -> Iterator[str]:
    """Names of sanitizer locks held by the current thread."""
    return (entry[0].name for entry in _held_stack())


class SanLock:
    """Wrapper over threading.Lock/RLock with order-graph instrumentation."""

    __slots__ = ("_lock", "name")

    def __init__(self, name: str, factory=threading.Lock) -> None:
        self._lock = factory()
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got and STATE.enabled:
            _note_acquired(self)
        return got

    def release(self) -> None:
        if STATE.enabled:
            _note_released(self)
        self._lock.release()

    def __enter__(self) -> "SanLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def held_by_me(self) -> bool:
        return any(entry[0] is self for entry in _held_stack())

    def __repr__(self) -> str:
        return f"<SanLock {self.name!r}>"


class SanCondition:
    """Condition-variable wrapper keeping held-lock bookkeeping consistent
    across ``wait`` (which releases the underlying lock in full)."""

    __slots__ = ("_san", "_cond")

    def __init__(self, name: str) -> None:
        self._san = SanLock(name, factory=threading.RLock)
        self._cond = threading.Condition(self._san._lock)
        # The condition shares the SanLock's raw lock, so acquire/release on
        # either keeps the same bookkeeping.

    @property
    def name(self) -> str:
        return self._san.name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        return self._san.acquire(blocking, timeout)

    def release(self) -> None:
        self._san.release()

    def __enter__(self) -> "SanCondition":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def held_by_me(self) -> bool:
        return self._san.held_by_me()

    def wait(self, timeout: float | None = None) -> bool:
        saved = _drop_all(self._san) if STATE.enabled else 0
        try:
            return self._cond.wait(timeout)
        finally:
            if STATE.enabled:
                _restore(self._san, saved)

    def wait_for(self, predicate, timeout: float | None = None):
        # Re-implemented over self.wait so the held-lock bookkeeping sees
        # every release/reacquire (Condition.wait_for would bypass it).
        import time
        endtime = None
        result = predicate()
        while not result:
            if timeout is not None:
                if endtime is None:
                    endtime = time.monotonic() + timeout
                waittime = endtime - time.monotonic()
                if waittime <= 0:
                    break
                self.wait(waittime)
            else:
                self.wait()
            result = predicate()
        return result

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()

    def __repr__(self) -> str:
        return f"<SanCondition {self.name!r}>"


def san_lock(name: str) -> SanLock:
    return SanLock(name, factory=threading.Lock)


def san_rlock(name: str) -> SanLock:
    return SanLock(name, factory=threading.RLock)


def san_condition(name: str) -> SanCondition:
    return SanCondition(name)
