"""figaro-san: the runtime sanitizer layer (dynamic counterpart to figaro-lint).

Three checks, all off by default and enabled together by ``FIGARO_SAN=1`` in
the environment or :func:`enable`:

* **race** (`races`, `locks`, `threads`) — instrumented lock wrappers and a
  lockset detector: per-thread lock-order graph with cycle (potential
  deadlock) findings, plus cross-thread shared-attribute access without the
  owning lock held, on the classes that declare ``@shared_state``.
* **retrace** (`retrace`) — every engine compile records its dispatch
  signature and trimmed call stack; steady-state mode turns any further
  compile into a finding that names the diverged signature component.
* **numerics** (`numerics`) — sampled float64 shadow dispatch asserting the
  observed error against the paper's database-size rounding-error budget,
  plus NaN/Inf tripwires on dispatch outputs.

Disabled cost is one attribute read per instrumentation site (the race
hooks are physically removed from the classes). Everything importable here
is stdlib-only; `numerics` (the one jax-dependent module) is imported
lazily by the engine. Quickstart §10 shows the full workflow, including the
"adding a runtime check" recipe.
"""

from __future__ import annotations

from . import _state, retrace
from ._state import STATE, SanFinding, env_enabled
from .locks import (SanCondition, SanLock, reset_order_graph, san_condition,
                    san_lock, san_rlock)
from .races import shared_state
from .threads import san_thread

__all__ = [
    "STATE", "SanFinding", "enable", "disable", "enabled", "reset",
    "findings", "report", "san_lock", "san_rlock", "san_condition",
    "san_thread", "shared_state", "SanLock", "SanCondition",
    "expect_no_retrace",
]

expect_no_retrace = retrace.expect_no_retrace


def enabled() -> bool:
    return STATE.enabled


def enable(*, race: bool = True, retrace_check: bool = True,
           numerics: bool = True, sample_every: int | None = None,
           slack: float | None = None) -> None:
    """Turn the sanitizer on (installing the race-detector class hooks)."""
    from . import races

    STATE.race = race
    STATE.retrace = retrace_check
    STATE.numerics = numerics
    if sample_every is not None:
        STATE.sample_every = int(sample_every)
    if slack is not None:
        STATE.numerics_slack = float(slack)
    STATE.enabled = True
    if race:
        races.install()


def disable() -> None:
    """Turn the sanitizer off and remove the race-detector class hooks."""
    from . import races

    STATE.enabled = False
    races.uninstall()


def reset() -> None:
    """Clear findings and observation state (keeps the enabled flag)."""
    from . import races

    STATE.clear_findings()
    races.reset_observations()
    reset_order_graph()
    retrace.reset()
    try:
        from . import numerics as _numerics
    except ImportError:  # pragma: no cover - numpy always present in tier-1
        pass
    else:
        _numerics.reset()


def findings(check: str | None = None) -> list[SanFinding]:
    return STATE.findings(check)


def report() -> str:
    return STATE.report()


if env_enabled():  # FIGARO_SAN=1: arm everything at import time
    enable()
