"""Shared mutable state for figaro-san.

One module-level :class:`SanitizerState` singleton holds the on/off flag,
per-check toggles, the finding registry, and the thread-local shadow-dispatch
marker. Everything here is stdlib-only so the analysis CI job (which has no
jax) can import the sanitizer; the numerics check imports jax lazily from its
own module.

The cardinal rule is that the *disabled* path must stay near-free: every
instrumentation site guards on ``STATE.enabled`` (a plain attribute read)
before doing any work, and the race detector's attribute hooks are only
installed on classes while the sanitizer is enabled.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import traceback
from typing import Any, Iterable

#: Frames whose filenames contain one of these fragments are dropped from
#: captured stacks — they are plumbing, not the call site the user wants.
_STACK_NOISE = ("/jax/", "/jaxlib/", "site-packages", "/repro/sanitizer/",
                "/threading.py", "/repro/core/engine.py")


@dataclasses.dataclass(frozen=True)
class SanFinding:
    """One runtime finding. ``check`` names the sub-sanitizer (``race``,
    ``lock-order``, ``retrace``, ``numerics``); ``stack`` is the trimmed
    call-site stack captured when the finding fired."""

    check: str
    message: str
    thread: str
    stack: tuple[str, ...] = ()
    details: dict[str, Any] = dataclasses.field(default_factory=dict, hash=False)

    def render(self) -> str:
        head = f"[figaro-san:{self.check}] {self.message} (thread={self.thread})"
        if not self.stack:
            return head
        return head + "\n" + "\n".join(f"    at {f}" for f in self.stack)


def trimmed_stack(limit: int = 6, skip: int = 2) -> tuple[str, ...]:
    """Trimmed call stack of the current thread: drops sanitizer/jax/stdlib
    plumbing frames, keeps the innermost ``limit`` user frames."""
    frames = traceback.extract_stack()[:-skip]
    keep = [f"{f.filename}:{f.lineno} in {f.name}"
            for f in frames
            if not any(n in f.filename.replace(os.sep, "/")
                       for n in _STACK_NOISE)]
    return tuple(keep[-limit:])


class SanitizerState:
    """Process-wide sanitizer switchboard and finding registry."""

    def __init__(self) -> None:
        self.enabled = False
        self.race = True
        self.retrace = True
        self.numerics = True
        #: Shadow-dispatch sampling: the first dispatch of each signature is
        #: always shadowed; afterwards every ``sample_every``-th dispatch is.
        self.sample_every = 16
        #: Slack multiplier on the analytic rounding-error budget. The model
        #: counts rotations, not the exact constant in front, so the budget
        #: carries an engineering factor like any backward-stability bound.
        self.numerics_slack = 64.0
        self.max_findings = 256
        self._reg_lock = threading.Lock()
        self._findings: list[SanFinding] = []
        self._fingerprints: set[tuple] = set()
        self._tls = threading.local()

    # -- findings ------------------------------------------------------------

    def add_finding(self, check: str, message: str, *,
                    details: dict[str, Any] | None = None,
                    stack: tuple[str, ...] | None = None,
                    dedupe_key: tuple | None = None) -> SanFinding | None:
        """Record a finding (deduped by ``dedupe_key`` when given). Returns
        the finding, or None if it was a duplicate or the registry is full."""
        if stack is None:
            stack = trimmed_stack(skip=3)
        f = SanFinding(check=check, message=message,
                       thread=threading.current_thread().name,
                       stack=stack, details=dict(details or {}))
        with self._reg_lock:
            key = dedupe_key if dedupe_key is not None else (check, message)
            if key in self._fingerprints:
                return None
            if len(self._findings) >= self.max_findings:
                return None
            self._fingerprints.add(key)
            self._findings.append(f)
        return f

    def findings(self, check: str | None = None) -> list[SanFinding]:
        with self._reg_lock:
            out = list(self._findings)
        if check is not None:
            out = [f for f in out if f.check == check]
        return out

    def clear_findings(self) -> None:
        with self._reg_lock:
            self._findings.clear()
            self._fingerprints.clear()

    def report(self) -> str:
        """Human-readable report grouped by check, mirroring figaro-lint's
        findings output."""
        found = self.findings()
        if not found:
            return "figaro-san: no findings"
        by_check: dict[str, list[SanFinding]] = {}
        for f in found:
            by_check.setdefault(f.check, []).append(f)
        lines = [f"figaro-san: {len(found)} finding(s)"]
        for check in sorted(by_check):
            lines.append(f"-- {check} ({len(by_check[check])}) --")
            lines.extend(f.render() for f in by_check[check])
        return "\n".join(lines)

    # -- shadow-dispatch marker ---------------------------------------------

    def shadow_active(self) -> bool:
        return getattr(self._tls, "in_shadow", False)

    def set_shadow(self, active: bool) -> None:
        self._tls.in_shadow = active


STATE = SanitizerState()


def env_enabled(environ: dict[str, str] | None = None) -> bool:
    val = (environ if environ is not None else os.environ).get("FIGARO_SAN", "")
    return val.strip().lower() in ("1", "true", "yes", "on")


def iter_checks() -> Iterable[str]:
    return ("race", "lock-order", "thread", "retrace", "numerics")
