"""Sanitizer-aware thread factory.

``san_thread(target=...)`` is a drop-in for ``threading.Thread``; FIG007
requires every thread started under ``src/`` to route through it. The
wrapper notes thread start/exit with the race detector (so "observed from
two threads" is anchored to real thread entries, not incidental imports)
and flags a finding if a thread exits while still holding sanitizer locks —
a leak that would deadlock the next acquirer forever.
"""

from __future__ import annotations

import threading

from ._state import STATE
from .locks import held_locks


def san_thread(target, *, args=(), kwargs=None, name: str | None = None,
               daemon: bool | None = None) -> threading.Thread:
    kwargs = kwargs or {}

    def run() -> None:
        try:
            target(*args, **kwargs)
        finally:
            if STATE.enabled:
                leaked = sorted(held_locks())
                if leaked:
                    STATE.add_finding(
                        "thread",
                        f"thread exited holding lock(s): {', '.join(leaked)}",
                        details={"locks": leaked},
                        dedupe_key=("thread-leak", tuple(leaked),
                                    threading.current_thread().name),
                    )

    t = threading.Thread(target=run, name=name)
    if daemon is not None:
        t.daemon = daemon
    return t
