"""Retrace sanitizer: *why* did the engine compile again?

`FigaroEngine` already counts traces per kind; the zero-retrace append
contract is asserted by diffing those counters. A bare counter diff says
"something retraced" — this module says *what*. The engine calls
:func:`note_trace` from inside the jit wrapper (which runs exactly once per
trace) with the full dispatch cache key; we store each kind's previous key
and, on a retrace, name the first signature component that diverged plus the
trimmed call stack of the dispatch that triggered it.

Steady-state mode (:func:`expect_no_retrace`) arms a tripwire: once armed,
*every* trace is a ``retrace`` finding. The append stress tests run armed
after warmup, so a contract violation fails with attribution instead of a
counter assert.
"""

from __future__ import annotations

import collections
import threading

from ._state import STATE, trimmed_stack

#: Components of the engine dispatch key, in order. Kept in sync with
#: ``FigaroEngine._signature``'s cache-key layout: one element per key slot
#: (the plan treedef + index-leaf abstracts travel as the single
#: ``plan_signature`` element there).
KEY_COMPONENTS = ("kind", "donate", "mesh_signature", "batch_axis",
                  "plan_signature", "data_abstract", "options")

_lock = threading.Lock()
_last_key: dict[str, tuple] = {}
_events: "collections.deque" = collections.deque(maxlen=64)
_armed = False


class TraceEvent:
    __slots__ = ("kind", "diverged", "stack")

    def __init__(self, kind: str, diverged: list[str],
                 stack: tuple[str, ...]) -> None:
        self.kind = kind
        self.diverged = diverged
        self.stack = stack


def reset() -> None:
    global _armed
    with _lock:
        _last_key.clear()
        _events.clear()
        _armed = False


def expect_no_retrace(armed: bool = True) -> None:
    """Arm (or disarm) steady-state mode: any further trace is a finding."""
    global _armed
    with _lock:
        _armed = armed


def events() -> list[TraceEvent]:
    with _lock:
        return list(_events)


def _diff_components(old: tuple, new: tuple) -> list[str]:
    out = []
    for i, label in enumerate(KEY_COMPONENTS):
        o = old[i] if i < len(old) else None
        n = new[i] if i < len(new) else None
        if o != n:
            out.append(label)
    return out or ["<identical key: cache eviction or first use>"]


def note_trace(kind: str, key: tuple) -> None:
    """Called from the engine's jit wrapper body — i.e. once per compile."""
    stack = trimmed_stack(skip=3, limit=8)
    with _lock:
        prev = _last_key.get(kind)
        diverged = _diff_components(prev, key) if prev is not None else []
        _last_key[kind] = key
        armed = _armed
        _events.append(TraceEvent(kind, diverged, stack))
    if not armed:
        return  # unarmed: warmup compiles are expected, events suffice
    what = ", ".join(diverged) if diverged else "first trace while armed"
    site = stack[-1] if stack else "?"
    STATE.add_finding(
        "retrace",
        f"retrace of kind={kind}: diverged signature component(s): {what}",
        stack=stack,
        details={"kind": kind, "diverged": diverged, "armed": armed},
        dedupe_key=("retrace", kind, tuple(diverged), site),
    )


def last_trace(kind: str) -> TraceEvent | None:
    """Most recent trace event for `kind`, for attribution in tests."""
    with _lock:
        for ev in reversed(_events):
            if ev.kind == kind:
                return ev
    return None
