"""Roofline terms from a compiled (dry-run) artifact — no hardware required.

TPU v5e constants (per chip): 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI.

``compiled.cost_analysis()`` reports FLOPs/bytes of the *partitioned*
per-device program, so the three terms come out per-device directly:

    compute_s    = flops / PEAK_FLOPS
    memory_s     = bytes_accessed / HBM_BW
    collective_s = collective_bytes / ICI_BW

collective_bytes is not in cost_analysis — we parse the post-SPMD HLO and sum
*operand* sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (ring-hop multipliers are intentionally not modeled;
the term is a lower bound and says which cells are collective-bound).

MODEL_FLOPS uses 6·N·D (dense) / 6·N_active·D (MoE) per trained token and
2·N·D per generated/prefilled token; the ratio MODEL_FLOPS / (flops·chips)
exposes remat/dispatch/padding waste.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # e.g.:  %all-reduce.5 = f32[128,512] all-reduce(f32[128,512] %x), ...
        m = re.search(r"=\s+[^\s]+\s+(" + "|".join(_COLLECTIVES) +
                      r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        args = stripped[m.end():]
        depth = 1
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = args[:end]
        total = sum(_shape_bytes(d, s) for d, s in
                    _SHAPE_RE.findall(operand_str))
        if total == 0:
            # operands may be given as bare %refs; fall back to result shape
            m2 = _SHAPE_RE.search(stripped.split("=", 1)[1])
            if m2:
                total = _shape_bytes(m2.group(1), m2.group(2))
        out[kind] += total
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict[str, int]
    peak_memory_per_device: float
    model_flops: float  # 6·N_active·D (train) / 2·N_active·tokens (serve)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate: max of the three overlapping terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        t = self.step_s
        return (self.model_flops / (self.chips * PEAK_FLOPS) / t) if t else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_s=self.step_s,
                 useful_flops_fraction=self.useful_flops_fraction,
                 mfu=self.mfu)
        return d


def build_roofline(*, arch: str, shape: str, mesh_name: str, chips: int,
                   cost: dict, peak_memory: float, model_flops: float,
                   hlo_text: str | None = None,
                   coll: dict[str, int] | None = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if coll is None:
        coll = collective_bytes(hlo_text or "")
    coll_total = float(sum(coll.values()))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        peak_memory_per_device=peak_memory, model_flops=model_flops,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_total / ICI_BW,
    )
