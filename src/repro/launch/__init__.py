"""Launchers: mesh construction, multi-pod dry-run, fault-tolerant trainer.

NOTE: do not import `dryrun` from library code — importing it sets
XLA_FLAGS for 512 host devices (by design, as the very first lines).
"""

from .mesh import make_production_mesh, make_host_mesh  # noqa: F401
