"""Production meshes. A v5e pod is 16x16 = 256 chips; multi-pod adds a
leading `pod` axis (2 pods = 512 chips for the dry-run).

`make_production_mesh` is a FUNCTION (module import never touches jax device
state); the dry-run sets XLA_FLAGS before any jax import to get 512 host
placeholder devices.  Mesh construction goes through `repro.compat` so the
same code runs on JAX versions with and without `AxisType` / the
`axis_types=` kwarg.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh
from repro.core.plan_cache import next_pow2

__all__ = ["make_production_mesh", "make_host_mesh", "make_data_mesh",
           "serving_batch_capacity"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> Mesh:
    """Elastic small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def make_data_mesh(num_devices: int | None = None) -> Mesh:
    """1-D ``data`` mesh over the first ``num_devices`` local devices (default:
    all) — the serving mesh for `FigaroEngine`'s ``shard=`` batched dispatch
    and `distributed_postprocess_r0`. Any device count works; the butterfly
    combine pads non-power-of-two axes."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"num_devices={n} outside [1, {len(devs)}]")
    return make_mesh((n,), ("data",), axis_types=(AxisType.Auto,),
                     devices=devs[:n])


def serving_batch_capacity(b: int, *, axis_size: int = 1) -> int:
    """Bucketed request-batch capacity for a live batch of ``b`` requests.

    The async serving queue (`train.async_serve`) dispatches coalesced
    micro-batches at these capacities — the next power of two, rounded up to
    a multiple of the serving mesh's ``data`` axis — so the executable cache
    keys on a handful of batch *buckets* instead of every live batch size,
    and a sharded dispatch never re-pads inside the engine. B=0 has no
    trailing request to repeat; it keeps its own (empty) signature.
    """
    if b <= 0:
        return 0
    cap = next_pow2(b)
    if axis_size > 1:
        cap = -(-cap // axis_size) * axis_size
    return cap
