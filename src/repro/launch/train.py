"""Fault-tolerant training driver.

Production behaviours implemented here (and exercised by examples/train_lm.py
and tests/test_train_driver.py):

  * **Auto-resume**: restores the latest checkpoint in --ckpt-dir (atomic
    files only — a crash mid-write leaves the previous checkpoint intact) and
    deterministically skips the data stream to the restored step.
  * **Elastic restore**: checkpoints are device-agnostic; the restore path
    reshards onto whatever mesh exists at restart (different device count,
    different DP/TP split — e.g. resume a 512-chip run on 256 chips).
  * **Preemption safety**: SIGTERM/SIGINT triggers a final blocking save
    before exit (the cluster scheduler's 30s grace window is enough for the
    async writer to flush).
  * **Straggler watchdog**: logs any step slower than --watchdog-factor ×
    the running median — on real fleets this is the signal that feeds
    hot-spare rescheduling; here it is surfaced in the step log.
  * **Gradient compression** (--grad-compression): error-feedback int8 for
    the cross-pod all-reduce (optim/compression.py).
  * **Beyond-paper**: --orthogonal-update routes 2-D gradients through the
    paper's TSQR machinery (optim/orthogonal.py).

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import signal
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import warmup_cosine, wsd
from repro.sharding.rules import param_shardings
from repro.train.step import TrainState, init_state, make_train_step


def _state_shardings(cfg, mesh, state_shape):
    p_sh = param_shardings(cfg, mesh, state_shape.params)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=p_sh,
        opt_state={"mu": p_sh, "nu": p_sh, "step": rep},
        step=rep,
    )


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size of the host mesh")
    ap.add_argument("--watchdog-factor", type=float, default=3.0)
    ap.add_argument("--orthogonal-update", action="store_true")
    ap.add_argument("--grad-compression", action="store_true",
                    help="error-feedback int8 cross-pod gradient all-reduce "
                         "(requires a `pod` mesh axis; logged otherwise)")
    return ap


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch, smoke=args.smoke)

    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    sched = (warmup_cosine(args.lr, args.warmup, args.steps) if
             args.schedule == "cosine" else
             wsd(args.lr, args.warmup, int(args.steps * 0.6),
                 int(args.steps * 0.4 - args.warmup)))
    opt_cfg = AdamWConfig(lr=sched)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, mesh, microbatch=args.microbatch or None,
        orthogonal_update=args.orthogonal_update))
    if args.grad_compression and "pod" not in mesh.shape:
        print("[train] --grad-compression requested but mesh has no `pod` "
              "axis; skipping (single-pod all-reduce stays full-precision)")

    state = init_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg)
    state_shape = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    shardings = _state_shardings(cfg, mesh, state_shape)

    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest(state_shape, shardings)
        if restored is not None:
            start_step, state = restored
            print(f"[train] resumed from step {start_step} "
                  f"(elastic restore onto {len(jax.devices())} devices)")

    pipe = TokenPipeline(cfg.vocab, args.seq, args.batch, seed=args.seed)
    stream = pipe.start(start_step)

    # Preemption: save-and-exit on SIGTERM/SIGINT.
    preempted = {"flag": False}

    def _sig(_signo, _frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    step_times: list[float] = []
    losses: list[float] = []
    t_train0 = time.time()
    cur = start_step
    with mesh:
        for cur in range(start_step, args.steps):
            batch = next(stream)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # realizes the step
            dt = time.time() - t0
            losses.append(loss)
            if len(step_times) >= 5:
                med = statistics.median(step_times)
                if dt > args.watchdog_factor * med:
                    print(f"[watchdog] step {cur} took {dt:.2f}s "
                          f"(median {med:.2f}s) — straggler suspected")
            step_times.append(dt)
            if not np.isfinite(loss):
                print(f"[train] non-finite loss at step {cur}; "
                      "halting before the checkpoint is poisoned")
                pipe.stop()
                return 2
            if (cur + 1) % args.log_every == 0:
                tput = args.batch * args.seq / max(dt, 1e-9)
                print(f"step {cur + 1:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"{dt * 1e3:.0f} ms  {tput:.0f} tok/s", flush=True)
            if mgr is not None and (cur + 1) % args.ckpt_every == 0:
                mgr.save(cur + 1, state,
                         extra_meta={"arch": cfg.name,
                                     "devices": len(jax.devices())})
            if preempted["flag"]:
                print(f"[train] preemption signal at step {cur + 1}; "
                      "writing final checkpoint")
                break
    pipe.stop()
    if mgr is not None:
        mgr.save(cur + 1, state, blocking=True,
                 extra_meta={"arch": cfg.name, "final": True})
    if losses:
        print(f"[train] done: steps {start_step}->{cur + 1} "
              f"loss {losses[0]:.4f}->{losses[-1]:.4f} "
              f"({time.time() - t_train0:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
