import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).
# 512 placeholder host devices exist ONLY in this process — smoke tests and
# benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step / prefill /
decode_step) with ShapeDtypeStruct stand-ins carrying full production
shardings, compiles it for the 16×16 (single-pod, 256 chips) and 2×16×16
(multi-pod, 512 chips) meshes, prints ``memory_analysis()`` (fits or not) and
``cost_analysis()`` (FLOPs/bytes), parses collective bytes from the
partitioned HLO, and writes the roofline record to JSON
(benchmarks/results/dryrun/). Sharding mismatches, compile-time OOMs and
unsupported collectives surface here as hard failures.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import data_axes, param_shardings
from repro.train.serve import cache_specs, make_decode_step, make_prefill
from repro.train.step import init_state, make_train_step


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(shape_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shape_tree, sharding_tree)


def _batch_specs(cfg: ModelConfig, mesh, batch: int, seq: int):
    """ShapeDtypeStructs for one model batch (tokens + modality stubs)."""
    dp = data_axes(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    text = seq - cfg.patch_positions if cfg.patch_positions else seq
    out = {"tokens": _sds((batch, text), jnp.int32, ns(P(dp, None)))}
    if cfg.is_enc_dec:
        out["frames"] = _sds((batch, cfg.encoder_len, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype), ns(P(dp, None, None)))
    if cfg.patch_positions:
        out["patches"] = _sds((batch, cfg.patch_positions, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype), ns(P(dp, None, None)))
    return out


#: Post-hillclimb defaults (EXPERIMENTS.md §Perf). ``--baseline`` restores the
#: pre-optimization behaviour so both sides of every iteration stay
#: reproducible.
OPT_DEFAULTS = {
    "hier_moe": True,       # §Perf A1: per-DP-shard MoE dispatch
    "seq_parallel": True,   # §Perf Q1: sequence-sharded activations
    "sharded_logits": True,  # §Perf C1: vocab-sharded logits output
    "serve_bf16": True,     # §Perf C2: bf16 weights + no ZeRO at serve time
    "kv_seq_shard": True,   # §Perf C2: KV slots sharded over `model`
    "train_bf16": False,    # §Perf A3: bf16 params+moments for huge-MoE train
}


def input_specs(cfg: ModelConfig, shape_name: str, mesh, *,
                force_micro: int | None = None, opts: dict | None = None):
    """(step_fn, args as sharded ShapeDtypeStructs, model_flops) per cell.

    Pure stand-ins — nothing is allocated; the same pattern a launcher would
    use to compile ahead-of-time on a coordinator host.
    """
    opts = dict(OPT_DEFAULTS, **(opts or {}))
    spec = SHAPES[shape_name]
    batch, seq = spec.global_batch, spec.seq_len
    # Pin activation batch sharding (long_500k's batch=1 shards the KV cache
    # sequence instead — no batch constraint there).
    import dataclasses as _dc
    if batch > 1:
        dp_size = 1
        for ax in data_axes(mesh):
            dp_size *= mesh.shape[ax]
        msz = mesh.shape.get("model", 1)
        ep_ok = cfg.moe is not None and cfg.moe.num_experts % msz == 0
        # Sequence parallelism only for pure-attention stacks: an SSM/RWKV
        # recurrence runs ALONG the sequence dim — sharding it between blocks
        # forces GSPMD into per-chunk resharding of the scan carry (observed:
        # jamba train_4k compile blows past 16 min; attn-only archs compile
        # in seconds).
        sp_ok = all(s.mixer == "attn"
                    for s in cfg.block + cfg.encoder_block)
        cfg = _dc.replace(
            cfg, dp_axes=data_axes(mesh),
            moe_groups=dp_size if (cfg.moe and opts["hier_moe"]) else 1,
            ep_axes=("model",) if (ep_ok and opts["hier_moe"]) else None,
            seq_shard_activations=bool(opts["seq_parallel"]) and sp_ok,
        )
    cfg = _dc.replace(cfg, shard_logits=bool(opts["sharded_logits"]))
    if spec.kind in ("prefill", "decode") and opts["serve_bf16"]:
        # Production serving: bf16 weights; drop ZeRO (per-token weight
        # gathers are pure overhead at inference) ONLY when the TP-sharded
        # bf16 weights actually fit — big-MoE archs (arctic 954 GB bf16)
        # must keep the data-axis weight sharding or they replicate
        # 60 GB/device. §Perf iteration C2 + its memory-fit refinement.
        msz = mesh.shape.get("model", 1)
        tp_resident_gb = 2 * cfg.param_count() / msz / 1e9
        cfg = _dc.replace(cfg, param_dtype="bfloat16",
                          fsdp=cfg.fsdp and tp_resident_gb > 6.0)
    if spec.kind == "train" and opts["train_bf16"]:
        # §Perf A3: bf16 master weights + bf16 moments — halves the ZeRO-3
        # all-gather volume and the optimizer-state footprint (the 0.5T-param
        # arctic config cannot fit a single pod otherwise).
        cfg = _dc.replace(cfg, param_dtype="bfloat16",
                          opt_state_dtype="bfloat16")
    opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
    n_active = cfg.active_param_count()

    params_shape = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), jax.random.PRNGKey(0))
    p_shards = param_shardings(cfg, mesh, params_shape)
    params_sds = _with_shardings(params_shape, p_shards)

    if spec.kind == "train":
        # Auto-microbatch: one sample per device per micro-step — bounds live
        # activations to [1, S, d] per scanned block (grad-accumulated).
        dp_size = 1
        for ax in data_axes(mesh):
            dp_size *= mesh.shape[ax]
        micro = max(1, batch // dp_size) if seq >= 4096 else 1
        if force_micro is not None:
            micro = force_micro
        step = make_train_step(cfg, opt_cfg, mesh, microbatch=micro)
        state_shape = jax.eval_shape(
            lambda k: init_state(k, cfg, opt_cfg), jax.random.PRNGKey(0))
        opt_shards = {
            "mu": p_shards, "nu": p_shards,
            "step": NamedSharding(mesh, P()),
        }
        state_sds = jax.tree_util.tree_map(
            lambda s, sh: _sds(s.shape, s.dtype, sh),
            {"params": state_shape.params, "opt_state": state_shape.opt_state,
             "step": state_shape.step},
            {"params": p_shards, "opt_state": opt_shards,
             "step": NamedSharding(mesh, P())})
        from repro.train.step import TrainState
        state_sds = TrainState(**state_sds)
        batch_sds = _batch_specs(cfg, mesh, batch, seq)
        flops = 6.0 * n_active * batch * seq
        return step, (state_sds, batch_sds), flops

    if spec.kind == "prefill":
        fn = make_prefill(cfg, max_len=seq)
        batch_sds = _batch_specs(cfg, mesh, batch, seq)
        flops = 2.0 * n_active * batch * seq
        return fn, (params_sds, batch_sds), flops

    # decode: one new token against a seq_len-deep cache.
    # Tq == 1: decode attention is ONE pass over the (locally sharded) cache —
    # the blockwise KV loop only exists to bound Tq×block memory in
    # train/prefill. Keeping the loop here makes GSPMD dynamic-slice a
    # model-sharded S dim per block (involuntary full rematerialization;
    # §Perf C3), so decode always attends over the cache in a single block.
    cfg = _dc.replace(cfg, attn_block_kv=max(seq, cfg.attn_block_kv))
    fn = make_decode_step(cfg)
    shard_seq = batch == 1  # context parallelism for long_500k
    cache_shape = jax.eval_shape(
        lambda: {"blocks": tfm.init_cache(cfg, batch, seq),
                 "pos": jnp.zeros((), jnp.int32)})
    spec_fn = cache_specs(cfg, mesh, shard_seq=shard_seq,
                          kv_seq_over_model=bool(opts["kv_seq_shard"]))
    cache_sds = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _sds(leaf.shape, leaf.dtype,
                                NamedSharding(mesh, spec_fn(path, leaf))),
        cache_shape)
    dp = data_axes(mesh)
    tok_spec = P() if shard_seq else P(dp, None)
    tokens_sds = _sds((batch, 1), jnp.int32, NamedSharding(mesh, tok_spec))
    flops = 2.0 * n_active * batch
    return fn, (params_sds, cache_sds, tokens_sds), flops


def _cost_pass(cfg: ModelConfig, shape_name: str, mesh,
               *, overrides: dict | None = None, opts: dict | None = None):
    """cost_analysis + collective bytes of the FULL-depth program.

    XLA's cost_analysis counts loop bodies ONCE, so the production artifact
    (scan over layers, microbatch scan, blockwise-attention scan, chunked-SSM
    scan) undercounts FLOPs/bytes/collectives.  Rather than compiling a
    full-depth unrolled artifact (minutes per cell on this 1-core box), we
    compile TWO small unrolled artifacts — 1 super-block and 2 super-blocks —
    and extrapolate linearly in depth:

        C(n) = C(1) + (n - 1) * (C(2) - C(1))

    which is exact for homogeneous stacks (every super-block is identical by
    construction; embed/lm_head/optimizer-fixed costs live in C(1)'s
    intercept). Enc-dec stacks (whisper) scale encoder_blocks together with
    n_blocks — valid because encoder_blocks == n_blocks for the assigned arch.
    """
    import dataclasses as dc

    seq = SHAPES[shape_name].seq_len
    assert cfg.encoder_blocks in (0, cfg.n_blocks), \
        "depth extrapolation assumes encoder_blocks == n_blocks"
    ov = overrides or {}

    def artifact(k: int):
        ccfg = dc.replace(
            cfg, n_blocks=k,
            encoder_blocks=k if cfg.is_enc_dec else 0,
            scan_layers=False,
            attn_block_kv=ov.get("attn_block_kv",
                                 max(seq, cfg.attn_block_kv)),
            ssm_chunk=ov.get("ssm_chunk", seq),
            **{k2: v for k2, v in ov.items()
               if k2 not in ("attn_block_kv", "ssm_chunk")})
        cfn, cargs, _ = input_specs(ccfg, shape_name, mesh, force_micro=1,
                                    opts=opts)
        with mesh:
            comp = jax.jit(cfn).lower(*cargs).compile()
        cost = comp.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        from repro.launch.roofline import collective_bytes
        return dict(cost), collective_bytes(comp.as_text())

    c1, coll1 = artifact(1)
    c2, coll2 = artifact(2)
    n = cfg.n_blocks

    def extrap(a, b):
        return {k: max(0.0, float(a.get(k, 0.0))
                       + (n - 1) * (float(b.get(k, 0.0)) - float(a.get(k, 0.0))))
                for k in set(a) | set(b)
                if isinstance(a.get(k, b.get(k)), (int, float))}

    return extrap(c1, c2), {k: int(v) for k, v in extrap(coll1, coll2).items()}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, smoke: bool = False, verbose: bool = True,
             with_cost: bool | None = None, opts: dict | None = None,
             cost_overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    cfg = get_config(arch, smoke=smoke)
    runnable, why = cell_is_runnable(cfg, SHAPES[shape_name])
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "reason": why}
    if not runnable:
        if verbose:
            print(f"[skip] {arch} × {shape_name} × {mesh_name}: {why}")
        return rec
    if with_cost is None:
        # Roofline table is single-pod; multi-pod proves the `pod` axis shards.
        with_cost = not multi_pod
    t0 = time.time()
    try:
        fn, args, model_flops = input_specs(cfg, shape_name, mesh, opts=opts)
        # Donate the state (train) / cache (decode): params+opt or KV buffers
        # alias in->out instead of doubling the footprint.
        donate = (0,) if SHAPES[shape_name].kind == "train" else \
            ((1,) if SHAPES[shape_name].kind == "decode" else ())
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()

        cost, coll = ({}, {})
        if with_cost:
            cost, coll = _cost_pass(cfg, shape_name, mesh, opts=opts,
                                    overrides=cost_overrides)
        t_cost = time.time() - t0 - t_lower - t_compile
        peak = 0.0
        mem_rec = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_rec[attr] = int(v)
            peak = float(mem_rec.get("argument_size_in_bytes", 0)
                         + mem_rec.get("temp_size_in_bytes", 0)
                         + mem_rec.get("output_size_in_bytes", 0)
                         - mem_rec.get("alias_size_in_bytes", 0))
        rec = {"status": "ok", "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "memory_analysis": mem_rec,
               "peak_memory_per_device": peak,
               "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
               "cost_pass_s": round(t_cost, 1)}
        if with_cost:
            rl = build_roofline(arch=arch, shape=shape_name,
                                mesh_name=mesh_name, chips=chips, cost=cost,
                                coll=coll, peak_memory=peak,
                                model_flops=model_flops)
            rec.update(rl.to_json())
        if verbose:
            if with_cost:
                print(f"[ok]   {arch} × {shape_name} × {mesh_name}: "
                      f"mem/dev={peak/1e9:.2f}GB "
                      f"flops/dev={rl.flops_per_device:.3e} "
                      f"coll/dev={rl.coll_bytes_per_device:.3e}B "
                      f"dominant={rl.dominant} "
                      f"(lower {t_lower:.0f}s compile {t_compile:.0f}s "
                      f"cost {t_cost:.0f}s)")
            else:
                print(f"[ok]   {arch} × {shape_name} × {mesh_name}: "
                      f"mem/dev={peak/1e9:.2f}GB compile-only "
                      f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print(f"       memory_analysis: {mem_rec}")
    except Exception as e:  # noqa: BLE001 — record and continue in --all mode
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} × {mesh_name}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn_out = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fn_out, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity, not the deliverable)")
    ap.add_argument("--baseline", action="store_true",
                    help="pre-hillclimb behaviour: global MoE sort, no "
                         "sequence parallelism, replicated logits "
                         "(EXPERIMENTS.md §Perf baselines)")
    ap.add_argument("--attn-accounting", choices=["dense", "blockwise"],
                    default="dense",
                    help="cost-pass attention model: 'dense' materializes "
                         "[B,H,S,S] scores (XLA default without a fused "
                         "kernel); 'blockwise' accounts the fused "
                         "flash-style kernel (kernels/flash_attn)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()
    opts = ({k: False for k in OPT_DEFAULTS} if args.baseline else None)
    cost_overrides = None
    if args.attn_accounting == "blockwise":
        cost_overrides = {"attn_block_kv": 1024, "attn_unroll_blocks": True}

    archs = ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, smoke=args.smoke,
                               opts=opts, cost_overrides=cost_overrides)
                n_fail += rec["status"] == "error"
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
