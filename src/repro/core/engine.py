"""Compiled FiGaRo engine: one executable per plan signature, batched serving.

`FigaroEngine` fronts the whole plan → counts → rotations → post-process
pipeline (`qr` / `svd` / `pca` / `least_squares`, plus raw `r0`) behind
`jax.jit` with the `FigaroPlan` passed **through** the jit boundary as a
pytree argument:

  * the plan's static `PlanSpec` is treedef metadata, so the executable cache
    keys on (spec, data shapes/dtypes, static options). Two different
    databases with the same join signature share one compiled program — no
    per-plan closure rebuild, no retrace on refreshed data;
  * data buffers are passed as their own argument and (optionally) **donated**
    to the executable, the serving configuration where request buffers are
    consumed by the dispatch that answers them;
  * `batched=True` vmaps the pipeline over a leading batch axis of the
    per-node data matrices with the plan held fixed — one join structure
    serving many feature-sets/users per dispatch. This is the "one
    factorization, many downstream reads" leverage: everything downstream
    (SVD, PCA, regression) reads off the one R.

  * ``shard=mesh`` (or ``shard=(mesh, axis)``) additionally splits the leading
    request-batch axis of a batched dispatch over the mesh axis with
    `shard_map`: one cached executable answers a *global* batch across all
    devices. The batch is padded up to a multiple of the axis size (by
    repeating the trailing request, so no degenerate all-zero pipelines run on
    the pad) and the pad is sliced off the result — batch sizes in the same
    padded bucket share one executable. The executable cache keys on the mesh
    signature as well as the plan signature.

  * ``bucket=True`` rounds the plan's static sizes up to powers of two
    (`repro.core.plan_cache`) before dispatching, so plans that differ only
    within one bucket land on the same cached executable — this is what
    bounds the compile count under heavy multi-tenant load. Capacity plans
    built with `plan_cache.build_capacity_plan` / refreshed with
    `plan_cache.refresh_plan` dispatch the same way without any per-call
    padding: an append that keeps the bucketed signature is retrace-free.

Trace counts are tracked per pipeline kind (`trace_count`) so tests and
benchmarks can assert cache hits instead of guessing. ``max_cached=`` bounds
the per-kind executable cache with LRU eviction (`eviction_count`) — without
it, heavy multi-tenant bucket misses grow the cache without bound.

`repro.api` (`repro.figaro`) wraps this engine in the user-facing
`Session` / `JoinDataset` façade; new code should usually start there.
"""

from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.sanitizer import _state as _san_state
from repro.sanitizer import numerics as _san_numerics
from repro.sanitizer import retrace as _san_retrace
from repro.sanitizer.locks import san_lock, san_rlock
from repro.sanitizer.races import shared_state

from .counts import compute_counts
from .figaro import figaro_r0
from .join_tree import FigaroPlan, JoinTree, build_plan
from .plan_cache import bucket_spec, pad_data, pad_plan
from .postprocess import postprocess_r0

__all__ = ["FigaroEngine", "PCAResult", "default_engine", "plan_for"]


def _repeat_pad(data, pad: int):
    """Pad the leading request-batch axis by repeating the trailing request
    — near-miss batch sizes then share an executable, and the pad rides
    through a well-posed pipeline (an all-zero pad would push singular
    systems through lsq/svd). The pad is sliced off the result."""
    return tuple(jnp.concatenate([jnp.asarray(d)] + [jnp.asarray(d)[-1:]]
                                 * pad) for d in data)


@functools.lru_cache(maxsize=None)
def _backend_supports_donation() -> bool:
    """CPU's PJRT client ignores buffer donation and warns on every dispatch
    that requests it. Requesting donation only where it works keeps serving
    loops quiet without touching the process-global warnings filters (a
    per-dispatch ``warnings.catch_warnings()`` save/restore is not
    thread-safe once the async serving threads dispatch concurrently with
    the caller's thread)."""
    return jax.default_backend() != "cpu"


def _bucketize(plan: FigaroPlan, data):
    """Pad an exact plan (and its data) into its power-of-two buckets so
    near-miss shapes share an executable; capacity plans pass through."""
    if any(ix.row_mask is not None for ix in plan.index):
        return plan, data  # already capacity-padded (its spec IS the bucket)
    cap = bucket_spec(plan.spec)
    padded = pad_plan(plan, cap)
    if data is not None:
        data = pad_data(data, cap)
    return padded, data


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PCAResult:
    components: jnp.ndarray  # [k, N] principal directions (rows)
    explained_variance: jnp.ndarray  # [k]
    mean: jnp.ndarray  # [N] column means over the join
    num_rows: jnp.ndarray  # scalar: |join|


def _column_moments(plan: FigaroPlan, data, dtype):
    """Factorized column sums & row count of the join (no materialization).

    Row r of relation i appears in exactly Φ°_i(key(r)) join rows, so
    Σ_join A[:, Y_i] = Σ_r data_i[r] · Φ°_i(key(r)) — a per-node weighted sum.
    Node columns are preorder-contiguous, so the global vector is a concat.
    """
    counts = compute_counts(plan, dtype=dtype)
    parts = []
    for sp, ix, d in zip(plan.spec.nodes, plan.index, data):
        w = counts[sp.idx]["phi_circ"][jnp.asarray(ix.row_to_group)]
        if ix.row_mask is not None:  # capacity plan: dead rows weigh nothing
            w = w * jnp.asarray(ix.row_mask, dtype)
        parts.append(w @ jnp.asarray(d, dtype))
    sums = jnp.concatenate(parts)
    total = counts[plan.spec.root]["full"].sum()
    return sums, total


@shared_state({"_jitted": "_cache_lock", "_trace_counts": "_count_lock",
               "_evictions": "_count_lock"})
class FigaroEngine:
    """Executable cache + dispatch for the compiled FiGaRo pipeline.

    One engine holds one `jax.jit` wrapper per (pipeline kind, donation)
    pair; jit's own cache then keys on the plan signature. Use a single
    long-lived engine per process (see `default_engine`) to get cross-call and
    cross-plan executable reuse — e.g. `partitioned_figaro_qr` runs every
    partition and every repeat call through the same engine.

    ``donate_data=True`` (default) donates caller-provided data buffers to the
    dispatch (serving mode: request buffers are consumed). Buffers taken from
    ``plan.data`` are never donated — the plan stays reusable. Pass
    ``donate_data=False`` when callers re-dispatch the same buffers
    (benchmark loops).

    ``max_cached=`` caps the number of cached executables **per pipeline
    kind** (``qr``, ``qr_batched``, ...). The cache is LRU: dispatching a new
    signature past the cap evicts the least-recently-used executable of that
    kind (its compiled program is dropped); re-dispatching an evicted
    signature recompiles (visible in `trace_count`). Evictions are counted
    per kind next to the trace counters — `eviction_count(kind)`. The default
    (``None``) keeps every executable, the pre-existing behavior.
    """

    _STATIC = {
        "r0": ("dtype", "use_kernel", "assembly"),
        "r0_batched": ("dtype", "use_kernel", "assembly"),
        "qr": ("dtype", "method", "leaf_rows", "panel", "use_kernel",
               "assembly"),
        "qr_batched": ("dtype", "method", "leaf_rows", "panel", "use_kernel",
                       "assembly"),
        "svd": ("dtype", "method", "leaf_rows", "panel", "use_kernel",
                "assembly"),
        "svd_batched": ("dtype", "method", "leaf_rows", "panel", "use_kernel",
                        "assembly"),
        "pca": ("dtype", "k", "center", "method", "leaf_rows", "panel",
                "use_kernel", "assembly"),
        "pca_batched": ("dtype", "k", "center", "method", "leaf_rows",
                        "panel", "use_kernel", "assembly"),
        "least_squares": ("dtype", "label_col", "ridge", "method",
                          "leaf_rows", "panel", "use_kernel", "assembly"),
        "least_squares_batched": ("dtype", "label_col", "ridge", "method",
                                  "leaf_rows", "panel", "use_kernel",
                                  "assembly"),
    }

    def __init__(self, *, donate_data: bool = True,
                 max_cached: int | None = None):
        if max_cached is not None and max_cached < 1:
            raise ValueError(f"max_cached must be >= 1 or None, "
                             f"got {max_cached}")
        self.donate_data = donate_data
        self.max_cached = max_cached
        # Executable cache, keyed on the FULL dispatch signature (kind, mesh,
        # plan treedef + leaf shapes/dtypes, static options) with one jit
        # wrapper per entry, so eviction can drop exactly one executable.
        # Insertion/access order is the LRU order. The locks make cache
        # bookkeeping and counter bumps safe under concurrent dispatch (the
        # async serving path dispatches from a background thread while the
        # owning session may keep dispatching from the caller's thread); they
        # are sanitizer-aware wrappers (FIG007) so FIGARO_SAN=1 can observe
        # lock order and cross-thread access. Locks are created before the
        # state they guard so the race detector can resolve them mid-__init__.
        self._cache_lock = san_rlock("engine._cache_lock")
        self._count_lock = san_lock("engine._count_lock")
        self._trace_counts: collections.Counter = collections.Counter()
        self._evictions: collections.Counter = collections.Counter()
        self._jitted: collections.OrderedDict = collections.OrderedDict()

    # -- cache plumbing ------------------------------------------------------

    def trace_count(self, kind: str | None = None) -> int:
        """Number of traces (compilations) since construction; cache-hit tests
        assert this stays flat across same-signature dispatches."""
        with self._count_lock:
            if kind is None:
                return sum(self._trace_counts.values())
            return self._trace_counts[kind]

    def trace_counts(self) -> dict[str, int]:
        """Per-kind trace counts as a plain dict (for stats surfaces)."""
        with self._count_lock:
            return {k: int(v) for k, v in sorted(self._trace_counts.items())}

    def eviction_count(self, kind: str | None = None) -> int:
        """Executables evicted by the ``max_cached`` LRU policy (0 when
        unbounded); tracked per kind, next to the trace counters."""
        with self._count_lock:
            if kind is None:
                return sum(self._evictions.values())
            return self._evictions[kind]

    def cache_size(self, kind: str | None = None) -> int:
        """Number of live cached executables (per kind, or total)."""
        with self._cache_lock:
            if kind is None:
                return len(self._jitted)
            return sum(1 for k in self._jitted if k[0] == kind)

    def _bump(self, kind: str) -> None:
        with self._count_lock:
            self._trace_counts[kind] += 1

    @staticmethod
    def _abstract(leaves) -> tuple:
        return tuple((np.shape(l), np.dtype(getattr(l, "dtype", None)
                                            or np.asarray(l).dtype).str)
                     for l in leaves)

    def _signature(self, kind: str, plan: FigaroPlan, data, donate: bool,
                   mesh, axis, options) -> tuple:
        """Hashable key covering everything a dispatch compiles against.

        The plan half (treedef + index-leaf shapes/dtypes) is cached on the
        plan object: flattening ~dozens of leaves per dispatch costs ~100µs,
        and plan lifecycles (`plan_cache.refresh_plan`, `with_data`) replace
        plan objects rather than mutating array shapes in place."""
        plan_sig = getattr(plan, "_engine_sig", None)
        if plan_sig is None:
            leaves, treedef = jax.tree_util.tree_flatten(plan.without_data())
            plan_sig = plan._engine_sig = (treedef, self._abstract(leaves))
        return (kind, donate, mesh, axis, plan_sig,
                self._abstract(data), tuple(sorted(options.items())))

    def _evict_lru(self, kind: str) -> None:
        """Drop least-recently-used executables of ``kind`` past the cap."""
        if self.max_cached is None:
            return
        while self.cache_size(kind) > self.max_cached:
            oldest = next(k for k in self._jitted if k[0] == kind)
            fn = self._jitted.pop(oldest)
            clear = getattr(fn, "clear_cache", None)
            if clear is not None:  # free the compiled program eagerly
                clear()
            with self._count_lock:
                self._evictions[kind] += 1

    @staticmethod
    def _normalize_shard(shard) -> tuple[Mesh | None, str | None]:
        """``shard=mesh`` or ``shard=(mesh, axis)`` → (mesh, axis)."""
        if shard is None:
            return None, None
        mesh, axis = shard if isinstance(shard, tuple) else (shard, "data")
        if axis not in mesh.shape:
            raise ValueError(
                f"shard axis {axis!r} not in mesh axes {tuple(mesh.shape)}")
        return mesh, axis

    def _make_jitted(self, kind: str, donate: bool, mesh, axis, key: tuple):
        impl = getattr(self, f"_{kind}_impl")
        if mesh is None:
            inner = impl
        else:
            def inner(plan, data, **options):
                # Per-shard body: the plan (index arrays) is replicated, the
                # leading request-batch axis of every data leaf is split over
                # ``mesh[axis]``; every output leaf has a leading batch axis.
                body = lambda p, d: impl(p, d, **options)
                # check_rep=False: pallas_call (the fused node kernel) has no
                # replication rule, and nothing here relies on the check —
                # the plan is replicated in, all outputs are P(axis)-sharded.
                mapped = shard_map(body, mesh=mesh,
                                   in_specs=(P(), P(axis)),
                                   out_specs=P(axis),
                                   check_rep=False)
                return mapped(plan, data)

        # wraps() keeps impl's signature visible so static_argnames resolve,
        # and putting the bump here (outside shard_map) guarantees exactly one
        # count per compilation however many times shard_map replays the body.
        # Shadow (float64 reference) dispatches from the numerics sanitizer
        # must not count as traces or feed the retrace sanitizer — they are
        # sanitizer-internal, not part of the serving contract.
        @functools.wraps(impl)
        def wrapper(plan, data, **options):
            if not _san_state.STATE.shadow_active():
                self._bump(kind)
                if _san_state.STATE.enabled and _san_state.STATE.retrace:
                    _san_retrace.note_trace(kind, key)
            return inner(plan, data, **options)

        return jax.jit(wrapper, static_argnames=self._STATIC[kind],
                       donate_argnums=(1,) if donate else ())

    def _dispatch(self, kind: str, plan: FigaroPlan, data, *, shard=None,
                  bucket: bool = False, batch_capacity: int | None = None,
                  **options):
        if not isinstance(plan, FigaroPlan):
            raise TypeError(_plan_arg_error("plan", plan))
        if bucket:
            plan, data = _bucketize(plan, data)
        mesh, axis = self._normalize_shard(shard)
        if mesh is not None and not kind.endswith("_batched"):
            raise ValueError(
                f"shard= requires a batched dispatch, got kind={kind!r}")
        if batch_capacity is not None and not kind.endswith("_batched"):
            raise ValueError(f"batch_capacity= requires a batched dispatch, "
                             f"got kind={kind!r}")
        if data is None:
            if mesh is not None:
                # plan.data is per-node [m_i, n_i] — there is no request-batch
                # axis to shard; padding it would fail deep inside vmap.
                raise ValueError(
                    "shard= needs an explicit [B, m_i, n_i] data batch")
            data, donate = plan.data, False  # plan-owned buffers stay alive
        else:
            data = tuple(data)
            # Never donate buffers the plan owns, even when the caller passes
            # them explicitly — donation would kill plan.data for later
            # dispatches on backends with real donation.
            plan_owned = {id(d) for d in plan.data}
            donate = (self.donate_data and _backend_supports_donation()
                      and not any(id(d) in plan_owned for d in data))
        b_live = cap_pad = 0
        if batch_capacity is not None and data:
            # Partial-batch bucket selection: pad the request axis up to the
            # chosen batch capacity (repeating the trailing request, as the
            # mesh path does) so live batch sizes in one bucket share one
            # executable; the pad is sliced off the result below. B=0 cannot
            # repeat a trailing request — it dispatches at its own (cheap to
            # compile) signature instead.
            b_live = int(np.shape(data[0])[0])
            cap_pad = batch_capacity - b_live
            if b_live and cap_pad > 0:
                data = _repeat_pad(data, cap_pad)
                # padded buffers are fresh — never plan-owned
                donate = self.donate_data and _backend_supports_donation()
            elif cap_pad < 0:
                raise ValueError(
                    f"batch_capacity={batch_capacity} smaller than the live "
                    f"request batch ({b_live})")
            else:
                cap_pad = 0
        b = pad = 0
        if mesh is not None:
            p = mesh.shape[axis]
            b = int(data[0].shape[0])
            if b == 0:
                # Nothing to shard — the pad-by-repeating-the-trailing-request
                # bucketing would index an empty batch out of range. Answer
                # through the unsharded batched executable, which vmaps over
                # the empty axis and returns correctly-shaped empty results.
                return self._dispatch(kind, plan, data, **options)
            pad = -(-b // p) * p - b
            if pad:
                # Bucket the batch to a multiple of the mesh axis.
                data = _repeat_pad(data, pad)
                # padded buffers are fresh — never plan-owned
                donate = self.donate_data and _backend_supports_donation()
            data = jax.device_put(data, NamedSharding(mesh, P(axis)))
        key = self._signature(kind, plan, data, donate, mesh, axis, options)
        shadow = None
        if _san_state.STATE.enabled and _san_state.STATE.numerics:
            # Host-copy the request before the jit call: donation may consume
            # the device buffers, and the float64 shadow re-dispatch needs
            # the original values.
            shadow = _san_numerics.prepare_shadow(self, kind, plan, data,
                                                  options)
        with self._cache_lock:
            fn = self._jitted.get(key)
            if fn is None:
                fn = self._jitted[key] = self._make_jitted(kind, donate, mesh,
                                                           axis, key)
                self._evict_lru(kind)
            else:
                self._jitted.move_to_end(key)  # LRU: most-recent at the tail
        out = fn(plan.without_data(), data, **options)
        if shadow is not None:
            # Before pad slicing: the shadow ran the same padded inputs, so
            # the comparable shapes line up exactly.
            _san_numerics.after_dispatch(self, shadow, out)
        if pad:
            out = jax.tree.map(lambda x: x[:b], out)
        if cap_pad:
            out = jax.tree.map(lambda x: x[:b_live], out)
        return out

    @staticmethod
    def _canon(dtype) -> np.dtype:
        return np.dtype(dtype)

    def stage(self, data, *, shard=None):
        """Start the H2D transfer of request leaves ahead of their dispatch.

        `jax.device_put` is asynchronous, so staging the *next* batch while
        the current dispatch is still executing overlaps its host-to-device
        copy with compute — with ``donate_data=True`` each staged slab is
        consumed by the dispatch that answers it, so a pipeline of queue
        depth 2 is exactly engine-level double buffering of donated inputs.
        With a mesh ``shard``, leaves are placed with the dispatch's batch
        sharding directly (the request axis should already be padded to a
        multiple of the axis — `launch.mesh.serving_batch_capacity`).
        """
        mesh, axis = self._normalize_shard(shard)
        if mesh is None:
            return tuple(jax.device_put(jnp.asarray(d)) for d in data)
        sharding = NamedSharding(mesh, P(axis))
        return tuple(jax.device_put(jnp.asarray(d), sharding) for d in data)

    # -- traced pipeline bodies (run once per executable) --------------------

    def _r0_impl(self, plan, data, *, dtype, use_kernel, assembly):
        return figaro_r0(plan, list(data), dtype=dtype, use_kernel=use_kernel,
                         assembly=assembly)

    def _r0_batched_impl(self, plan, data, *, dtype, use_kernel, assembly):
        return jax.vmap(lambda d: figaro_r0(
            plan, list(d), dtype=dtype, use_kernel=use_kernel,
            assembly=assembly))(data)

    def _qr_one(self, plan, data, *, dtype, method, leaf_rows, panel,
                use_kernel, assembly):
        r0 = figaro_r0(plan, list(data), dtype=dtype, use_kernel=use_kernel,
                       assembly=assembly)
        return postprocess_r0(r0, method=method, leaf_rows=leaf_rows,
                              panel=panel, use_kernel=use_kernel)

    def _qr_impl(self, plan, data, *, dtype, method, leaf_rows, panel,
                 use_kernel, assembly):
        return self._qr_one(plan, data, dtype=dtype, method=method,
                            leaf_rows=leaf_rows, panel=panel,
                            use_kernel=use_kernel, assembly=assembly)

    def _qr_batched_impl(self, plan, data, *, dtype, method, leaf_rows, panel,
                         use_kernel, assembly):
        return jax.vmap(lambda d: self._qr_one(
            plan, d, dtype=dtype, method=method, leaf_rows=leaf_rows,
            panel=panel, use_kernel=use_kernel, assembly=assembly))(data)

    def _svd_one(self, plan, data, *, dtype, method, leaf_rows, panel,
                 use_kernel, assembly):
        r = self._qr_one(plan, data, dtype=dtype, method=method,
                         leaf_rows=leaf_rows, panel=panel,
                         use_kernel=use_kernel, assembly=assembly)
        _, s, vt = jnp.linalg.svd(r)
        return s, vt

    def _svd_impl(self, plan, data, *, dtype, method, leaf_rows, panel,
                  use_kernel, assembly):
        return self._svd_one(plan, data, dtype=dtype, method=method,
                             leaf_rows=leaf_rows, panel=panel,
                             use_kernel=use_kernel, assembly=assembly)

    def _svd_batched_impl(self, plan, data, *, dtype, method, leaf_rows,
                          panel, use_kernel, assembly):
        return jax.vmap(lambda d: self._svd_one(
            plan, d, dtype=dtype, method=method, leaf_rows=leaf_rows,
            panel=panel, use_kernel=use_kernel, assembly=assembly))(data)

    def _pca_one(self, plan, data, *, k, center, dtype, method, leaf_rows,
                 panel, use_kernel, assembly):
        r = self._qr_one(plan, data, dtype=dtype, method=method,
                         leaf_rows=leaf_rows, panel=panel,
                         use_kernel=use_kernel, assembly=assembly)
        sums, total = _column_moments(plan, data, dtype)
        mean = sums / total
        gram = r.T @ r
        if center:
            gram = gram - total * jnp.outer(mean, mean)
        cov = gram / jnp.maximum(total - 1.0, 1.0)
        evals, evecs = jnp.linalg.eigh(cov)  # ascending
        # The centered-Gram subtraction can leave tiny negative eigenvalues
        # (a variance); clamp before the top-k select so near-constant
        # columns report 0, not -1e-17.
        evals = jnp.maximum(evals, jnp.zeros((), evals.dtype))
        order = jnp.argsort(-evals)[:k]
        return PCAResult(components=evecs[:, order].T,
                         explained_variance=evals[order],
                         mean=mean, num_rows=total)

    def _pca_impl(self, plan, data, *, k, center, dtype, method, leaf_rows,
                  panel, use_kernel, assembly):
        return self._pca_one(plan, data, k=k, center=center, dtype=dtype,
                             method=method, leaf_rows=leaf_rows, panel=panel,
                             use_kernel=use_kernel, assembly=assembly)

    def _pca_batched_impl(self, plan, data, *, k, center, dtype, method,
                          leaf_rows, panel, use_kernel, assembly):
        return jax.vmap(lambda d: self._pca_one(
            plan, d, k=k, center=center, dtype=dtype, method=method,
            leaf_rows=leaf_rows, panel=panel, use_kernel=use_kernel,
            assembly=assembly))(data)

    def _least_squares_one(self, plan, data, *, label_col, ridge, dtype,
                           method, leaf_rows, panel, use_kernel, assembly):
        r = self._qr_one(plan, data, dtype=dtype, method=method,
                         leaf_rows=leaf_rows, panel=panel,
                         use_kernel=use_kernel, assembly=assembly)
        n = plan.spec.num_cols
        feat = jnp.array([j for j in range(n) if j != label_col])
        # Permute label last, re-triangularize the permuted R (cheap: N×N).
        perm = jnp.concatenate([feat, jnp.array([label_col])])
        rp = r[:, perm]
        rr = jnp.linalg.qr(rp, mode="r")[:n]
        r_ff = rr[: n - 1, : n - 1]
        r_fl = rr[: n - 1, n - 1]
        if ridge:
            g = r_ff.T @ r_ff + ridge * jnp.eye(n - 1, dtype=dtype)
            beta = jnp.linalg.solve(g, r_ff.T @ r_fl)
            # The ridge solution does not zero the projected residual, so
            # ‖Aβ − y‖ keeps both terms: ‖r_ff·β − r_fl‖² + rr[n−1,n−1]².
            resid = jnp.sqrt(jnp.sum(jnp.square(r_ff @ beta - r_fl))
                             + jnp.square(rr[n - 1, n - 1]))
        else:
            beta = jax.scipy.linalg.solve_triangular(r_ff, r_fl, lower=False)
            resid = jnp.abs(rr[n - 1, n - 1])
        return beta, resid

    def _least_squares_impl(self, plan, data, *, label_col, ridge, dtype,
                            method, leaf_rows, panel, use_kernel, assembly):
        return self._least_squares_one(
            plan, data, label_col=label_col, ridge=ridge, dtype=dtype,
            method=method, leaf_rows=leaf_rows, panel=panel,
            use_kernel=use_kernel, assembly=assembly)

    def _least_squares_batched_impl(self, plan, data, *, label_col, ridge,
                                    dtype, method, leaf_rows, panel,
                                    use_kernel, assembly):
        return jax.vmap(lambda d: self._least_squares_one(
            plan, d, label_col=label_col, ridge=ridge, dtype=dtype,
            method=method, leaf_rows=leaf_rows, panel=panel,
            use_kernel=use_kernel, assembly=assembly))(data)

    # -- public API ----------------------------------------------------------

    def r0(self, plan: FigaroPlan, data=None, *, batched: bool = False,
           shard=None, bucket: bool = False, batch_capacity: int | None = None,
           dtype=jnp.float32, use_kernel: bool = False,
           assembly: str = "padded") -> jnp.ndarray:
        """R₀ of Algorithm 2; ``batched`` expects [B, m_i, n_i] data leaves.

        ``shard`` (a `Mesh` or ``(mesh, axis)``; requires ``batched=True``)
        splits the batch axis over the mesh — one executable per
        (plan signature, mesh signature) answers the global batch.

        ``bucket=True`` pads the plan (and data rows) to its power-of-two
        capacities first, so near-miss plan shapes share one executable; R₀
        then carries extra all-zero rows at the capacity layout. Long-lived
        callers should hold a `plan_cache.build_capacity_plan` plan instead
        (same executables, no per-dispatch host padding).

        ``batch_capacity`` (requires ``batched=True``) pads a partial request
        batch up to the given bucket (repeating the trailing request; the pad
        is sliced off the result), so the executable cache tracks batch
        *buckets*, not every live batch size — the micro-batching serving
        queue (`train.async_serve`) picks its buckets this way.

        ``use_kernel`` routes each node through the fused Pallas pass
        (`kernels/node_fused`); ``assembly`` ("padded" | "band") picks the R₀
        materialization (see `core.figaro`). Both are static options — part
        of the executable cache key.
        """
        return self._dispatch("r0_batched" if batched else "r0", plan, data,
                              shard=shard, bucket=bucket,
                              batch_capacity=batch_capacity,
                              dtype=self._canon(dtype),
                              use_kernel=use_kernel, assembly=assembly)

    def qr(self, plan: FigaroPlan, data=None, *, batched: bool = False,
           shard=None, bucket: bool = False, batch_capacity: int | None = None,
           dtype=jnp.float32, method: str = "tsqr", leaf_rows: int = 256,
           panel: int = 32, use_kernel: bool = False,
           assembly: str = "padded") -> jnp.ndarray:
        """Upper-triangular R of the join's QR ([B, N, N] when batched)."""
        return self._dispatch(
            "qr_batched" if batched else "qr", plan, data, shard=shard,
            bucket=bucket, batch_capacity=batch_capacity,
            dtype=self._canon(dtype), method=method,
            leaf_rows=leaf_rows, panel=panel, use_kernel=use_kernel,
            assembly=assembly)

    def svd(self, plan: FigaroPlan, data=None, *, batched: bool = False,
            shard=None, bucket: bool = False,
            batch_capacity: int | None = None, dtype=jnp.float64,
            method: str = "tsqr", leaf_rows: int = 256, panel: int = 32,
            use_kernel: bool = False, assembly: str = "padded"):
        """Singular values + right-singular vectors of the join matrix."""
        return self._dispatch(
            "svd_batched" if batched else "svd", plan, data, shard=shard,
            bucket=bucket, batch_capacity=batch_capacity,
            dtype=self._canon(dtype), method=method,
            leaf_rows=leaf_rows, panel=panel, use_kernel=use_kernel,
            assembly=assembly)

    def pca(self, plan: FigaroPlan, data=None, *, batched: bool = False,
            shard=None, bucket: bool = False,
            batch_capacity: int | None = None, k: int | None = None,
            center: bool = True, dtype=jnp.float64, method: str = "tsqr",
            leaf_rows: int = 256, panel: int = 32,
            use_kernel: bool = False,
            assembly: str = "padded") -> PCAResult:
        """PCA of the join matrix from R (+ factorized means when centering)."""
        n = plan.spec.num_cols
        k = n if k is None else min(k, n)
        return self._dispatch(
            "pca_batched" if batched else "pca", plan, data, shard=shard,
            bucket=bucket, batch_capacity=batch_capacity, k=k, center=center,
            dtype=self._canon(dtype),
            method=method, leaf_rows=leaf_rows, panel=panel,
            use_kernel=use_kernel, assembly=assembly)

    def least_squares(self, plan: FigaroPlan, label_col: int, data=None, *,
                      batched: bool = False, shard=None, bucket: bool = False,
                      batch_capacity: int | None = None,
                      ridge: float = 0.0, dtype=jnp.float64,
                      method: str = "tsqr", leaf_rows: int = 256,
                      panel: int = 32, use_kernel: bool = False,
                      assembly: str = "padded"):
        """argmin_β ‖A[:, feats]·β − A[:, label]‖² over the unmaterialized join."""
        return self._dispatch(
            "least_squares_batched" if batched else "least_squares", plan,
            data, shard=shard, bucket=bucket, batch_capacity=batch_capacity,
            label_col=label_col,
            ridge=float(ridge), dtype=self._canon(dtype), method=method,
            leaf_rows=leaf_rows, panel=panel, use_kernel=use_kernel,
            assembly=assembly)


def _plan_arg_error(arg_name: str, value) -> str:
    """A clear TypeError message for a non-plan handed to a plan argument.

    Without this, a `Database` or a raw ``{name: array}`` table dict sinks
    into pytree flattening and surfaces as a deep, unrelated error."""
    from .relation import Database

    got = type(value).__name__
    if isinstance(value, Database):
        hint = ("a Database is not executable yet — pick a join tree first: "
                "JoinTree.from_edges(db, root, edges), or use the façade: "
                "repro.figaro.Session().ingest(db).join(root, edges)")
    elif isinstance(value, dict) or (
            isinstance(value, (list, tuple)) and value
            and isinstance(value[0], np.ndarray)):
        hint = ("raw tables must be ingested first: "
                "repro.figaro.Session().ingest(tables).join(root, edges), or "
                "Database.from_arrays(tables) + JoinTree.from_edges")
    else:
        hint = ("build one with join_tree.build_plan(tree) or "
                "plan_cache.build_capacity_plan(tree)")
    return (f"argument {arg_name!r} must be a JoinTree or FigaroPlan, "
            f"got {got}: {hint}")


_DEFAULT_ENGINE: FigaroEngine | None = None


def default_engine() -> FigaroEngine:
    """Process-wide shared engine (non-donating, safe for repeated dispatch of
    the same buffers) — the cross-call executable cache behind the module-level
    `qr`/`svd` convenience APIs and `partitioned_figaro_qr`."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = FigaroEngine(donate_data=False)
    return _DEFAULT_ENGINE


def plan_for(tree_or_plan: JoinTree | FigaroPlan) -> FigaroPlan:
    """Accept either a `JoinTree` (compiled here) or a ready `FigaroPlan`.

    Anything else — a `Database`, a raw table dict — raises a `TypeError`
    naming the offending argument instead of failing deep inside pytree
    flattening."""
    if isinstance(tree_or_plan, FigaroPlan):
        return tree_or_plan
    if isinstance(tree_or_plan, JoinTree):
        return build_plan(tree_or_plan)
    raise TypeError(_plan_arg_error("tree_or_plan", tree_or_plan))
