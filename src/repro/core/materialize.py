"""Join materialization oracle (numpy) — for tests/baselines only.

FiGaRo's whole point is to *avoid* this. Tests and the `*-on-materialized-join`
baselines use it to (a) cross-check `R₀ᵀR₀ == AᵀA`, (b) feed the classical
Givens/Householder algorithms, (c) brute-force the count aggregates.

Column order of the produced matrix matches the plan's preorder layout, so
``figaro_r0(plan)`` and ``qr(materialize(tree))`` decompose the same matrix.
"""

from __future__ import annotations

import numpy as np

from .join_tree import JoinTree

__all__ = ["materialize_join", "join_output_rows"]


def _mix(keys: dict[str, np.ndarray], attrs: tuple[str, ...],
         cards: dict[str, int], n: int) -> np.ndarray:
    code = np.zeros(n, dtype=np.int64)
    for a in attrs:
        code = code * cards[a] + keys[a]
    return code


def _inner_join(lk, ld, rk, rd, attrs, cards):
    n_l = ld.shape[0]
    n_r = rd.shape[0]
    lcode = _mix(lk, attrs, cards, n_l)
    rcode = _mix(rk, attrs, cards, n_r)
    order = np.argsort(rcode, kind="stable")
    rcode_s = rcode[order]
    starts = np.searchsorted(rcode_s, lcode, side="left")
    ends = np.searchsorted(rcode_s, lcode, side="right")
    counts = ends - starts
    total = int(counts.sum())
    l_idx = np.repeat(np.arange(n_l), counts)
    offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    r_idx = order[np.repeat(starts, counts) + offs]
    keys = {a: lk[a][l_idx] for a in lk}
    for a in rk:
        if a not in keys:
            keys[a] = rk[a][r_idx]
    data = np.concatenate([ld[l_idx], rd[r_idx]], axis=1)
    return keys, data


def materialize_join(tree: JoinTree) -> np.ndarray:
    """The data matrix ``A[:, Ȳ]`` of the natural join (preorder column layout)."""
    db = tree.db
    cards: dict[str, int] = {}
    for rel in db:
        for a in rel.key_attrs:
            c = int(rel.key_col(a).max()) + 1 if rel.num_rows else 1
            cards[a] = max(cards.get(a, 1), c)

    def rec(name: str):
        rel = db[name]
        keys = {a: rel.key_col(a) for a in rel.key_attrs}
        data = np.asarray(rel.data, dtype=np.float64)
        for ch in tree.children[name]:
            ck, cd = rec(ch)
            shared = tree.shared_attrs(name, ch)
            keys, data = _inner_join(keys, data, ck, cd, shared, cards)
        return keys, data

    _, data = rec(tree.root)
    return data


def join_output_rows(tree: JoinTree) -> int:
    return materialize_join(tree).shape[0]
