"""Plan lifecycle: bucketed signatures + incremental (append-only) refreshes.

FiGaRo's cost model tracks the *database*, not the join — but a compiled
engine only delivers that if data refreshes and near-miss tenant shapes do not
trigger fresh XLA compiles. This module bounds the compile count two ways:

  * `bucket_spec(spec)` rounds every node's static sizes ``(m, K, P)`` up to
    powers of two, so all plans whose live sizes fall in the same buckets
    share one `PlanSpec` — and therefore (plans being spec-keyed pytrees) one
    compiled executable per pipeline kind.
  * `pad_plan(plan, cap_spec)` embeds an exact plan into such a capacity spec:
    index arrays are padded to capacity shapes and a **live-row mask** rides
    along as a pytree leaf. Appending rows then only changes leaf *values*;
    as long as the bucketed signature is unchanged the dispatch crosses
    `jax.jit` with zero retraces.

Capacity vs live size (the contract every layer observes):

  * **capacity** is static: `NodeSpec.m/K/P`, the R₀ row layout, `r0_rows` —
    all bucketed, all part of the treedef, all baked into the executable;
  * **live size** is dynamic: the row mask and the zeroed tail of
    ``group_count`` (dead group slots have count 0). `figaro.figaro_r0` uses
    the mask as the Givens weight vector (dead rows rotate with weight 0 and
    emit zero R₀ rows) and `counts.compute_counts` resolves the resulting
    0/0 aggregates to 0, so a capacity plan computes exactly what the
    underlying exact plan computes, padded with zero rows.

Padding layout invariants (relied on by the masked math):

  * dead rows sit at the tail of each node's row range and are appended to
    the **last live group** with continuing ``pos_in_group`` — never a
    segment start, so segmented prefix sums keep positive denominators;
  * dead group slots (``[K_live, K_cap)``) hold zero rows (``group_count
    0``), attach to the last live pgroup with continuing ``pos_in_pgroup``,
    and look up the child's last live P-slot — harmless, because their
    ``theta``/``full`` counts are identically 0;
  * dead pgroup slots hold zero groups, so carried scales ``√Φ↓`` vanish.

`build_capacity_plan(tree)` produces a refreshable plan (it keeps the source
`JoinTree` on the plan object, host-side only); `refresh_plan(plan, rows)`
appends rows, re-ingests, and re-pads — into the *same* capacities when the
new live sizes still fit (zero retraces), or grown buckets when they don't
(one retrace, reported by the changed spec).
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Mapping

import numpy as np

from repro.sanitizer.locks import san_rlock
from repro.sanitizer.races import shared_state

from .join_tree import (FigaroPlan, JoinTree, NodeIndex, PlanSpec, build_plan)
from .relation import Database, Relation

__all__ = [
    "next_pow2",
    "bucket_spec",
    "pad_plan",
    "pad_data",
    "build_capacity_plan",
    "refresh_plan",
    "spec_fits",
    "PlanHolder",
]


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def bucket_spec(spec: PlanSpec, *, headroom: int = 0) -> PlanSpec:
    """Round every node's ``(m, K, P)`` up to powers of two and recompute the
    R₀ row layout for the bucketed sizes. Column layout is untouched (the
    feature schema is part of the tenant's signature, not its load).

    ``headroom`` rows are added to every node's live row count before
    bucketing, guaranteeing streaming appends of up to that many rows stay
    inside the capacity even when the live size sits exactly on a power of
    two (where ``next_pow2`` alone would leave zero slack)."""
    nodes = [dataclasses.replace(sp, m=next_pow2(sp.m + headroom),
                                 K=next_pow2(sp.K), P=next_pow2(sp.P))
             for sp in spec.nodes]
    row_acc = 0  # emission order: reversed preorder, m tail rows then K
    for i in reversed(spec.preorder):
        nodes[i] = dataclasses.replace(nodes[i], tail_row0=row_acc,
                                       out_row0=row_acc + nodes[i].m)
        row_acc += nodes[i].m + nodes[i].K
    return dataclasses.replace(
        spec, nodes=tuple(nodes),
        total_rows=sum(sp.m for sp in nodes), r0_rows=row_acc)


def spec_fits(live: PlanSpec, cap: PlanSpec) -> bool:
    """True iff an exact plan with spec ``live`` embeds into capacities
    ``cap``: same topology/schema, per-node sizes within capacity."""
    if (live.names != cap.names or live.preorder != cap.preorder
            or live.root != cap.root or live.num_cols != cap.num_cols):
        return False
    for sp, cp in zip(live.nodes, cap.nodes):
        if (sp.name != cp.name or sp.parent != cp.parent
                or sp.children != cp.children or sp.n != cp.n
                or sp.col_start != cp.col_start
                or sp.subtree_start != cp.subtree_start
                or sp.subtree_width != cp.subtree_width
                or sp.child_rel_col0 != cp.child_rel_col0):
            return False
        if sp.m > cp.m or sp.K > cp.K or sp.P > cp.P:
            return False
    return True


def _pad_tail(arr: np.ndarray, size: int, fill) -> np.ndarray:
    """Pad a 1-D int index array up to ``size`` with a constant fill value."""
    arr = np.asarray(arr)
    pad = size - arr.shape[0]
    if pad == 0:
        return arr
    return np.concatenate([arr, np.full(pad, fill, dtype=arr.dtype)])


def pad_data(data, spec: PlanSpec):
    """Zero-pad per-node data leaves ([..., m_i, n_i]) on the row axis up to
    the capacities of ``spec``. Leaves already at capacity pass through."""
    out = []
    for sp, d in zip(spec.nodes, data):
        d = np.asarray(d)
        if d.shape[-2] > sp.m or d.shape[-1] != sp.n:
            raise ValueError(
                f"{sp.name}: data shape {d.shape} does not fit capacity "
                f"({sp.m}, {sp.n})")
        pad = sp.m - d.shape[-2]
        if pad:
            widths = [(0, 0)] * (d.ndim - 2) + [(0, pad), (0, 0)]
            d = np.pad(d, widths)
        out.append(d)
    return tuple(out)


def _pad_index(ix: NodeIndex, sp_live, sp_cap,
               child_live_p: Mapping[int, int]) -> NodeIndex:
    """Embed one node's exact index arrays into capacity shapes (see module
    docstring for the layout invariants this establishes)."""
    m, k, p = sp_live.m, sp_live.K, sp_live.P
    mc, kc, pc = sp_cap.m, sp_cap.K, sp_cap.P
    last_group = k - 1
    last_pgroup = p - 1
    # Dead rows join the last live group, continuing its positions.
    row_to_group = _pad_tail(ix.row_to_group, mc, last_group)
    pos_in_group = _pad_tail(ix.pos_in_group, mc, 0)
    if mc > m:
        pos_in_group[m:] = ix.group_count[last_group] + np.arange(
            mc - m, dtype=pos_in_group.dtype)
    row_seg_start = _pad_tail(ix.row_seg_start, mc,
                              ix.group_start[last_group])
    # Dead group slots: zero rows, attached to the last live pgroup.
    group_start = _pad_tail(ix.group_start, kc, m)
    group_count = _pad_tail(ix.group_count, kc, 0)
    group_to_pgroup = _pad_tail(ix.group_to_pgroup, kc, last_pgroup)
    group_seg_start = _pad_tail(ix.group_seg_start, kc,
                                ix.group_seg_start[last_group])
    pos_in_pgroup = _pad_tail(ix.pos_in_pgroup, kc, 0)
    if kc > k:
        pos_in_pgroup[k:] = ix.pgroup_count[last_pgroup] + np.arange(
            kc - k, dtype=pos_in_pgroup.dtype)
    pgroup_count = _pad_tail(ix.pgroup_count, pc, 0)
    child_lookup = {}
    for ch, lookup in ix.child_lookup.items():
        # Dead parent groups point at the child's last LIVE P-slot; their
        # `full` count is 0, so the gather/segment-sum they feed is inert.
        child_lookup[ch] = _pad_tail(lookup, kc, child_live_p[ch] - 1)
    mask = np.zeros(mc, dtype=np.float64)
    mask[:m] = 1.0
    return NodeIndex(
        row_to_group=row_to_group, row_seg_start=row_seg_start,
        pos_in_group=pos_in_group, group_start=group_start,
        group_count=group_count, group_to_pgroup=group_to_pgroup,
        group_seg_start=group_seg_start, pos_in_pgroup=pos_in_pgroup,
        pgroup_count=pgroup_count, child_lookup=child_lookup, row_mask=mask)


def pad_plan(plan: FigaroPlan, cap_spec: PlanSpec | None = None) -> FigaroPlan:
    """Embed an exact plan into a capacity spec (default: its own buckets).

    Returns a masked `FigaroPlan` whose treedef is ``cap_spec`` — every plan
    padded into the same capacities shares one executable per pipeline kind.
    """
    if any(ix.row_mask is not None for ix in plan.index):
        raise ValueError("pad_plan expects an exact plan "
                         "(refresh_plan re-pads from the source tree)")
    cap_spec = bucket_spec(plan.spec) if cap_spec is None else cap_spec
    if not spec_fits(plan.spec, cap_spec):
        raise ValueError("plan does not fit the requested capacity spec")
    live_p = {sp.idx: sp.P for sp in plan.spec.nodes}
    index = [
        _pad_index(ix, sp_live, sp_cap, live_p)
        for sp_live, sp_cap, ix in zip(plan.spec.nodes, cap_spec.nodes,
                                       plan.index)
    ]
    data = pad_data(plan.data, cap_spec) if plan.data else ()
    return FigaroPlan(spec=cap_spec, index=tuple(index), data=data)


def build_capacity_plan(tree: JoinTree, *, dtype=np.float64,
                        cap_spec: PlanSpec | None = None,
                        headroom: int = 0) -> FigaroPlan:
    """Ingest + pad in one step, keeping the source tree for refreshes.

    ``headroom`` reserves extra row capacity per node (see `bucket_spec`) so
    a known append rate cannot immediately overflow a bucket. The returned
    plan carries ``plan.source_tree`` (a host-side attribute, not a pytree
    leaf — it does not survive flatten/unflatten), which `refresh_plan` uses
    to re-ingest after appends.
    """
    exact = build_plan(tree, dtype=dtype)
    if cap_spec is None:
        cap_spec = bucket_spec(exact.spec, headroom=headroom)
    plan = pad_plan(exact, cap_spec)
    plan.source_tree = tree
    plan.capacity_headroom = headroom
    return plan


def _append_rows(rel: Relation, keys: Mapping[str, np.ndarray],
                 data: np.ndarray) -> Relation:
    data = np.atleast_2d(np.asarray(data, dtype=rel.data.dtype))
    if set(keys) != set(rel.key_attrs):
        raise ValueError(
            f"{rel.name}: appended keys {sorted(keys)} != relation key "
            f"attrs {sorted(rel.key_attrs)}")
    if rel.key_attrs:
        new_keys = np.stack(
            [np.asarray(keys[a], dtype=np.int64) for a in rel.key_attrs],
            axis=1)
    else:
        new_keys = np.zeros((data.shape[0], 0), dtype=np.int64)
    return Relation(rel.name, rel.key_attrs, rel.data_attrs,
                    np.concatenate([rel.keys, new_keys]),
                    np.concatenate([rel.data, data]))


def refresh_plan(
    plan: FigaroPlan,
    new_rows_per_node: Mapping[str, tuple[Mapping[str, np.ndarray],
                                          np.ndarray]],
) -> FigaroPlan:
    """Append-only data refresh: returns a new capacity plan over the grown
    database.

    ``new_rows_per_node`` maps relation name -> ``(key_columns, data_rows)``
    with ``key_columns`` a dict of integer-encoded key arrays (natural-join
    semantics, as at ingest) and ``data_rows`` a [rows, n_i] matrix. Appended
    rows must keep the database fully reduced (dangling keys raise, exactly
    as at `build_plan` time).

    If the refreshed live sizes still fit the plan's capacities, the result
    reuses the **same** `PlanSpec` — same treedef, same executable, zero
    retraces. Otherwise the capacities grow to the new buckets (compare
    ``out.spec == plan.spec`` to detect the one-off recompile).
    """
    tree = getattr(plan, "source_tree", None)
    if tree is None:
        raise ValueError(
            "refresh_plan needs a plan from build_capacity_plan / a previous "
            "refresh_plan (it keeps the source JoinTree for re-ingest)")
    rels = dict(tree.db.relations)
    for name, (keys, data) in new_rows_per_node.items():
        if name not in rels:
            raise KeyError(f"unknown relation {name!r}; have {sorted(rels)}")
        rels[name] = _append_rows(rels[name], keys, data)
    new_tree = JoinTree(Database(rels), dict(tree.parent))
    exact = build_plan(new_tree, dtype=plan.data[0].dtype if plan.data
                       else np.float64)
    headroom = getattr(plan, "capacity_headroom", 0)
    cap = plan.spec if spec_fits(exact.spec, plan.spec) \
        else bucket_spec(exact.spec, headroom=headroom)
    out = pad_plan(exact, cap)
    out.source_tree = new_tree
    out.capacity_headroom = headroom
    return out


@shared_state({"_plan": "_lock", "_servers": "_lock",
               "appends": "_lock", "regrows": "_lock",
               "reroots": "_lock", "append_volume": "_lock"})
class PlanHolder:
    """Thread-safe owner of ONE current capacity plan.

    A `JoinDataset` and every server spawned from it (``ds.serve(...)``)
    share a single holder, so an append through *either* surface is visible
    to both — there is exactly one plan state per join, never a silent fork
    where ``server.append(...)`` leaves ``ds.plan`` / ``ds.stats()`` stale
    (or vice versa).

    ``refresh(rows_per_node)`` is the one mutation path: it first **drains**
    every attached server (in-flight and queued requests were validated and
    padded against the old capacities, so they must be answered before the
    plan can change), then applies `refresh_plan` under the holder's lock.
    The ``appends`` / ``regrows`` counters live here for the same reason the
    plan does — any surface that can append must see the same counts.

    ``on_regrow`` is an optional policy hook applied when a refresh
    overflows the current capacities: it receives the (bucket-regrown)
    refreshed plan and returns the plan to install — `repro.api` uses it to
    keep ``bucket=False`` datasets on exact capacities across regrows.

    The holder also records **per-relation append volume**
    (``append_volumes()``) — the raw signal the adaptive re-rooting policy
    (`repro.planner.replan.Replanner`) keys off — and exposes
    ``replace(plan)``, the drain-then-install path a re-root uses: in-flight
    and queued requests captured the old plan at submit time
    (`train.async_serve`), so draining first makes the orientation swap
    invisible to every outstanding future.
    """

    def __init__(self, plan: FigaroPlan | None = None, *,
                 on_regrow: Callable[[FigaroPlan], FigaroPlan] | None = None):
        # Lock first: the race detector resolves it while __init__ assigns
        # the state it guards.
        self._lock = san_rlock("plan_holder._lock")
        self._on_regrow = on_regrow
        self._plan = plan
        self._servers: weakref.WeakSet = weakref.WeakSet()
        self.appends = 0
        self.regrows = 0
        self.reroots = 0
        self.append_volume: dict[str, int] = {}

    @property
    def plan(self) -> FigaroPlan | None:
        with self._lock:
            return self._plan

    def set(self, plan: FigaroPlan) -> None:
        """Install a plan (the lazy first build); use `refresh` for appends."""
        with self._lock:
            self._plan = plan

    def attach(self, server) -> None:
        """Register a server (anything with ``flush()``) to drain before
        plan swaps. Held weakly — dropping the server detaches it.
        WeakSet mutation is not atomic (it prunes dead refs internally), so
        registration takes the holder lock like every other mutation."""
        with self._lock:
            self._servers.add(server)

    def drain(self) -> None:
        """Block until every attached server has answered its queue.

        The snapshot is taken under the lock; the flushes run outside it —
        a server flush can dispatch and re-enter holder reads, and holding
        the lock across it would invert the holder/server lock order."""
        with self._lock:
            servers = list(self._servers)
        for server in servers:
            server.flush()

    def note_external_append(self, node: str | None = None,
                             rows: int = 0) -> None:
        """Count an append applied outside `refresh` (the pre-plan ingest
        path, where rows land in the source tables before the lazy first
        plan build)."""
        with self._lock:
            self.appends += 1
            if node is not None:
                self.append_volume[node] = \
                    self.append_volume.get(node, 0) + int(rows)

    def counters(self) -> tuple[int, int]:
        """(appends, regrows) read consistently under the holder lock."""
        with self._lock:
            return self.appends, self.regrows

    def reroot_count(self) -> int:
        with self._lock:
            return self.reroots

    def append_volumes(self) -> dict[str, int]:
        """Rows appended per relation since construction (both refresh and
        pre-plan appends) — the growth signal adaptive re-rooting consumes."""
        with self._lock:
            return dict(self.append_volume)

    def replace(self, plan: FigaroPlan) -> None:
        """Drain attached servers, then install a *structurally different*
        plan (adaptive re-root). Unlike `refresh`, the incoming plan may have
        a new topology/orientation; the drain guarantees every request
        submitted against the old plan is answered by it first, so the swap
        is invisible to in-flight futures."""
        self.drain()
        with self._lock:
            if self._plan is None:
                raise ValueError("PlanHolder has no plan yet — build one "
                                 "before replacing")
            self._plan = plan
            self.reroots += 1

    def refresh(self, new_rows_per_node) -> bool:
        """Drain attached servers, then append rows via `refresh_plan`.

        Returns True when the refresh stayed within the plan's capacities
        (same signature — the next dispatch is launch-only) and False when
        the capacities grew (one recompile on the next dispatch).
        """
        self.drain()
        with self._lock:
            if self._plan is None:
                raise ValueError("PlanHolder has no plan yet — build one "
                                 "before refreshing")
            new_plan = refresh_plan(self._plan, new_rows_per_node)
            in_capacity = new_plan.spec == self._plan.spec
            self.appends += 1
            for name, (_, data) in new_rows_per_node.items():
                rows = int(np.atleast_2d(np.asarray(data)).shape[0])
                self.append_volume[name] = \
                    self.append_volume.get(name, 0) + rows
            if not in_capacity:
                self.regrows += 1
                if self._on_regrow is not None:
                    new_plan = self._on_regrow(new_plan)
            self._plan = new_plan
        return in_capacity
