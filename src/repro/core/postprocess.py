"""Post-processing (paper §7): triangularize R₀ (M×N) → R (N×N).

The paper's THIN scheme — each thread Givens-reduces its share of rows, then a
parallel combine — is, in block form, exactly TSQR (tall-skinny QR with a
binary combine tree). Here:

  * `householder_qr_r`   — column-at-a-time Householder, pure JAX `fori_loop`
                           (the in-house leaf factorization; MKL-analog).
  * `blocked_qr_r`       — panel/WY blocked variant; the panel factorization
                           can be served by the Pallas `panel_qr` kernel.
  * `tsqr_r`             — row-blocked leaf QRs + log₂ pairwise combine
                           (THIN on TPU; the mesh version lives in
                           `core/distributed.py`).
  * `postprocess_r0`     — R₀ → upper-triangular R with non-negative diagonal.

All functions return only R (the paper never materializes Q either).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "householder_qr_r",
    "blocked_qr_r",
    "tsqr_r",
    "postprocess_r0",
    "normalize_sign",
]


def normalize_sign(r: jnp.ndarray) -> jnp.ndarray:
    """Flip row signs so diag(R) >= 0 (QR uniqueness normalization).

    Sign vector is built in ``r.dtype`` — a Python-float fill would promote
    low-precision inputs (bf16/f16 serving) and silently upcast the result.
    """
    r = jnp.asarray(r)
    s = jnp.sign(jnp.diagonal(r))
    s = jnp.where(s == 0, jnp.ones((), r.dtype), s).astype(r.dtype)
    return r * s[:, None]


def householder_qr_r(a: jnp.ndarray) -> jnp.ndarray:
    """R factor via Householder reflections; [m, n] -> [n, n] (m >= 1).

    Column-at-a-time `fori_loop`; O(mn²) flops, static shapes throughout.
    """
    m, n = a.shape
    dtype = a.dtype
    steps = min(m - 1, n)
    rows = jnp.arange(m)

    def body(k, a):
        col = jax.lax.dynamic_index_in_dim(a, k, axis=1, keepdims=False)
        x = jnp.where(rows >= k, col, jnp.zeros_like(col))
        sigma = jnp.linalg.norm(x)
        xk = x[k]
        # alpha = -sign(xk)*sigma with sign(0) := 1
        sgn = jnp.where(xk >= 0, jnp.ones((), dtype), -jnp.ones((), dtype))
        alpha = -sgn * sigma
        v = x - alpha * (rows == k).astype(dtype)
        vv = v @ v
        beta = jnp.where(vv > 0, 2.0 / jnp.where(vv > 0, vv, 1.0), 0.0)
        w = v @ a  # [n]
        return a - beta * v[:, None] * w[None, :]

    a = jax.lax.fori_loop(0, steps, body, a)
    r = jnp.triu(a[:n])
    if m < n:  # degenerate tall requirement; pad for a consistent [n, n]
        r = jnp.zeros((n, n), dtype).at[:m].set(jnp.triu(a)[:m])
    return r


def _apply_wy(a: jnp.ndarray, v: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Trailing update A ← Hₙ…H₁·A = (I − V·Tᵀ·Vᵀ)·A (compact WY on the MXU).

    With Q = H₁…Hₙ = I − V·T·Vᵀ (LAPACK forward convention), the QR trailing
    update applies Qᵀ, i.e. Tᵀ.
    """
    return a - v @ (t.T @ (v.T @ a))


def _panel_to_wy(v: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Compact-WY T from unit reflectors V (columns) and betas: forward recurrence."""
    nb = v.shape[1]
    # Derive the zero init from the inputs so it inherits their vma type
    # (shard_map-manual axes): fresh constants would be "unvarying" and the
    # fori_loop carry would type-mismatch.
    t = jnp.zeros((nb, nb), v.dtype) + 0.0 * beta[0]

    def body(j, t):
        col = -beta[j] * (t @ (v.T @ v[:, j]))
        col = jnp.where(jnp.arange(nb) < j, col, 0.0)
        t = t.at[:, j].set(col)
        return t.at[j, j].set(beta[j])

    return jax.lax.fori_loop(0, nb, body, t)


def householder_panel(a: jnp.ndarray):
    """Factor a panel: returns (V unit-lower reflectors [m, nb], beta [nb], R_panel [m, nb]).

    Pure-JAX reference; `repro.kernels.panel_qr` implements the same contract
    as a Pallas kernel (validated against this in tests).
    """
    m, nb = a.shape
    dtype = a.dtype
    rows = jnp.arange(m)
    vs = a * 0.0  # zeros that inherit `a`'s vma type (see _panel_to_wy note)
    betas = jnp.sum(a, axis=0)[:nb] * 0.0 if m >= 1 else jnp.zeros((nb,), dtype)

    def body(k, carry):
        a, vs, betas = carry
        col = jax.lax.dynamic_index_in_dim(a, k, axis=1, keepdims=False)
        x = jnp.where(rows >= k, col, jnp.zeros_like(col))
        sigma = jnp.linalg.norm(x)
        xk = x[k]
        sgn = jnp.where(xk >= 0, jnp.ones((), dtype), -jnp.ones((), dtype))
        alpha = -sgn * sigma
        v = x - alpha * (rows == k).astype(dtype)
        vk = v[k]
        safe = jnp.abs(vk) > 0
        v = jnp.where(safe, v / jnp.where(safe, vk, 1.0), v)  # unit diagonal
        vv = v @ v
        beta = jnp.where(vv > 0, 2.0 / jnp.where(vv > 0, vv, 1.0), 0.0)
        w = v @ a
        a = a - beta * v[:, None] * w[None, :]
        return a, vs.at[:, k].set(v), betas.at[k].set(beta)

    a, vs, betas = jax.lax.fori_loop(0, min(m, nb), body, (a, vs, betas))
    return vs, betas, a


def blocked_qr_r(a: jnp.ndarray, panel: int = 32, *,
                 use_kernel: bool = False) -> jnp.ndarray:
    """Blocked Householder QR (panel + compact-WY trailing update) -> R [n, n]."""
    m, n = a.shape
    if m < n:
        a = jnp.concatenate([a, jnp.zeros((n - m, n), a.dtype)], axis=0)
        m = n
    pos = 0
    while pos < n:
        nb = min(panel, n - pos)
        block = a[pos:, pos:pos + nb]
        if use_kernel:
            from repro.kernels.panel_qr import ops as pq_ops
            v, beta, rp = pq_ops.panel_qr(block)
        else:
            v, beta, rp = householder_panel(block)
        t = _panel_to_wy(v, beta)
        a = a.at[pos:, pos:pos + nb].set(rp)
        if pos + nb < n:
            trailing = _apply_wy(a[pos:, pos + nb:], v, t)
            a = a.at[pos:, pos + nb:].set(trailing)
        pos += nb
    return jnp.triu(a[:n])


def tsqr_r(a: jnp.ndarray, leaf_rows: int = 256,
           leaf_qr=householder_qr_r) -> jnp.ndarray:
    """TSQR: row-block leaf QRs, then pairwise combines — THIN (§7) in block form.

    [m, n] -> R [n, n]. Rows are zero-padded to a full grid; zero rows do not
    change R.
    """
    m, n = a.shape
    leaf_rows = max(leaf_rows, n)
    blocks = max(1, -(-m // leaf_rows))
    pad = blocks * leaf_rows - m
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad, n), a.dtype)], axis=0)
    rs = jax.vmap(leaf_qr)(a.reshape(blocks, leaf_rows, n))  # [B, n, n]
    while rs.shape[0] > 1:
        b = rs.shape[0]
        if b % 2:
            rs = jnp.concatenate([rs, jnp.zeros((1, n, n), a.dtype)], axis=0)
            b += 1
        stacked = rs.reshape(b // 2, 2 * n, n)
        rs = jax.vmap(leaf_qr)(stacked)
    return rs[0]


def postprocess_r0(r0: jnp.ndarray, *, method: str = "tsqr",
                   leaf_rows: int = 256, panel: int = 32,
                   use_kernel: bool = False) -> jnp.ndarray:
    """R₀ (M×N, almost upper-triangular) → R (N×N, diag ≥ 0)."""
    if method == "tsqr":
        leaf = functools.partial(blocked_qr_r, panel=panel, use_kernel=use_kernel) \
            if use_kernel else householder_qr_r
        r = tsqr_r(r0, leaf_rows=leaf_rows, leaf_qr=leaf)
    elif method == "householder":
        r = householder_qr_r(r0)
    elif method == "blocked":
        r = blocked_qr_r(r0, panel=panel, use_kernel=use_kernel)
    elif method == "lapack":  # XLA's native QR (the openblas/MKL analog)
        r = jnp.linalg.qr(r0, mode="r")
        n = r0.shape[1]
        r = r[:n]
    else:
        raise ValueError(f"unknown postprocess method {method!r}")
    return normalize_sign(r)
