"""FiGaRo (paper §6, Algorithm 2): pushing Givens rotations past the join.

Bottom-up over the join tree; per node:

  HEADS_AND_TAILS            per-join-key head/tail of the node's data columns;
                             tails scaled by √Φ° go to the output, heads into
                             the carried `Data` matrix (one row per key X̄_i).
  PROCESS_AND_JOIN_CHILDREN  gather children's carried heads through the key
                             lookup, apply the cross-subtree scale products
                             (lines 21–26 of Algorithm 2).
  PROJECT_AWAY_JOIN_ATTRS    generalized head/tail over `Data` weighted by the
                             carried scales; generalized tails scaled by √Φ↑ go
                             to the output, heads (one row per X̄_p) are carried
                             to the parent with scales √Φ↓.

The result ``R₀`` is almost upper-triangular with at most M non-zero rows and
satisfies ``A[:, Ȳ] = Q·[R₀; 0]`` for orthogonal Q (Theorem 6.1) — equivalently
``R₀ᵀR₀ == AᵀA``, the invariant the tests enforce.

All row/segment bookkeeping is static (from the `FigaroPlan`), so this function
jits; every node's transform is independent per key block, which is exactly the
paper's parallelism — on TPU it vectorizes instead of threading.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .counts import compute_counts
from .heads_tails import segmented_head_tail
from .join_tree import FigaroPlan

__all__ = ["figaro_r0", "figaro_r0_fn"]


def figaro_r0(
    plan: FigaroPlan,
    data: Sequence[jnp.ndarray] | None = None,
    *,
    dtype=jnp.float32,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Run Algorithm 2; returns R₀ with static shape [plan.r0_rows, plan.num_cols].

    ``data[i]`` overrides node i's data matrix (same row order as the plan) —
    used for jit arguments and for propagating gradients through FiGaRo.
    """
    nodes = plan.nodes
    if data is None:
        data = [jnp.asarray(nd.data, dtype=dtype) for nd in nodes]
    else:
        data = [jnp.asarray(d, dtype=dtype) for d in data]
    counts = compute_counts(plan, dtype=dtype)

    # Carried state per node (filled children-first).
    carried_data: dict[int, jnp.ndarray] = {}
    carried_scales: dict[int, jnp.ndarray] = {}
    out_blocks: list[tuple[int, int, jnp.ndarray]] = []  # (row0, col0, block)
    row_acc = 0

    def emit(col0: int, block: jnp.ndarray) -> None:
        nonlocal row_acc
        out_blocks.append((row_acc, col0, block))
        row_acc += block.shape[0]

    for idx in reversed(plan.preorder):  # children strictly before parents
        nd = nodes[idx]
        cnt = counts[idx]
        x = data[idx]

        # --- HEADS_AND_TAILS (lines 11-16) --------------------------------
        ones = jnp.ones((nd.m,), dtype=dtype)
        heads, tails, _ = segmented_head_tail(
            x, ones, jnp.asarray(nd.row_to_group), jnp.asarray(nd.pos_in_group),
            nd.K, use_kernel=use_kernel)
        phi_circ_row = cnt["phi_circ"][jnp.asarray(nd.row_to_group)]
        emit(nd.col_start, tails * jnp.sqrt(phi_circ_row)[:, None])

        scales = jnp.sqrt(cnt["rpk"])  # √|S_i^x̄|, one per key
        width = nd.subtree_width
        # --- PROCESS_AND_JOIN_CHILDREN (lines 17-26) ----------------------
        if nd.children:
            gathered = []  # (rel_col0, data [K, w_ch], scale [K])
            for ch in nd.children:
                lookup = jnp.asarray(nd.child_lookup[ch])
                gathered.append((
                    nodes[ch].subtree_start - nd.subtree_start,
                    carried_data.pop(ch)[lookup],
                    carried_scales.pop(ch)[lookup],
                ))
            prod_all = functools.reduce(jnp.multiply, [s for _, _, s in gathered])
            parts = [(0, heads * prod_all[:, None])]
            for j, (rel0, dj, sj) in enumerate(gathered):
                prod_except = functools.reduce(
                    jnp.multiply,
                    [s for k, (_, _, s) in enumerate(gathered) if k != j],
                    scales)  # scales = √rpk_i  (line 24's `scales[x̄_i]` factor)
                parts.append((rel0, dj * prod_except[:, None]))
            data_mat = jnp.zeros((nd.K, width), dtype=dtype)
            for rel0, block in parts:
                data_mat = data_mat.at[:, rel0:rel0 + block.shape[1]].set(block)
            scales = scales * prod_all  # line 26
        else:
            data_mat = heads  # width == n for a leaf

        # --- PROJECT_AWAY_JOIN_ATTRIBUTES (lines 27-34) / root (lines 7-8) -
        if nd.parent >= 0:
            gheads, gtails, _ = segmented_head_tail(
                data_mat, scales, jnp.asarray(nd.group_to_pgroup),
                jnp.asarray(nd.pos_in_pgroup), nd.P, use_kernel=use_kernel)
            phi_up_group = cnt["phi_up"][jnp.asarray(nd.group_to_pgroup)]
            emit(nd.subtree_start, gtails * jnp.sqrt(phi_up_group)[:, None])
            carried_data[idx] = gheads
            carried_scales[idx] = jnp.sqrt(cnt["phi_down"])
        else:
            emit(nd.subtree_start, data_mat)

    assert row_acc == plan.r0_rows, (row_acc, plan.r0_rows)
    r0 = jnp.zeros((plan.r0_rows, plan.num_cols), dtype=dtype)
    for row0, col0, block in out_blocks:
        r0 = r0.at[row0:row0 + block.shape[0],
                   col0:col0 + block.shape[1]].set(block)
    return r0


def figaro_r0_fn(plan: FigaroPlan, *, dtype=jnp.float32, use_kernel: bool = False):
    """A jittable closure ``data_list -> R₀`` for a fixed plan."""

    def fn(data: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return figaro_r0(plan, data, dtype=dtype, use_kernel=use_kernel)

    return jax.jit(fn)
