"""FiGaRo (paper §6, Algorithm 2): pushing Givens rotations past the join.

Bottom-up over the join tree; per node:

  HEADS_AND_TAILS            per-join-key head/tail of the node's data columns;
                             tails scaled by √Φ° go to the output, heads into
                             the carried `Data` matrix (one row per key X̄_i).
  PROCESS_AND_JOIN_CHILDREN  gather children's carried heads through the key
                             lookup, apply the cross-subtree scale products
                             (lines 21–26 of Algorithm 2).
  PROJECT_AWAY_JOIN_ATTRS    generalized head/tail over `Data` weighted by the
                             carried scales; generalized tails scaled by √Φ↑ go
                             to the output, heads (one row per X̄_p) are carried
                             to the parent with scales √Φ↓.

The result ``R₀`` is almost upper-triangular with at most M non-zero rows and
satisfies ``A[:, Ȳ] = Q·[R₀; 0]`` for orthogonal Q (Theorem 6.1) — equivalently
``R₀ᵀR₀ == AᵀA``, the invariant the tests enforce.

Execution model (post plan-split): the `FigaroPlan` is a pytree — its static
`PlanSpec` (shapes, topology, R₀ row/column layout) is treedef metadata and the
`NodeIndex` arrays are leaves — so this function jits **with the plan as an
argument**. One compiled executable serves every plan with the same signature;
`repro.core.engine.FigaroEngine` owns that cache and the batched (vmapped)
dispatch over a leading data axis.

Two hot-path variants, both cache-keyed by the engine:

  * ``use_kernel=True`` routes each node's two head/tail passes through the
    fused `kernels/node_fused` Pallas kernel: live-row masking, the weighted
    segmented scan, the tail formula, segment-start zeroing and √Φ emission
    scaling collapse into one HBM round-trip per pass, and the heads come
    from an O(m) gather of the kernel's inclusive sums instead of a second
    [m, n] reduction. ``use_kernel=False`` (default) is the XLA path —
    `segmented_head_tail` per pass — which stays the CPU fallback.

  * ``assembly`` picks how the emitted slabs become R₀. ``"padded"``
    (default) pads every slab to the full ``num_cols`` width and concatenates
    in emission order — every slab is written twice at full width. ``"band"``
    uses the band layout recorded in ``PlanSpec.bands``: each slab is
    slice-updated into a zeros [r0_rows, num_cols] buffer at its static
    (row0, col0) band, so beyond the single zero fill each slab moves only
    its own rowsᵢ·widthᵢ elements (`assembly_traffic` is the analytic model
    the benchmarks report). Both paths produce bit-identical layouts.

Capacity-padded plans (`repro.core.plan_cache`): when a node carries a
``row_mask``, the static shapes above are *capacities* and the mask is the
weight vector of every row-level Givens sequence — dead rows contribute
nothing (weight 0, data zeroed) and the corresponding R₀ rows are exactly
zero, so the same executable serves every live size up to capacity. The fused
kernel keeps this contract: the mask rides in as the kernel's ``data_scale``
so masked slab rows are exactly zero straight out of the kernel.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .counts import compute_counts
from .heads_tails import segmented_head_tail
from .join_tree import FigaroPlan, PlanSpec

__all__ = ["figaro_r0", "figaro_r0_batched", "figaro_r0_fn",
           "assembly_traffic"]

ASSEMBLIES = ("padded", "band")


def _pad_cols(block: jnp.ndarray, col0: int, num_cols: int) -> jnp.ndarray:
    """Embed ``block`` into columns [col0, col0+w) of an all-zero [rows, N] slab."""
    return jnp.pad(block, ((0, 0), (col0, num_cols - col0 - block.shape[1])))


def _assemble_padded(spec: PlanSpec, tail_slabs, out_slabs) -> jnp.ndarray:
    """Every slab padded to full width, concatenated in emission order."""
    slabs = []
    for idx in reversed(spec.preorder):
        sp = spec.nodes[idx]
        slabs.append(_pad_cols(tail_slabs[idx], sp.col_start, spec.num_cols))
        slabs.append(_pad_cols(out_slabs[idx], sp.subtree_start, spec.num_cols))
    return jnp.concatenate(slabs, axis=0)


def _assemble_band(spec: PlanSpec, tail_slabs, out_slabs) -> jnp.ndarray:
    """Band-wise R₀ assembly (bit-identical layout to the padded path).

    Every slab's destination is a *static* contiguous band recorded in
    ``PlanSpec.bands`` — rows [row0, row0+rows) × columns [col0, col0+width)
    of R₀, zero outside — so the slabs are slice-updated straight into one
    [r0_rows, num_cols] zeros buffer. Static-offset `dynamic_update_slice` is
    a contiguous block write XLA performs in place on the dead operand (NOT a
    row-index scatter, which the emission layout was designed to avoid), so
    the assembly writes each slab once at its own width: r0_rows·num_cols for
    the zero fill plus Σ rowsᵢ·widthᵢ for the bands, instead of the padded
    path's full-width copy of every slab followed by the full-width concat.
    """
    dtype = out_slabs[spec.root].dtype
    r0 = jnp.zeros((spec.r0_rows, spec.num_cols), dtype)
    for b in spec.bands:
        slab = tail_slabs[b.node] if b.kind == "tail" else out_slabs[b.node]
        r0 = jax.lax.dynamic_update_slice(r0, slab, (b.row0, b.col0))
    return r0


def assembly_traffic(spec: PlanSpec, *, assembly: str = "padded",
                     itemsize: int = 8) -> int:
    """Analytic bytes *written* by R₀ assembly.

    ``"padded"`` writes a full-width copy of every slab narrower than
    ``num_cols`` (the pad) plus the final [r0_rows, num_cols] concat;
    ``"band"`` writes the zero fill once plus each slab at its own band
    width. This is the attribution model `benchmarks/engine_bench.py` reports
    next to wall-clock, so a band-vs-padded win is explainable in bytes, not
    just observed in seconds.
    """
    full = spec.r0_rows * spec.num_cols
    if assembly == "padded":
        pad_writes = sum(b.rows * spec.num_cols for b in spec.bands
                         if b.width != spec.num_cols)
        return (pad_writes + full) * itemsize
    if assembly == "band":
        band_writes = sum(b.rows * b.width for b in spec.bands)
        return (full + band_writes) * itemsize
    raise ValueError(f"unknown assembly {assembly!r}; expected {ASSEMBLIES}")


def figaro_r0(
    plan: FigaroPlan,
    data: Sequence[jnp.ndarray] | None = None,
    *,
    dtype=jnp.float32,
    use_kernel: bool = False,
    assembly: str = "padded",
) -> jnp.ndarray:
    """Run Algorithm 2; returns R₀ with static shape [plan.r0_rows, plan.num_cols].

    ``data[i]`` overrides node i's data matrix (same row order as the plan) —
    used for jit arguments and for propagating gradients through FiGaRo.
    ``use_kernel`` routes the per-node passes through the fused Pallas kernel;
    ``assembly`` ("padded" | "band") picks the R₀ materialization (see module
    docstring) — the layouts are identical, only the traffic differs.
    """
    if assembly not in ASSEMBLIES:
        raise ValueError(f"unknown assembly {assembly!r}; expected {ASSEMBLIES}")
    if use_kernel:
        from repro.kernels.node_fused import ops as nf_ops
    spec = plan.spec
    if data is None:
        data = plan.data
    data = [jnp.asarray(d, dtype=dtype) for d in data]
    counts = compute_counts(plan, dtype=dtype)

    # Carried state per node (filled children-first); emitted slabs by node.
    carried_data: dict[int, jnp.ndarray] = {}
    carried_scales: dict[int, jnp.ndarray] = {}
    tail_slabs: dict[int, jnp.ndarray] = {}
    out_slabs: dict[int, jnp.ndarray] = {}

    for idx in reversed(spec.preorder):  # children strictly before parents
        sp = spec.nodes[idx]
        ix = plan.index[idx]
        cnt = counts[idx]
        x = data[idx]
        row_to_group = jnp.asarray(ix.row_to_group)
        pos_in_group = jnp.asarray(ix.pos_in_group)

        # --- HEADS_AND_TAILS (lines 11-16) --------------------------------
        # Capacity-padded plans weight the Givens sequences by the live-row
        # mask: dead rows carry weight 0 (they neither move the prefix sums
        # nor receive a tail) and their data is zeroed so the padded slab rows
        # of R₀ come out identically zero. Dead rows are never segment starts
        # (plan_cache appends them to the last live group), so every division
        # inside the head/tail formulas stays well-posed.
        mask = (jnp.asarray(ix.row_mask, dtype=dtype)
                if ix.row_mask is not None else None)
        weights = mask if mask is not None else jnp.ones((sp.m,), dtype=dtype)
        phi_circ_row = cnt["phi_circ"][row_to_group]
        if use_kernel:
            # Fused pass: masking (data_scale), scan, tail, √Φ° scaling and
            # start-row zeroing in one kernel; heads gathered from the
            # segment-final inclusive sums.
            last = jnp.asarray(ix.group_start) + jnp.asarray(ix.group_count) - 1
            live = jnp.asarray(ix.group_count) > 0
            slab, heads, _ = nf_ops.fused_node_pass(
                x, weights, pos_in_group, jnp.sqrt(phi_circ_row), last, live,
                data_scale=mask)
            tail_slabs[idx] = slab
        else:
            if mask is not None:
                x = x * mask[:, None]
            heads, tails, _ = segmented_head_tail(
                x, weights, row_to_group, pos_in_group, sp.K)
            tail_slabs[idx] = tails * jnp.sqrt(phi_circ_row)[:, None]

        scales = jnp.sqrt(cnt["rpk"])  # √|S_i^x̄|, one per key
        # --- PROCESS_AND_JOIN_CHILDREN (lines 17-26) ----------------------
        if sp.children:
            gathered = []  # (data [K, w_ch], scale [K]) in child (column) order
            for ch in sp.children:
                lookup = jnp.asarray(ix.child_lookup[ch])
                gathered.append((carried_data.pop(ch)[lookup],
                                 carried_scales.pop(ch)[lookup]))
            prod_all = functools.reduce(jnp.multiply, [s for _, s in gathered])
            blocks = [heads * prod_all[:, None]]
            for j, (dj, _) in enumerate(gathered):
                prod_except = functools.reduce(
                    jnp.multiply,
                    [s for k, (_, s) in enumerate(gathered) if k != j],
                    scales)  # scales = √rpk_i  (line 24's `scales[x̄_i]` factor)
                blocks.append(dj * prod_except[:, None])
            # Children subtrees are column-contiguous after the node's own
            # columns (validated at plan build) — Data is a pure concat.
            data_mat = jnp.concatenate(blocks, axis=1)
            scales = scales * prod_all  # line 26
        else:
            data_mat = heads  # width == n for a leaf

        # --- PROJECT_AWAY_JOIN_ATTRIBUTES (lines 27-34) / root (lines 7-8) -
        if sp.parent >= 0:
            group_to_pgroup = jnp.asarray(ix.group_to_pgroup)
            pos_in_pgroup = jnp.asarray(ix.pos_in_pgroup)
            phi_up_group = cnt["phi_up"][group_to_pgroup]
            if use_kernel:
                # Dead group slots continue the last live pgroup's segment
                # with scale 0, so the segment-final gather index may safely
                # land on them — the inclusive sums are unchanged past the
                # last live member.
                last = jax.ops.segment_max(
                    jnp.arange(sp.K), group_to_pgroup, num_segments=sp.P,
                    indices_are_sorted=True)
                live = jnp.asarray(ix.pgroup_count) > 0
                slab, gheads, _ = nf_ops.fused_node_pass(
                    data_mat, scales, pos_in_pgroup, jnp.sqrt(phi_up_group),
                    last, live)
                out_slabs[idx] = slab
            else:
                gheads, gtails, _ = segmented_head_tail(
                    data_mat, scales, group_to_pgroup, pos_in_pgroup, sp.P)
                out_slabs[idx] = gtails * jnp.sqrt(phi_up_group)[:, None]
            carried_data[idx] = gheads
            carried_scales[idx] = jnp.sqrt(cnt["phi_down"])
        else:
            out_slabs[idx] = data_mat

    if assembly == "band":
        r0 = _assemble_band(spec, tail_slabs, out_slabs)
    else:
        r0 = _assemble_padded(spec, tail_slabs, out_slabs)
    assert r0.shape == (spec.r0_rows, spec.num_cols), (r0.shape, spec.r0_rows)
    return r0


def figaro_r0_batched(
    plan: FigaroPlan,
    data_batch: Sequence[jnp.ndarray],
    *,
    dtype=jnp.float32,
    use_kernel: bool = False,
    assembly: str = "padded",
) -> jnp.ndarray:
    """Algorithm 2 vmapped over a leading batch axis of the data matrices.

    ``data_batch[i]`` is [B, m_i, n_i]; the plan (and therefore the counts,
    which depend only on the index structure) is held fixed across the batch —
    one join structure serving B feature-sets per dispatch. Returns
    [B, r0_rows, num_cols].
    """
    fn = functools.partial(figaro_r0, plan, dtype=dtype, use_kernel=use_kernel,
                           assembly=assembly)
    return jax.vmap(lambda d: fn(list(d)))(tuple(data_batch))


def figaro_r0_fn(plan: FigaroPlan, *, dtype=jnp.float32,
                 use_kernel: bool = False, assembly: str = "padded"):
    """A jittable closure ``data_list -> R₀`` for a fixed plan.

    Kept for the pre-engine call sites; new code should go through
    `repro.core.engine.FigaroEngine`, which passes the plan through jit as a
    pytree argument and shares one executable across same-signature plans.
    """

    def fn(data: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return figaro_r0(plan, data, dtype=dtype, use_kernel=use_kernel,
                         assembly=assembly)

    # Deliberately plan-closed: kept for the pre-engine call sites and
    # dispatch-minimal benchmarks (see docstring).
    return jax.jit(fn)  # figaro-lint: disable=FIG002 -- plan-closed by design
