"""FiGaRo (paper §6, Algorithm 2): pushing Givens rotations past the join.

Bottom-up over the join tree; per node:

  HEADS_AND_TAILS            per-join-key head/tail of the node's data columns;
                             tails scaled by √Φ° go to the output, heads into
                             the carried `Data` matrix (one row per key X̄_i).
  PROCESS_AND_JOIN_CHILDREN  gather children's carried heads through the key
                             lookup, apply the cross-subtree scale products
                             (lines 21–26 of Algorithm 2).
  PROJECT_AWAY_JOIN_ATTRS    generalized head/tail over `Data` weighted by the
                             carried scales; generalized tails scaled by √Φ↑ go
                             to the output, heads (one row per X̄_p) are carried
                             to the parent with scales √Φ↓.

The result ``R₀`` is almost upper-triangular with at most M non-zero rows and
satisfies ``A[:, Ȳ] = Q·[R₀; 0]`` for orthogonal Q (Theorem 6.1) — equivalently
``R₀ᵀR₀ == AᵀA``, the invariant the tests enforce.

Execution model (post plan-split): the `FigaroPlan` is a pytree — its static
`PlanSpec` (shapes, topology, R₀ row/column layout) is treedef metadata and the
`NodeIndex` arrays are leaves — so this function jits **with the plan as an
argument**. One compiled executable serves every plan with the same signature;
`repro.core.engine.FigaroEngine` owns that cache and the batched (vmapped)
dispatch over a leading data axis.

R₀ assembly is scatter-free: the (row, col) layout of every emitted block is
precomputed in `join_tree.build_plan` (``tail_row0``/``out_row0``), so R₀ is
the concatenation of column-padded row slabs in emission order — no
``zeros().at[].set`` scatters on the hot path, and the carried `Data` matrix of
an inner node is likewise a pure concatenation (its child blocks are
column-contiguous by the preorder layout).

Capacity-padded plans (`repro.core.plan_cache`): when a node carries a
``row_mask``, the static shapes above are *capacities* and the mask is the
weight vector of every row-level Givens sequence — dead rows contribute
nothing (weight 0, data zeroed) and the corresponding R₀ rows are exactly
zero, so the same executable serves every live size up to capacity.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .counts import compute_counts
from .heads_tails import segmented_head_tail
from .join_tree import FigaroPlan

__all__ = ["figaro_r0", "figaro_r0_batched", "figaro_r0_fn"]


def _pad_cols(block: jnp.ndarray, col0: int, num_cols: int) -> jnp.ndarray:
    """Embed ``block`` into columns [col0, col0+w) of an all-zero [rows, N] slab."""
    return jnp.pad(block, ((0, 0), (col0, num_cols - col0 - block.shape[1])))


def figaro_r0(
    plan: FigaroPlan,
    data: Sequence[jnp.ndarray] | None = None,
    *,
    dtype=jnp.float32,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Run Algorithm 2; returns R₀ with static shape [plan.r0_rows, plan.num_cols].

    ``data[i]`` overrides node i's data matrix (same row order as the plan) —
    used for jit arguments and for propagating gradients through FiGaRo.
    """
    spec = plan.spec
    if data is None:
        data = plan.data
    data = [jnp.asarray(d, dtype=dtype) for d in data]
    counts = compute_counts(plan, dtype=dtype)

    # Carried state per node (filled children-first).
    carried_data: dict[int, jnp.ndarray] = {}
    carried_scales: dict[int, jnp.ndarray] = {}
    slabs: list[jnp.ndarray] = []  # column-padded row blocks, emission order

    def emit(col0: int, block: jnp.ndarray) -> None:
        slabs.append(_pad_cols(block, col0, spec.num_cols))

    for idx in reversed(spec.preorder):  # children strictly before parents
        sp = spec.nodes[idx]
        ix = plan.index[idx]
        cnt = counts[idx]
        x = data[idx]

        # --- HEADS_AND_TAILS (lines 11-16) --------------------------------
        # Capacity-padded plans weight the Givens sequences by the live-row
        # mask: dead rows carry weight 0 (they neither move the prefix sums
        # nor receive a tail) and their data is zeroed so the padded slab rows
        # of R₀ come out identically zero. Dead rows are never segment starts
        # (plan_cache appends them to the last live group), so every division
        # inside segmented_head_tail stays well-posed.
        if ix.row_mask is not None:
            weights = jnp.asarray(ix.row_mask, dtype=dtype)
            x = x * weights[:, None]
        else:
            weights = jnp.ones((sp.m,), dtype=dtype)
        heads, tails, _ = segmented_head_tail(
            x, weights, jnp.asarray(ix.row_to_group),
            jnp.asarray(ix.pos_in_group), sp.K, use_kernel=use_kernel)
        phi_circ_row = cnt["phi_circ"][jnp.asarray(ix.row_to_group)]
        emit(sp.col_start, tails * jnp.sqrt(phi_circ_row)[:, None])

        scales = jnp.sqrt(cnt["rpk"])  # √|S_i^x̄|, one per key
        # --- PROCESS_AND_JOIN_CHILDREN (lines 17-26) ----------------------
        if sp.children:
            gathered = []  # (data [K, w_ch], scale [K]) in child (column) order
            for ch in sp.children:
                lookup = jnp.asarray(ix.child_lookup[ch])
                gathered.append((carried_data.pop(ch)[lookup],
                                 carried_scales.pop(ch)[lookup]))
            prod_all = functools.reduce(jnp.multiply, [s for _, s in gathered])
            blocks = [heads * prod_all[:, None]]
            for j, (dj, _) in enumerate(gathered):
                prod_except = functools.reduce(
                    jnp.multiply,
                    [s for k, (_, s) in enumerate(gathered) if k != j],
                    scales)  # scales = √rpk_i  (line 24's `scales[x̄_i]` factor)
                blocks.append(dj * prod_except[:, None])
            # Children subtrees are column-contiguous after the node's own
            # columns (validated at plan build) — Data is a pure concat.
            data_mat = jnp.concatenate(blocks, axis=1)
            scales = scales * prod_all  # line 26
        else:
            data_mat = heads  # width == n for a leaf

        # --- PROJECT_AWAY_JOIN_ATTRIBUTES (lines 27-34) / root (lines 7-8) -
        if sp.parent >= 0:
            gheads, gtails, _ = segmented_head_tail(
                data_mat, scales, jnp.asarray(ix.group_to_pgroup),
                jnp.asarray(ix.pos_in_pgroup), sp.P, use_kernel=use_kernel)
            phi_up_group = cnt["phi_up"][jnp.asarray(ix.group_to_pgroup)]
            emit(sp.subtree_start, gtails * jnp.sqrt(phi_up_group)[:, None])
            carried_data[idx] = gheads
            carried_scales[idx] = jnp.sqrt(cnt["phi_down"])
        else:
            emit(sp.subtree_start, data_mat)

    r0 = jnp.concatenate(slabs, axis=0)
    assert r0.shape[0] == spec.r0_rows, (r0.shape, spec.r0_rows)
    return r0


def figaro_r0_batched(
    plan: FigaroPlan,
    data_batch: Sequence[jnp.ndarray],
    *,
    dtype=jnp.float32,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Algorithm 2 vmapped over a leading batch axis of the data matrices.

    ``data_batch[i]`` is [B, m_i, n_i]; the plan (and therefore the counts,
    which depend only on the index structure) is held fixed across the batch —
    one join structure serving B feature-sets per dispatch. Returns
    [B, r0_rows, num_cols].
    """
    fn = functools.partial(figaro_r0, plan, dtype=dtype, use_kernel=use_kernel)
    return jax.vmap(lambda d: fn(list(d)))(tuple(data_batch))


def figaro_r0_fn(plan: FigaroPlan, *, dtype=jnp.float32, use_kernel: bool = False):
    """A jittable closure ``data_list -> R₀`` for a fixed plan.

    Kept for the pre-engine call sites; new code should go through
    `repro.core.engine.FigaroEngine`, which passes the plan through jit as a
    pytree argument and shares one executable across same-signature plans.
    """

    def fn(data: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return figaro_r0(plan, data, dtype=dtype, use_kernel=use_kernel)

    return jax.jit(fn)
