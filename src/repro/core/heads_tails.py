"""Heads and tails (paper §3): block effects of Givens-rotation sequences.

``head(A, v)`` / ``tail(A, v)`` implement Definition 3.4 (the unweighted
Definition 3.2 is the ``v = 1`` special case). Together they form a *weighted
Helmert transform*: the orthogonal matrix ``G = R_m … R_2`` of Lemma 3.5, so

    G @ [S⊗v | A]  ==  [ ‖v‖₂·S  head(A,v) ]
                       [   0     tail(A,v) ]

`segmented_head_tail` applies the transform independently per contiguous
segment of rows (one segment per join key) — the vectorized form FiGaRo needs.
`givens_sequence` builds the explicit rotation sequence (test oracle: applying
it row-by-row must reproduce head/tail bit-for-bit-ish).

Numerics note (paper observation (3)): head/tail never squares *data* values —
only the weights are squared — which is where FiGaRo's accuracy edge over
Householder-on-the-join comes from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "head",
    "tail",
    "head_tail",
    "segmented_head_tail",
    "segmented_cumsum",
    "givens_rotation",
    "givens_sequence",
]


def head(a: jnp.ndarray, v: jnp.ndarray | None = None) -> jnp.ndarray:
    """Generalized head ``H(A, v) = (1/‖v‖₂) Σᵢ vᵢ A[i,:]`` — one row."""
    a = jnp.asarray(a)
    if v is None:
        return jnp.sum(a, axis=0) / jnp.sqrt(a.shape[0])
    # Cast the weights to the data dtype (as `tail` does): a float64 weight
    # vector must not silently upcast low-precision (bf16/f16/f32) data.
    v = jnp.asarray(v, dtype=a.dtype)
    return (v @ a) / jnp.linalg.norm(v)


def tail(a: jnp.ndarray, v: jnp.ndarray | None = None) -> jnp.ndarray:
    """Generalized tail ``T(A, v)`` — (m-1) rows (Definition 3.4).

    Row ``j`` (1-based, j∈[m-1]) is
      ( ‖v₁..ⱼ‖·A[j+1,:] − vⱼ₊₁·(Σᵢ≤ⱼ vᵢA[i,:])/‖v₁..ⱼ‖ ) / ‖v₁..ⱼ₊₁‖.
    """
    a = jnp.asarray(a)
    m = a.shape[0]
    if v is None:
        v = jnp.ones((m,), dtype=a.dtype)
    v = jnp.asarray(v, dtype=a.dtype)
    w2 = v * v
    c_incl = jnp.cumsum(w2)  # ‖v₁..ⱼ‖² at j (inclusive)
    s_incl = jnp.cumsum(v[:, None] * a, axis=0)
    c_excl = c_incl - w2
    s_excl = s_incl - v[:, None] * a
    c_excl_safe = jnp.where(c_excl > 0, c_excl, 1.0)
    t = (jnp.sqrt(c_excl_safe)[:, None] * a
         - v[:, None] * s_excl / jnp.sqrt(c_excl_safe)[:, None])
    t = t / jnp.sqrt(c_incl)[:, None]
    return t[1:]


def head_tail(a: jnp.ndarray, v: jnp.ndarray | None = None):
    return head(a, v), tail(a, v)


# ---------------------------------------------------------------------------
# Segmented (per-join-key) version — FiGaRo's workhorse.
# ---------------------------------------------------------------------------


def segmented_cumsum(x: jnp.ndarray, first_flag: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumsum that restarts wherever ``first_flag`` is True.

    Implemented with an associative scan (no subtract-the-base trick), so long
    arrays do not suffer cross-segment cancellation — this mirrors what the
    Pallas kernel does natively on TPU.
    """
    flags = first_flag
    if x.ndim == 2:
        flags = first_flag[:, None]
    flags = jnp.broadcast_to(flags, x.shape)

    def combine(a, b):
        fa, xa = a
        fb, xb = b
        return fa | fb, xb + jnp.where(fb, jnp.zeros_like(xa), xa)

    _, out = jax.lax.associative_scan(combine, (flags, x), axis=0)
    return out


def segmented_head_tail(
    data: jnp.ndarray,
    weights: jnp.ndarray,
    seg_id: jnp.ndarray,
    pos_in_seg: jnp.ndarray,
    num_segments: int,
    *,
    use_kernel: bool = False,
):
    """Per-segment generalized head & tail over contiguous row segments.

    Args:
      data: [m, n]; rows of all segments, concatenated (segment-sorted).
      weights: [m] strictly positive weights ``v``.
      seg_id: [m] int — segment of each row (non-decreasing).
      pos_in_seg: [m] int — 0 for the first row of a segment.
      num_segments: static segment count K.
      use_kernel: route the segmented scan through the Pallas kernel
        (`repro.kernels.head_tail`) instead of the XLA associative scan.

    Returns:
      heads: [K, n]   — H(seg, v_seg)
      tails: [m, n]   — row r holds T(seg, v_seg)[pos-1] for pos>0, else 0
      norms: [K]      — ‖v_seg‖₂ (the scaling Lemma 3.5 applies to the S part)
    """
    m, _ = data.shape
    dtype = data.dtype
    weights = weights.astype(dtype)
    first = pos_in_seg == 0
    w2 = weights * weights
    wa = data * weights[:, None]

    if use_kernel:
        from repro.kernels.head_tail import ops as ht_ops
        c_incl = segmented_cumsum(w2, first)
        c_excl = c_incl - w2
        c_excl_safe = jnp.where(pos_in_seg > 0, c_excl, 1.0)
        coef_a = jnp.sqrt(c_excl_safe / c_incl)
        coef_b = -weights / jnp.sqrt(c_excl_safe * c_incl)
        tails = ht_ops.segmented_tail(data, wa, first, coef_a, coef_b)
    else:
        c_incl = segmented_cumsum(w2, first)
        s_incl = segmented_cumsum(wa, first)
        c_excl = c_incl - w2
        s_excl = s_incl - wa
        c_excl_safe = jnp.where(pos_in_seg > 0, c_excl, 1.0)
        tails = (jnp.sqrt(c_excl_safe)[:, None] * data
                 - weights[:, None] * s_excl / jnp.sqrt(c_excl_safe)[:, None])
        tails = tails / jnp.sqrt(c_incl)[:, None]
    tails = jnp.where((pos_in_seg > 0)[:, None], tails, jnp.zeros_like(tails))

    c_tot = jax.ops.segment_sum(w2, seg_id, num_segments=num_segments)
    s_tot = jax.ops.segment_sum(wa, seg_id, num_segments=num_segments)
    norms = jnp.sqrt(c_tot)
    heads = s_tot / jnp.where(norms > 0, norms, 1.0)[:, None]
    return heads, tails, norms


# ---------------------------------------------------------------------------
# Explicit Givens rotations — the oracle the closed forms must agree with.
# ---------------------------------------------------------------------------


def givens_rotation(m: int, i: int, j: int, s: float, c: float) -> np.ndarray:
    """``Giv_m(i, j, sinθ, cosθ)`` (Definition 3.1), 0-based indices."""
    g = np.eye(m)
    g[i, i] = c
    g[j, j] = c
    g[i, j] = -s
    g[j, i] = s
    return g


def givens_sequence(v: np.ndarray) -> np.ndarray:
    """The orthogonal ``G = R_m … R_2`` of Lemma 3.5 for weight vector ``v``.

    Applying G to ``[S⊗v | T]`` zeroes all but the first (scaled) copy of S and
    produces [head; tail] — the oracle used by tests.
    """
    v = np.asarray(v, dtype=np.float64)
    m = v.shape[0]
    g = np.eye(m)
    for i in range(1, m):  # paper's i = 2..m (1-based)
        norm_i = np.linalg.norm(v[: i + 1])
        norm_im1 = np.linalg.norm(v[:i])
        r = givens_rotation(m, 0, i, -v[i] / norm_i, norm_im1 / norm_i)
        g = r @ g
    return g
