"""Distributed FiGaRo: THIN/TSQR on the mesh + fact-partitioned multi-pod QR.

Two levels, mirroring the paper's own structure (§7 THIN, §8 Exp 2):

1. **Mesh post-processing** (`distributed_postprocess_r0`): R₀'s rows are
   sharded over a mesh axis; each shard runs a local blocked-Householder QR,
   then a butterfly ``ppermute`` combine (log₂ P rounds of QR on stacked
   [2n × n] triangles) leaves every shard holding the identical final R.
   This is the paper's dominant cost parallelized with `shard_map` — the TPU
   version of THIN's per-thread Givens + parallel combine.

2. **Fact-table domain partitioning** (`partitioned_figaro_qr`): the join is a
   disjoint union over partitions of the fact (root) relation's rows (key
   groups kept whole; dimension relations replicated) — so
   ``A = vstack(A_1..A_P)`` and ``R = tsqr-combine(R_1..R_P)``. Each partition
   runs the full FiGaRo pipeline independently (in production: one partition
   per pod, SPMD; here: per-partition jit programs + the same combine). This
   is how FiGaRo scales past a single pod, and it is *elastic*: P is chosen at
   launch from the devices that exist.

Orthogonal-freedom note: any composition of orthogonal reductions yields the
same R up to row signs (tests pin signs via `normalize_sign` and check the
Gram invariant), which is exactly the freedom the paper exploits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from .figaro import figaro_r0
from .join_tree import JoinTree, build_plan
from .postprocess import blocked_qr_r, householder_qr_r, normalize_sign, tsqr_r
from .relation import Database, Relation

__all__ = [
    "butterfly_qr_combine",
    "distributed_postprocess_r0",
    "distributed_qr_r",
    "partition_fact_table",
    "partitioned_figaro_qr",
]


def butterfly_qr_combine(r_local: jnp.ndarray, axis_name: str,
                         axis_size: int, leaf_qr=householder_qr_r) -> jnp.ndarray:
    """Inside shard_map: combine per-shard R factors so all shards hold the
    final R.

    For a power-of-two ``axis_size``: log₂(P) butterfly rounds; round d stacks
    each shard's R with its distance-d partner's and re-triangularizes
    ([2n, n] QR). For any other P the pure butterfly is *invalid* — partner
    ``i ^ d`` can point past the axis (P=3 pairs shard 2 with nonexistent
    shard 3) and that shard would end the loop without the others'
    contributions. Instead the remainder shards [P₂, P) (P₂ = largest power of
    two ≤ P) are first folded into shards [0, P−P₂), the butterfly runs on the
    [0, P₂) core, and the combined R is broadcast back to the folded-away
    shards: 1 + log₂(P₂) + 1 rounds, every round a valid permutation.
    """
    axis_size = int(axis_size)
    if axis_size < 1:
        raise ValueError(f"axis_size must be a positive int, got {axis_size}")
    if axis_size == 1:
        return r_local
    r = r_local
    idx = jax.lax.axis_index(axis_name)
    core = 1 << (axis_size.bit_length() - 1)  # largest power of two <= P
    rem = axis_size - core
    if rem:  # fold shards [core, P) into [0, rem)
        r_in = jax.lax.ppermute(r, axis_name,
                                [(core + i, i) for i in range(rem)])
        r = jnp.where(idx < rem, leaf_qr(jnp.concatenate([r, r_in], axis=0)),
                      r)
    d = 1
    while d < core:
        perm = [(i, i ^ d) for i in range(core)]
        r_other = jax.lax.ppermute(r, axis_name, perm)
        # Stable stacking order (lower index first) keeps all core shards
        # bitwise identical after each round.
        lo = jnp.where(idx < (idx ^ d), r, r_other)
        hi = jnp.where(idx < (idx ^ d), r_other, r)
        r = jnp.where(idx < core, leaf_qr(jnp.concatenate([lo, hi], axis=0)),
                      r)
        d *= 2
    if rem:  # broadcast the combined R back to the folded-away shards
        r_bcast = jax.lax.ppermute(r, axis_name,
                                   [(i, core + i) for i in range(rem)])
        r = jnp.where(idx >= core, r_bcast, r)
    return r


def distributed_postprocess_r0(
    r0: jnp.ndarray,
    mesh: Mesh,
    axis: str = "data",
    *,
    panel: int = 32,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """R₀ (M×N) → R (N×N) with rows sharded over ``mesh[axis]`` (THIN on TPU)."""
    m, n = r0.shape
    p = mesh.shape[axis]
    mp = -(-m // p) * p
    if mp != m:
        r0 = jnp.concatenate([r0, jnp.zeros((mp - m, n), r0.dtype)], axis=0)
    # Pre-shard the rows over the mesh: inputs committed to a single device
    # (e.g. the stacked per-partition Rs) would otherwise be rejected by the
    # mesh-wide computation.
    r0 = jax.device_put(r0, NamedSharding(mesh, P(axis, None)))

    local_qr = functools.partial(blocked_qr_r, panel=panel,
                                 use_kernel=use_kernel)

    def shard_fn(block):  # [mp/p, n] per shard
        r_local = local_qr(block)
        return butterfly_qr_combine(r_local, axis, p, leaf_qr=householder_qr_r)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=P(axis, None),
        out_specs=P(axis, None),  # each shard returns its (identical) R
    )
    out = fn(r0)  # [p*n, n] stacked identical copies
    return normalize_sign(out[:n])


def distributed_qr_r(a: jnp.ndarray, mesh: Mesh, axis: str = "data",
                     **kw) -> jnp.ndarray:
    """General tall-skinny distributed QR (used by optim.orthogonal too)."""
    return distributed_postprocess_r0(a, mesh, axis, **kw)


# ---------------------------------------------------------------------------
# Multi-pod scaling: fact-table domain partitioning.
# ---------------------------------------------------------------------------


def partition_fact_table(tree: JoinTree, num_parts: int) -> list[JoinTree]:
    """Split the root relation's rows into ``num_parts`` contiguous chunks
    (whole key groups; paper §8 Exp 2 'domain parallelism'), replicating the
    other relations. Empty chunks are dropped."""
    db = tree.db
    root = db[tree.root]
    # Root must be grouped by its sort order for contiguous whole groups;
    # sort exactly as build_plan would (no parent => canonical key order).
    root_sorted = root.sorted_by(root.key_attrs)
    m = root_sorted.num_rows
    if root.key_attrs:
        codes = np.zeros(m, dtype=np.int64)
        for a in root.key_attrs:
            codes = codes * (int(root_sorted.key_col(a).max()) + 1) + \
                root_sorted.key_col(a)
        boundaries = np.nonzero(np.r_[True, codes[1:] != codes[:-1]])[0]
    else:
        boundaries = np.arange(m)
    # Cut at group starts nearest to equal row counts.
    cuts = [0]
    for k in range(1, num_parts):
        target = k * m // num_parts
        j = int(boundaries[np.searchsorted(boundaries, target)]) \
            if target <= boundaries[-1] else m
        cuts.append(max(j, cuts[-1]))
    cuts.append(m)
    trees = []
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        if hi <= lo:
            continue
        part = Relation(root.name, root.key_attrs, root.data_attrs,
                        root_sorted.keys[lo:hi], root_sorted.data[lo:hi])
        rels = dict(db.relations)
        rels[root.name] = part
        sub_db = Database(rels)
        # Dimension rows that no longer join with this fact chunk must be
        # dropped (full reduction per partition).
        from .relation import full_reduce
        sub_db = full_reduce(sub_db, tree.edges())
        trees.append(JoinTree(sub_db, dict(tree.parent)))
    return trees


def partitioned_figaro_qr(
    tree: JoinTree,
    num_parts: int,
    *,
    dtype=jnp.float64,
    method: str = "tsqr",
    use_kernel: bool = False,
    assembly: str = "padded",
    engine=None,
    mesh: Mesh | None = None,
    axis: str = "data",
) -> jnp.ndarray:
    """FiGaRo over ``num_parts`` fact partitions + TSQR combine.

    Per-partition programs are independent (different static shapes — in
    production each runs on its own pod). Each partition dispatches through
    the shared `FigaroEngine` (default: the `repro.api.default_session()`
    engine, so partitions share executables with the rest of the façade),
    whose executable cache keys on the partition's plan signature — repeat
    calls (elastic re-dispatch, refreshed data) reuse the compiled programs
    instead of re-tracing per call. `figaro.Session.partitioned_qr` is the
    façade form (session engine/mesh/dtype defaults).

    Without a ``mesh`` the partitions run (async) on the default device and
    the partial R factors are TSQR-combined locally. With a ``mesh`` each
    partition's program is placed on its own device slot (round-robin over the
    mesh — jit dispatch is async, so the per-partition programs execute
    concurrently) and the stacked partial Rs are combined on the mesh itself
    via `distributed_postprocess_r0`'s butterfly.
    """
    if engine is None:
        from repro.api import default_session

        engine = default_session().engine
    parts = partition_fact_table(tree, num_parts)
    if mesh is None:
        rs = [engine.qr(build_plan(t), dtype=dtype, method=method,
                        use_kernel=use_kernel, assembly=assembly)
              for t in parts]
        stacked = jnp.concatenate(rs, axis=0)
        return normalize_sign(tsqr_r(stacked, leaf_rows=max(
            r.shape[0] for r in rs)))
    slots = mesh.devices.reshape(-1)
    rs = []
    for i, t in enumerate(parts):
        with jax.default_device(slots[i % slots.size]):
            rs.append(engine.qr(build_plan(t), dtype=dtype, method=method,
                                use_kernel=use_kernel, assembly=assembly))
    # Colocate the per-slot Rs before stacking (cross-device concat is an
    # error), then THIN-combine the [P·N, N] stack over the mesh.
    stacked = jnp.concatenate(
        [jax.device_put(r, slots[0]) for r in rs], axis=0)
    return distributed_postprocess_r0(stacked, mesh, axis,
                                      use_kernel=use_kernel)
