"""Join trees and the FiGaRo execution plan (structural index, built at ingest).

A `JoinTree` fixes the evaluation order of the acyclic natural join (paper §2).
`build_plan` compiles the database + tree into a `FigaroPlan`: per-node group
structure (segments by full join key ``X̄_i`` and by the parent-shared key
``X̄_p``), child lookup maps, and the global column layout. All shapes in the
plan are static, so the numeric passes (`counts.py`, `figaro.py`) jit cleanly.

Terminology matches the paper: for node ``i``, ``X̄_i`` = all join attributes of
``S_i``; ``X̄_p`` = join attributes shared with the parent (empty for the root or
for Cartesian edges); ``X̄_ij`` = attributes shared with child ``j`` (== child's
``X̄_p``).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .relation import Database, Relation

__all__ = ["JoinTree", "NodePlan", "FigaroPlan", "build_plan"]


@dataclasses.dataclass
class JoinTree:
    """Rooted join tree over relation names: ``parent[name]`` (root maps to None)."""

    db: Database
    parent: dict[str, str | None]

    def __post_init__(self) -> None:
        roots = [n for n, p in self.parent.items() if p is None]
        if len(roots) != 1:
            raise ValueError(f"join tree needs exactly one root, got {roots}")
        self.root = roots[0]
        self.children: dict[str, list[str]] = {n: [] for n in self.parent}
        for n, p in self.parent.items():
            if p is not None:
                self.children[p].append(n)
        if set(self.parent) != set(self.db.names):
            raise ValueError("join tree nodes != database relations")
        self._validate_join_tree_property()

    @staticmethod
    def from_edges(db: Database, root: str,
                   edges: Sequence[tuple[str, str]]) -> "JoinTree":
        """Build a join tree rooted at ``root``; ``edges`` may be given in any
        orientation (they are re-oriented away from the root), so one edge set
        can be evaluated under every join-tree choice (Table 2)."""
        adj: dict[str, list[str]] = {}
        for a, b in edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, []).append(a)
        parent: dict[str, str | None] = {root: None}
        stack = [root]
        while stack:
            node = stack.pop()
            for nb in adj.get(node, []):
                if nb not in parent:
                    parent[nb] = node
                    stack.append(nb)
        if adj and len(parent) != len(adj):
            raise ValueError(
                f"edges do not form a tree reaching {set(adj) - set(parent)}")
        return JoinTree(db, parent)

    def preorder(self) -> list[str]:
        out: list[str] = []

        def rec(n: str) -> None:
            out.append(n)
            for c in self.children[n]:
                rec(c)

        rec(self.root)
        return out

    def edges(self) -> list[tuple[str, str]]:
        return [(p, c) for c, p in self.parent.items() if p is not None]

    def shared_attrs(self, a: str, b: str) -> tuple[str, ...]:
        ra, rb = self.db[a], self.db[b]
        return tuple(x for x in ra.key_attrs if x in rb.key_attrs)

    def _validate_join_tree_property(self) -> None:
        """Each attribute must induce a connected subtree (α-acyclicity)."""
        attr_nodes: dict[str, list[str]] = {}
        for rel in self.db:
            for a in rel.key_attrs:
                attr_nodes.setdefault(a, []).append(rel.name)
        for attr, nodes in attr_nodes.items():
            if len(nodes) <= 1:
                continue
            # The nodes containing `attr`, plus tree edges between them, must
            # form a connected subgraph.
            node_set = set(nodes)
            # union-find over tree edges whose both endpoints have the attr
            parent_uf = {n: n for n in nodes}

            def find(x: str) -> str:
                while parent_uf[x] != x:
                    parent_uf[x] = parent_uf[parent_uf[x]]
                    x = parent_uf[x]
                return x

            for p, c in self.edges():
                if p in node_set and c in node_set:
                    parent_uf[find(p)] = find(c)
            roots = {find(n) for n in nodes}
            if len(roots) != 1:
                raise ValueError(
                    f"attribute {attr!r} violates the join-tree property "
                    f"(occurs in disconnected nodes {sorted(nodes)}) — the join "
                    "is not acyclic for this tree; materialize a tree "
                    "decomposition first (paper §2)."
                )


def _group_structure(codes: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """For a sorted code array return (elem_to_group, group_start, group_count)."""
    m = codes.shape[0]
    if m == 0:
        z = np.zeros(0, dtype=np.int32)
        return z, z, z
    first = np.ones(m, dtype=bool)
    first[1:] = codes[1:] != codes[:-1]
    elem_to_group = np.cumsum(first).astype(np.int32) - 1
    group_start = np.nonzero(first)[0].astype(np.int32)
    group_count = np.diff(np.append(group_start, m)).astype(np.int32)
    return elem_to_group, group_start, group_count


def _codes(rel: Relation, attrs: Sequence[str], cards: dict[str, int]) -> np.ndarray:
    """Composite int64 codes over `attrs` using *global* attribute cardinalities,
    so codes are comparable across relations."""
    code = np.zeros(rel.num_rows, dtype=np.int64)
    for a in attrs:
        code = code * cards[a] + rel.key_col(a)
    return code


@dataclasses.dataclass
class NodePlan:
    name: str
    idx: int
    parent: int  # -1 for root
    children: list[int]
    # Static sizes.
    m: int  # rows
    n: int  # data columns
    K: int  # distinct full join keys X̄_i
    P: int  # distinct parent-shared keys X̄_p (1 for root / Cartesian edge)
    # Row-level structure (all [m]).
    row_to_group: np.ndarray
    row_seg_start: np.ndarray  # first row index of the row's group
    pos_in_group: np.ndarray
    # Group-level structure.
    group_start: np.ndarray  # [K] first row of group
    group_count: np.ndarray  # [K]
    group_to_pgroup: np.ndarray  # [K]
    group_seg_start: np.ndarray  # [K] first group index of the group's pgroup
    pos_in_pgroup: np.ndarray  # [K]
    pgroup_count: np.ndarray  # [P] (# groups per pgroup)
    # Child lookups: child idx -> [K] index into that child's P-table.
    child_lookup: dict[int, np.ndarray]
    # Column layout (global, preorder => subtree columns contiguous).
    col_start: int
    subtree_start: int
    subtree_width: int
    # The node's sorted numeric data.
    data: np.ndarray  # [m, n] float


@dataclasses.dataclass
class FigaroPlan:
    nodes: list[NodePlan]  # indexed by node idx
    preorder: list[int]
    root: int
    num_cols: int  # N = total data columns
    total_rows: int  # M = sum of m_i
    r0_rows: int  # rows of the (padded) almost-upper-triangular R0
    names: list[str]

    def node_by_name(self, name: str) -> NodePlan:
        return self.nodes[self.names.index(name)]


def build_plan(tree: JoinTree, dtype=np.float64) -> FigaroPlan:
    """Compile (database, join tree) into a FigaroPlan.

    Sorts every relation with the parent-shared attributes major (paper §5
    assumption), derives segment structure, child lookup tables, and the global
    preorder column layout.
    """
    db = tree.db
    order = tree.preorder()
    name_to_idx = {n: i for i, n in enumerate(order)}

    # Global attribute cardinalities (for cross-relation composite codes).
    cards: dict[str, int] = {}
    for rel in db:
        for a in rel.key_attrs:
            c = int(rel.key_col(a).max()) + 1 if rel.num_rows else 1
            cards[a] = max(cards.get(a, 1), c)

    # Column layout: preorder, so each subtree occupies a contiguous range.
    col_start: dict[str, int] = {}
    acc = 0
    for nme in order:
        col_start[nme] = acc
        acc += db[nme].num_data_cols
    num_cols = acc

    def subtree_cols(nme: str) -> int:
        return db[nme].num_data_cols + sum(subtree_cols(c) for c in tree.children[nme])

    nodes: list[NodePlan] = [None] * len(order)  # type: ignore

    # First pass: sort relations and build per-node group structure.
    sorted_rels: dict[str, Relation] = {}
    pkey_attrs: dict[str, tuple[str, ...]] = {}
    for nme in order:
        par = tree.parent[nme]
        xp = tree.shared_attrs(nme, par) if par is not None else ()
        rest = tuple(a for a in db[nme].key_attrs if a not in xp)
        sorted_rels[nme] = db[nme].sorted_by(tuple(xp) + rest)
        pkey_attrs[nme] = tuple(xp)

    # Distinct X̄_p tables per node (codes, sorted) — needed for parent lookups.
    pcode_table: dict[str, np.ndarray] = {}
    for nme in order:
        rel = sorted_rels[nme]
        pcodes = _codes(rel, pkey_attrs[nme], cards)
        pcode_table[nme] = np.unique(pcodes)  # sorted

    for nme in order:
        rel = sorted_rels[nme]
        par = tree.parent[nme]
        xp = pkey_attrs[nme]
        # Rows are sorted xp-major; full-key codes must therefore be mixed
        # xp-major too for sortedness:
        xp_major = tuple(xp) + tuple(a for a in rel.key_attrs if a not in xp)
        full_codes = _codes(rel, xp_major, cards)
        if np.any(np.diff(full_codes) < 0):
            raise AssertionError(f"{nme}: rows not sorted — ingest bug")
        row_to_group, group_start, group_count = _group_structure(full_codes)
        K = group_start.shape[0]
        pos_in_group = np.arange(rel.num_rows, dtype=np.int32) - group_start[row_to_group]
        row_seg_start = group_start[row_to_group]

        # pgroup structure over groups.
        pcodes_rows = _codes(rel, xp, cards)
        pcodes_groups = pcodes_rows[group_start]
        group_to_pgroup, pg_start, pg_count = _group_structure(pcodes_groups)
        P = pg_start.shape[0]
        group_seg_start = pg_start[group_to_pgroup]
        pos_in_pgroup = np.arange(K, dtype=np.int32) - group_seg_start

        # Child lookups: project this node's group keys onto X̄_ij and find the
        # index in the child's distinct X̄_p table. Fully-reduced inputs make
        # every lookup hit (asserted).
        child_lookup: dict[int, np.ndarray] = {}
        for ch in tree.children[nme]:
            xij = pkey_attrs[ch]
            proj = _codes(rel, xij, cards)[group_start]
            table = pcode_table[ch]
            pos = np.searchsorted(table, proj)
            pos = np.clip(pos, 0, table.shape[0] - 1)
            if not np.all(table[pos] == proj):
                raise ValueError(
                    f"dangling key {nme}->{ch}: database is not fully reduced; "
                    "run relation.full_reduce first")
            child_lookup[name_to_idx[ch]] = pos.astype(np.int32)

        nodes[name_to_idx[nme]] = NodePlan(
            name=nme,
            idx=name_to_idx[nme],
            parent=-1 if par is None else name_to_idx[par],
            children=[name_to_idx[c] for c in tree.children[nme]],
            m=rel.num_rows,
            n=rel.num_data_cols,
            K=K,
            P=int(pcode_table[nme].shape[0]),
            row_to_group=row_to_group,
            row_seg_start=row_seg_start.astype(np.int32),
            pos_in_group=pos_in_group,
            group_start=group_start,
            group_count=group_count,
            group_to_pgroup=group_to_pgroup,
            group_seg_start=group_seg_start.astype(np.int32),
            pos_in_pgroup=pos_in_pgroup,
            pgroup_count=pg_count,
            child_lookup=child_lookup,
            col_start=col_start[nme],
            subtree_start=col_start[nme],
            subtree_width=subtree_cols(nme),
            data=np.asarray(rel.data, dtype=dtype),
        )

    # Reverse-lookup sanity: child P-table == child's distinct X̄_p codes, and
    # the parent must cover all of them (full reduction the other way).
    for nme in order:
        for ch in tree.children[nme]:
            child = nodes[name_to_idx[ch]]
            lookup = nodes[name_to_idx[nme]].child_lookup[child.idx]
            covered = np.unique(lookup)
            if covered.shape[0] != child.P:
                raise ValueError(
                    f"dangling keys in {ch} (not matched by {nme}); run full_reduce")

    total_rows = sum(nd.m for nd in nodes)
    # R0 rows: per node its m tail rows; for non-root nodes K generalized-tail
    # rows; for the root K data (head) rows.
    r0_rows = sum(nd.m for nd in nodes)
    r0_rows += sum(nd.K for nd in nodes if nd.parent >= 0)
    r0_rows += nodes[name_to_idx[tree.root]].K

    return FigaroPlan(
        nodes=nodes,
        preorder=[name_to_idx[n] for n in order],
        root=name_to_idx[tree.root],
        num_cols=num_cols,
        total_rows=total_rows,
        r0_rows=r0_rows,
        names=order,
    )
