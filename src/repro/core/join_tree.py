"""Join trees and the FiGaRo execution plan, split static/dynamic for jit.

A `JoinTree` fixes the evaluation order of the acyclic natural join (paper §2).
`build_plan` compiles the database + tree into a `FigaroPlan`, which is split
into the two halves a compiled execution engine needs:

  * `PlanSpec` / `NodeSpec` — the **static** half: shapes, tree topology,
    column layout, and the R₀ row layout (where every node's tail block and
    generalized-tail block lands). All Python ints/tuples, hashable; it is the
    pytree *treedef* of a plan, so two plans with equal specs hit the same
    compiled executable.
  * `NodeIndex` — the **dynamic** half: per-node segment/group index arrays
    and child lookup tables. These are pytree *leaves*, so a `FigaroPlan`
    passes straight **through** `jax.jit` as an argument — no per-plan closure
    rebuild, one compilation per plan signature (see `repro.core.engine`).

`FigaroPlan` itself is a registered dataclass pytree `(spec, index, data)`;
`plan.nodes` still yields the merged per-node `NodePlan` views the rest of the
repo (benchmarks, examples, tests) reads fields off.

Terminology matches the paper: for node ``i``, ``X̄_i`` = all join attributes of
``S_i``; ``X̄_p`` = join attributes shared with the parent (empty for the root or
for Cartesian edges); ``X̄_ij`` = attributes shared with child ``j`` (== child's
``X̄_p``).

R₀ row layout: Algorithm 2 emits, per node in reversed preorder, first the
``m_i`` scaled-tail rows (at column ``col_start``) and then the ``K_i``
generalized-tail rows (root: head rows) at column ``subtree_start``. The
offsets are precomputed here (``tail_row0`` / ``out_row0``) so `figaro_r0`
assembles R₀ scatter-free by concatenating padded row slabs in layout order.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from .relation import Database, Relation

__all__ = [
    "JoinTree",
    "NodeSpec",
    "NodeIndex",
    "PlanSpec",
    "SlabBand",
    "NodePlan",
    "FigaroPlan",
    "build_plan",
]


@dataclasses.dataclass
class JoinTree:
    """Rooted join tree over relation names: ``parent[name]`` (root maps to None)."""

    db: Database
    parent: dict[str, str | None]

    def __post_init__(self) -> None:
        roots = [n for n, p in self.parent.items() if p is None]
        if len(roots) != 1:
            raise ValueError(f"join tree needs exactly one root, got {roots}")
        self.root = roots[0]
        self.children: dict[str, list[str]] = {n: [] for n in self.parent}
        for n, p in self.parent.items():
            if p is not None:
                self.children[p].append(n)
        if set(self.parent) != set(self.db.names):
            raise ValueError("join tree nodes != database relations")
        self._validate_join_tree_property()

    @staticmethod
    def from_edges(db: Database, root: str,
                   edges: Sequence[tuple[str, str]]) -> "JoinTree":
        """Build a join tree rooted at ``root``; ``edges`` may be given in any
        orientation (they are re-oriented away from the root), so one edge set
        can be evaluated under every join-tree choice (Table 2).

        Unknown names fail eagerly: a ``root`` or edge endpoint that is not a
        relation of ``db`` raises a `ValueError` naming it and listing the
        ingested relations, instead of a bare `KeyError` (or a misleading
        not-a-tree error) deep inside tree construction."""
        names = set(db.names)
        unknown = sorted({n for e in edges for n in e if n not in names})
        if root not in names and root not in unknown:
            unknown.insert(0, root)
        if unknown:
            noun = "relation" if len(unknown) == 1 else "relations"
            raise ValueError(
                f"unknown {noun} {', '.join(map(repr, unknown))}; "
                f"ingested relations are {sorted(names)}")
        adj: dict[str, list[str]] = {}
        for a, b in edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, []).append(a)
        parent: dict[str, str | None] = {root: None}
        stack = [root]
        while stack:
            node = stack.pop()
            for nb in adj.get(node, []):
                if nb not in parent:
                    parent[nb] = node
                    stack.append(nb)
        if adj and len(parent) != len(adj):
            raise ValueError(
                f"edges do not form a tree reaching {set(adj) - set(parent)}")
        return JoinTree(db, parent)

    def preorder(self) -> list[str]:
        out: list[str] = []

        def rec(n: str) -> None:
            out.append(n)
            for c in self.children[n]:
                rec(c)

        rec(self.root)
        return out

    def edges(self) -> list[tuple[str, str]]:
        return [(p, c) for c, p in self.parent.items() if p is not None]

    def shared_attrs(self, a: str, b: str) -> tuple[str, ...]:
        ra, rb = self.db[a], self.db[b]
        return tuple(x for x in ra.key_attrs if x in rb.key_attrs)

    def _validate_join_tree_property(self) -> None:
        """Each attribute must induce a connected subtree (α-acyclicity)."""
        attr_nodes: dict[str, list[str]] = {}
        for rel in self.db:
            for a in rel.key_attrs:
                attr_nodes.setdefault(a, []).append(rel.name)
        for attr, nodes in attr_nodes.items():
            if len(nodes) <= 1:
                continue
            # The nodes containing `attr`, plus tree edges between them, must
            # form a connected subgraph.
            node_set = set(nodes)
            # union-find over tree edges whose both endpoints have the attr
            parent_uf = {n: n for n in nodes}

            def find(x: str) -> str:
                while parent_uf[x] != x:
                    parent_uf[x] = parent_uf[parent_uf[x]]
                    x = parent_uf[x]
                return x

            for p, c in self.edges():
                if p in node_set and c in node_set:
                    parent_uf[find(p)] = find(c)
            roots = {find(n) for n in nodes}
            if len(roots) != 1:
                raise ValueError(
                    f"attribute {attr!r} violates the join-tree property "
                    f"(occurs in disconnected nodes {sorted(nodes)}) — the join "
                    "is not acyclic for this tree; materialize a tree "
                    "decomposition first (paper §2)."
                )


def _group_structure(codes: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """For a sorted code array return (elem_to_group, group_start, group_count)."""
    m = codes.shape[0]
    if m == 0:
        z = np.zeros(0, dtype=np.int32)
        return z, z, z
    first = np.ones(m, dtype=bool)
    first[1:] = codes[1:] != codes[:-1]
    elem_to_group = np.cumsum(first).astype(np.int32) - 1
    group_start = np.nonzero(first)[0].astype(np.int32)
    group_count = np.diff(np.append(group_start, m)).astype(np.int32)
    return elem_to_group, group_start, group_count


def _codes(rel: Relation, attrs: Sequence[str], cards: dict[str, int]) -> np.ndarray:
    """Composite int64 codes over `attrs` using *global* attribute cardinalities,
    so codes are comparable across relations."""
    code = np.zeros(rel.num_rows, dtype=np.int64)
    for a in attrs:
        code = code * cards[a] + rel.key_col(a)
    return code


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Static, hashable per-node metadata — part of the plan's treedef."""

    name: str
    idx: int
    parent: int  # -1 for root
    children: tuple[int, ...]
    # Static sizes.
    m: int  # rows
    n: int  # data columns
    K: int  # distinct full join keys X̄_i
    P: int  # distinct parent-shared keys X̄_p (1 for root / Cartesian edge)
    # Column layout (global, preorder => subtree columns contiguous).
    col_start: int
    subtree_start: int
    subtree_width: int
    # Column offsets of each child's subtree block inside this node's carried
    # Data matrix (aligned with `children`; block 0 = own cols is implicit).
    child_rel_col0: tuple[int, ...]
    # R₀ row layout (emission order: reversed preorder, tails then gen-tails).
    tail_row0: int  # first row of the m scaled-tail rows
    out_row0: int  # first row of the K gen-tail (root: head) rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NodeIndex:
    """Dynamic per-node index arrays — pytree leaves, device-resident under jit.

    Built as numpy int32 at ingest; they cross the jit boundary as arguments,
    so gathers/segment-reductions trace against them without recompilation
    when only their *values* change (same-shape plan => cache hit).

    Capacity vs live size: for a *capacity-padded* plan (see
    `repro.core.plan_cache`) the static ``NodeSpec`` sizes are bucketed
    **capacities** and the live row/group/pgroup counts are dynamic — encoded
    here as ``row_mask`` (1.0 for live rows, 0.0 for padding) plus zeroed
    ``group_count`` entries for dead group slots. Appending rows only rewrites
    these leaf *values*, so a refresh with unchanged capacities re-dispatches
    the cached executable with zero retraces. ``row_mask is None`` marks an
    exact (unpadded) plan; the treedef difference keeps the two paths in
    separate executables.
    """

    # Row-level structure (all [m]).
    row_to_group: np.ndarray
    row_seg_start: np.ndarray  # first row index of the row's group
    pos_in_group: np.ndarray
    # Group-level structure.
    group_start: np.ndarray  # [K] first row of group
    group_count: np.ndarray  # [K]
    group_to_pgroup: np.ndarray  # [K]
    group_seg_start: np.ndarray  # [K] first group index of the group's pgroup
    pos_in_pgroup: np.ndarray  # [K]
    pgroup_count: np.ndarray  # [P] (# groups per pgroup)
    # Child lookups: child idx -> [K] index into that child's P-table.
    child_lookup: dict[int, np.ndarray]
    # Live-row mask [m] (float, 1.0 live / 0.0 dead) for capacity-padded
    # plans; None for exact plans.
    row_mask: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class SlabBand:
    """Band metadata of one emitted R₀ slab: rows [row0, row0+rows) hold node
    ``node``'s columns [col0, col0+width) and are zero outside that band —
    what band-wise assembly (`figaro_r0(assembly="band")`) materializes
    instead of padding every slab to the full ``num_cols`` width."""

    node: int
    kind: str  # "tail" (m scaled-tail rows) | "out" (K gen-tail/head rows)
    row0: int
    rows: int
    col0: int
    width: int


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """Static, hashable whole-plan metadata (the compilation signature)."""

    nodes: tuple[NodeSpec, ...]
    preorder: tuple[int, ...]
    root: int
    num_cols: int  # N = total data columns
    total_rows: int  # M = sum of m_i
    r0_rows: int  # rows of the (padded) almost-upper-triangular R0
    names: tuple[str, ...]
    # R₀ band layout in emission (row) order. Derived from `nodes` — always
    # recomputed in __post_init__, so `dataclasses.replace` (capacity
    # bucketing in plan_cache) can never leave it stale; any passed-in value
    # is overwritten.
    bands: tuple[SlabBand, ...] = ()

    def __post_init__(self) -> None:
        bands: list[SlabBand] = []
        for i in reversed(self.preorder):
            sp = self.nodes[i]
            bands.append(SlabBand(node=i, kind="tail", row0=sp.tail_row0,
                                  rows=sp.m, col0=sp.col_start, width=sp.n))
            bands.append(SlabBand(node=i, kind="out", row0=sp.out_row0,
                                  rows=sp.K, col0=sp.subtree_start,
                                  width=sp.subtree_width))
        object.__setattr__(self, "bands", tuple(bands))


@dataclasses.dataclass
class NodePlan:
    """Merged per-node view (spec + index + data) — the pre-split interface
    that benchmarks/examples/tests keep reading fields off."""

    name: str
    idx: int
    parent: int
    children: list[int]
    m: int
    n: int
    K: int
    P: int
    row_to_group: np.ndarray
    row_seg_start: np.ndarray
    pos_in_group: np.ndarray
    group_start: np.ndarray
    group_count: np.ndarray
    group_to_pgroup: np.ndarray
    group_seg_start: np.ndarray
    pos_in_pgroup: np.ndarray
    pgroup_count: np.ndarray
    child_lookup: dict[int, np.ndarray]
    col_start: int
    subtree_start: int
    subtree_width: int
    data: np.ndarray  # [m, n] float


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FigaroPlan:
    """(static spec, dynamic index, data) — a pytree that crosses jit whole.

    ``spec`` is metadata (hashable, part of the treedef); ``index`` and
    ``data`` are leaves. Passing a plan as a jit *argument* therefore keys the
    executable cache on the spec + array shapes only — new databases with the
    same shape re-use the compiled program.
    """

    index: tuple[NodeIndex, ...]
    data: tuple[np.ndarray, ...]  # per-node [m_i, n_i], preorder-indexed
    spec: PlanSpec = dataclasses.field(metadata=dict(static=True))

    # -- pre-split compatibility surface ------------------------------------
    @property
    def nodes(self) -> list[NodePlan]:
        return [
            NodePlan(
                name=sp.name, idx=sp.idx, parent=sp.parent,
                children=list(sp.children), m=sp.m, n=sp.n, K=sp.K, P=sp.P,
                row_to_group=ix.row_to_group, row_seg_start=ix.row_seg_start,
                pos_in_group=ix.pos_in_group, group_start=ix.group_start,
                group_count=ix.group_count,
                group_to_pgroup=ix.group_to_pgroup,
                group_seg_start=ix.group_seg_start,
                pos_in_pgroup=ix.pos_in_pgroup, pgroup_count=ix.pgroup_count,
                child_lookup=ix.child_lookup, col_start=sp.col_start,
                subtree_start=sp.subtree_start,
                subtree_width=sp.subtree_width,
                data=d,
            )
            for sp, ix, d in zip(self.spec.nodes, self.index,
                                 self.data if self.data else
                                 (None,) * len(self.spec.nodes))
        ]

    @property
    def preorder(self) -> tuple[int, ...]:
        return self.spec.preorder

    @property
    def root(self) -> int:
        return self.spec.root

    @property
    def num_cols(self) -> int:
        return self.spec.num_cols

    @property
    def total_rows(self) -> int:
        return self.spec.total_rows

    @property
    def r0_rows(self) -> int:
        return self.spec.r0_rows

    @property
    def names(self) -> tuple[str, ...]:
        return self.spec.names

    def node_by_name(self, name: str) -> NodePlan:
        return self.nodes[self.spec.names.index(name)]

    def with_data(self, data) -> "FigaroPlan":
        """Same plan over new per-node data matrices (shapes must match)."""
        data = tuple(data)
        for sp, d in zip(self.spec.nodes, data):
            if tuple(d.shape[-2:]) != (sp.m, sp.n):
                raise ValueError(
                    f"{sp.name}: data shape {d.shape} != plan ({sp.m}, {sp.n})")
        return dataclasses.replace(self, data=data)

    def without_data(self) -> "FigaroPlan":
        """Strip the data leaves (the engine passes data as its own argument,
        so donation can target data buffers without touching the index)."""
        return dataclasses.replace(self, data=())


def build_plan(tree: JoinTree, dtype=np.float64) -> FigaroPlan:
    """Compile (database, join tree) into a FigaroPlan.

    Sorts every relation with the parent-shared attributes major (paper §5
    assumption), derives segment structure, child lookup tables, the global
    preorder column layout, and the static R₀ row layout.
    """
    db = tree.db
    order = tree.preorder()
    name_to_idx = {n: i for i, n in enumerate(order)}

    # Global attribute cardinalities (for cross-relation composite codes).
    cards: dict[str, int] = {}
    for rel in db:
        for a in rel.key_attrs:
            c = int(rel.key_col(a).max()) + 1 if rel.num_rows else 1
            cards[a] = max(cards.get(a, 1), c)

    # Column layout: preorder, so each subtree occupies a contiguous range.
    col_start: dict[str, int] = {}
    acc = 0
    for nme in order:
        col_start[nme] = acc
        acc += db[nme].num_data_cols
    num_cols = acc

    def subtree_cols(nme: str) -> int:
        return db[nme].num_data_cols + sum(subtree_cols(c) for c in tree.children[nme])

    # First pass: sort relations and build per-node group structure.
    sorted_rels: dict[str, Relation] = {}
    pkey_attrs: dict[str, tuple[str, ...]] = {}
    for nme in order:
        par = tree.parent[nme]
        xp = tree.shared_attrs(nme, par) if par is not None else ()
        rest = tuple(a for a in db[nme].key_attrs if a not in xp)
        sorted_rels[nme] = db[nme].sorted_by(tuple(xp) + rest)
        pkey_attrs[nme] = tuple(xp)

    # Distinct X̄_p tables per node (codes, sorted) — needed for parent lookups.
    pcode_table: dict[str, np.ndarray] = {}
    for nme in order:
        rel = sorted_rels[nme]
        pcodes = _codes(rel, pkey_attrs[nme], cards)
        pcode_table[nme] = np.unique(pcodes)  # sorted

    specs: list[NodeSpec] = [None] * len(order)  # type: ignore
    index: list[NodeIndex] = [None] * len(order)  # type: ignore
    data: list[np.ndarray] = [None] * len(order)  # type: ignore

    for nme in order:
        rel = sorted_rels[nme]
        par = tree.parent[nme]
        xp = pkey_attrs[nme]
        # Rows are sorted xp-major; full-key codes must therefore be mixed
        # xp-major too for sortedness:
        xp_major = tuple(xp) + tuple(a for a in rel.key_attrs if a not in xp)
        full_codes = _codes(rel, xp_major, cards)
        if np.any(np.diff(full_codes) < 0):
            raise AssertionError(f"{nme}: rows not sorted — ingest bug")
        row_to_group, group_start, group_count = _group_structure(full_codes)
        K = group_start.shape[0]
        pos_in_group = np.arange(rel.num_rows, dtype=np.int32) - group_start[row_to_group]
        row_seg_start = group_start[row_to_group]

        # pgroup structure over groups.
        pcodes_rows = _codes(rel, xp, cards)
        pcodes_groups = pcodes_rows[group_start]
        group_to_pgroup, pg_start, pg_count = _group_structure(pcodes_groups)
        P = pg_start.shape[0]
        group_seg_start = pg_start[group_to_pgroup]
        pos_in_pgroup = np.arange(K, dtype=np.int32) - group_seg_start

        # Child lookups: project this node's group keys onto X̄_ij and find the
        # index in the child's distinct X̄_p table. Fully-reduced inputs make
        # every lookup hit (asserted).
        child_lookup: dict[int, np.ndarray] = {}
        for ch in tree.children[nme]:
            xij = pkey_attrs[ch]
            proj = _codes(rel, xij, cards)[group_start]
            table = pcode_table[ch]
            pos = np.searchsorted(table, proj)
            pos = np.clip(pos, 0, table.shape[0] - 1)
            if not np.all(table[pos] == proj):
                raise ValueError(
                    f"dangling key {nme}->{ch}: database is not fully reduced; "
                    "run relation.full_reduce first")
            child_lookup[name_to_idx[ch]] = pos.astype(np.int32)

        # Carried-Data column layout: own cols first, then each child subtree;
        # preorder makes the blocks contiguous — asserted so the engine can
        # assemble by concatenation alone.
        child_idxs = tuple(name_to_idx[c] for c in tree.children[nme])
        rel_col0 = []
        cursor = db[nme].num_data_cols
        for c in tree.children[nme]:
            r0c = col_start[c] - col_start[nme]
            assert r0c == cursor, (nme, c, r0c, cursor)
            rel_col0.append(r0c)
            cursor += subtree_cols(c)
        assert cursor == subtree_cols(nme)

        i = name_to_idx[nme]
        specs[i] = NodeSpec(
            name=nme,
            idx=i,
            parent=-1 if par is None else name_to_idx[par],
            children=child_idxs,
            m=rel.num_rows,
            n=rel.num_data_cols,
            K=K,
            P=int(pcode_table[nme].shape[0]),
            col_start=col_start[nme],
            subtree_start=col_start[nme],
            subtree_width=subtree_cols(nme),
            child_rel_col0=tuple(rel_col0),
            tail_row0=-1,  # filled below once all K/m are known
            out_row0=-1,
        )
        index[i] = NodeIndex(
            row_to_group=row_to_group,
            row_seg_start=row_seg_start.astype(np.int32),
            pos_in_group=pos_in_group,
            group_start=group_start,
            group_count=group_count,
            group_to_pgroup=group_to_pgroup,
            group_seg_start=group_seg_start.astype(np.int32),
            pos_in_pgroup=pos_in_pgroup,
            pgroup_count=pg_count,
            child_lookup=child_lookup,
        )
        data[i] = np.asarray(rel.data, dtype=dtype)

    # Reverse-lookup sanity: child P-table == child's distinct X̄_p codes, and
    # the parent must cover all of them (full reduction the other way).
    for nme in order:
        for ch in tree.children[nme]:
            ci = name_to_idx[ch]
            lookup = index[name_to_idx[nme]].child_lookup[ci]
            covered = np.unique(lookup)
            if covered.shape[0] != specs[ci].P:
                raise ValueError(
                    f"dangling keys in {ch} (not matched by {nme}); run full_reduce")

    # R₀ row layout, in emission order (reversed preorder; per node the m tail
    # rows then the K generalized-tail rows — for the root, K head rows).
    preorder = tuple(name_to_idx[n] for n in order)
    row_acc = 0
    for i in reversed(preorder):
        sp = specs[i]
        specs[i] = dataclasses.replace(sp, tail_row0=row_acc,
                                       out_row0=row_acc + sp.m)
        row_acc += sp.m + sp.K

    total_rows = sum(sp.m for sp in specs)
    spec = PlanSpec(
        nodes=tuple(specs),
        preorder=preorder,
        root=name_to_idx[tree.root],
        num_cols=num_cols,
        total_rows=total_rows,
        r0_rows=row_acc,
        names=tuple(order),
    )
    return FigaroPlan(spec=spec, index=tuple(index), data=tuple(data))
