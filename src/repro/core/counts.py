"""Batched group-by count queries over the join tree (paper §5, Algorithm 1).

Computes, for every node ``i`` of the join tree:

  Φ↓_i(x̄_p)  join size of S_i's subtree, grouped by the parent-shared key
  Φ↑_i(x̄_p)  join size of everything *outside* S_i's subtree
  Φ°_i(x̄_i)  join size of all relations except S_i, grouped by X̄_i

in two passes (bottom-up, then top-down), linear time. The paper's CPU version
uses atomics for concurrent accumulation; here every accumulation is a
`segment_sum` / gather over the static index structure in the `FigaroPlan`, so
the whole thing jits and differentiates away on TPU with zero synchronization.

Counts can exceed 2^31 quickly (they multiply along the tree), so they are
computed in floating point of a configurable dtype; sqrt of the counts is what
FiGaRo actually consumes. The default is float64: float32 is exact only up to
2^24, beyond which the full-join sizes round and ``phi_circ`` (= full / rpk)
silently corrupts the emission scaling. A numpy int64 reference lives in
`compute_counts_reference` for exactness tests.

Capacity-padded (masked) plans — see `repro.core.plan_cache` — carry group
slots with ``group_count == 0``; their counts are identically zero, and every
division below is guarded so 0/0 resolves to 0 instead of NaN. For exact plans
all denominators are >= 1, so the guards are value-neutral.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .join_tree import FigaroPlan

__all__ = ["NodeCounts", "compute_counts", "compute_counts_reference"]


class NodeCounts(dict):
    """Per-node aggregate bundle: keys rpk, theta_down, phi_down, full, phi_up, phi_circ."""


def _safe_div(num: jnp.ndarray, den: jnp.ndarray) -> jnp.ndarray:
    """``num / den`` with 0/0 -> 0 (dead capacity slots of masked plans)."""
    ok = den > 0
    return jnp.where(ok, num / jnp.where(ok, den, 1), jnp.zeros((), num.dtype))


def compute_counts(plan: FigaroPlan, dtype=jnp.float64) -> list[NodeCounts]:
    """Algorithm 1, jitted-friendly. Returns one `NodeCounts` per node index.

    Reads the static sizes off ``plan.spec`` and the (possibly traced) index
    arrays off ``plan.index``, so it composes with plans passed through jit as
    pytree arguments.
    """
    spec = plan.spec
    out: list[NodeCounts] = [NodeCounts() for _ in spec.nodes]

    # --- PASS 1 (bottom-up): ROWS_PER_KEY, Θ↓, Φ↓ -------------------------
    for idx in reversed(spec.preorder):
        sp, ix = spec.nodes[idx], plan.index[idx]
        rpk = jnp.asarray(ix.group_count, dtype=dtype)
        theta = rpk
        for ch in sp.children:
            phi_down_child = out[ch]["phi_down"]  # [P_child]
            lookup = jnp.asarray(ix.child_lookup[ch])
            theta = theta * phi_down_child[lookup]
        out[idx]["rpk"] = rpk
        out[idx]["theta_down"] = theta
        if sp.parent >= 0:
            out[idx]["phi_down"] = jax.ops.segment_sum(
                theta, jnp.asarray(ix.group_to_pgroup), num_segments=sp.P)

    # --- PASS 2 (top-down): FULL_JOIN_SIZE, Φ↑, Φ° ------------------------
    for idx in spec.preorder:
        sp, ix = spec.nodes[idx], plan.index[idx]
        if sp.parent >= 0:
            up = out[idx]["phi_up"]  # set by the parent below
            full = out[idx]["theta_down"] * up[jnp.asarray(ix.group_to_pgroup)]
        else:
            full = out[idx]["theta_down"]
        out[idx]["full"] = full
        out[idx]["phi_circ"] = _safe_div(full, out[idx]["rpk"])
        for ch in sp.children:
            lookup = jnp.asarray(ix.child_lookup[ch])
            full_ij = jax.ops.segment_sum(full, lookup,
                                          num_segments=spec.nodes[ch].P)
            out[ch]["phi_up"] = _safe_div(full_ij, out[ch]["phi_down"])

    return out


def compute_counts_reference(plan: FigaroPlan) -> list[dict[str, np.ndarray]]:
    """Same two-pass recurrences in numpy int64 (exact) — test oracle."""
    nodes = plan.nodes
    out: list[dict[str, np.ndarray]] = [dict() for _ in nodes]
    for idx in reversed(plan.preorder):
        nd = nodes[idx]
        rpk = nd.group_count.astype(np.int64)
        theta = rpk.copy()
        for ch in nd.children:
            theta = theta * out[ch]["phi_down"][nd.child_lookup[ch]]
        out[idx]["rpk"] = rpk
        out[idx]["theta_down"] = theta
        if nd.parent >= 0:
            acc = np.zeros(nd.P, dtype=np.int64)
            np.add.at(acc, nd.group_to_pgroup, theta)
            out[idx]["phi_down"] = acc
    for idx in plan.preorder:
        nd = nodes[idx]
        if nd.parent >= 0:
            full = out[idx]["theta_down"] * out[idx]["phi_up"][nd.group_to_pgroup]
        else:
            full = out[idx]["theta_down"]
        out[idx]["full"] = full
        assert np.all(full % out[idx]["rpk"] == 0)
        out[idx]["phi_circ"] = full // out[idx]["rpk"]
        for ch in nd.children:
            acc = np.zeros(nodes[ch].P, dtype=np.int64)
            np.add.at(acc, nd.child_lookup[ch], full)
            assert np.all(acc % out[ch]["phi_down"] == 0)
            out[ch]["phi_up"] = acc // out[ch]["phi_down"]
    return out
