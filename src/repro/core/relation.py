"""Relations, databases and ingest-time preprocessing for FiGaRo.

The paper's setting: a database of relations ``S_1..S_r``, each with *join* (key)
attributes ``X_i`` (any hashable type) and *data* attributes ``Y_i`` (reals). The
matrix ``A`` is defined by the natural join of the relations, projected onto the
data columns.

Design split (see DESIGN.md §3): everything *structural* — dictionary encoding of
keys, sorting, grouping, full reduction — happens here at ingest time in numpy
("query compilation", mirrors the paper's assumption that inputs are pre-sorted).
Everything *numeric* is jitted JAX downstream (`counts.py`, `figaro.py`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "Relation",
    "Database",
    "encode_database",
    "full_reduce",
]


@dataclasses.dataclass
class Relation:
    """One relation: integer-encoded key columns + float data columns.

    ``keys[:, a]`` is the dictionary-encoded value of key attribute
    ``key_attrs[a]`` for each row; encodings are shared across relations per
    attribute name so natural-join equality == integer equality.
    """

    name: str
    key_attrs: tuple[str, ...]
    data_attrs: tuple[str, ...]
    keys: np.ndarray  # [m, len(key_attrs)] int64
    data: np.ndarray  # [m, len(data_attrs)] float

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys, dtype=np.int64)
        self.data = np.asarray(self.data)
        if self.keys.ndim != 2 or self.data.ndim != 2:
            raise ValueError(f"{self.name}: keys/data must be 2-D")
        if self.keys.shape[0] != self.data.shape[0]:
            raise ValueError(f"{self.name}: keys and data row counts differ")
        if self.keys.shape[1] != len(self.key_attrs):
            raise ValueError(f"{self.name}: keys width != len(key_attrs)")
        if self.data.shape[1] != len(self.data_attrs):
            raise ValueError(f"{self.name}: data width != len(data_attrs)")

    @property
    def num_rows(self) -> int:
        return self.keys.shape[0]

    @property
    def num_data_cols(self) -> int:
        return self.data.shape[1]

    def key_col(self, attr: str) -> np.ndarray:
        return self.keys[:, self.key_attrs.index(attr)]

    def sorted_by(self, attr_order: Sequence[str]) -> "Relation":
        """Stable sort rows lexicographically by the given key attributes."""
        cols = [self.key_col(a) for a in attr_order]
        # np.lexsort sorts by the *last* key first.
        order = np.lexsort(tuple(reversed(cols))) if cols else np.arange(self.num_rows)
        return Relation(
            self.name, self.key_attrs, self.data_attrs,
            self.keys[order], self.data[order],
        )

    def select_rows(self, mask: np.ndarray) -> "Relation":
        return Relation(self.name, self.key_attrs, self.data_attrs,
                        self.keys[mask], self.data[mask])


@dataclasses.dataclass
class Database:
    relations: dict[str, Relation]

    def __getitem__(self, name: str) -> Relation:
        return self.relations[name]

    def __iter__(self):
        return iter(self.relations.values())

    @property
    def names(self) -> list[str]:
        return list(self.relations.keys())

    @property
    def total_rows(self) -> int:
        return sum(r.num_rows for r in self)

    @property
    def total_data_cols(self) -> int:
        return sum(r.num_data_cols for r in self)

    @staticmethod
    def from_tables(
        tables: Mapping[str, tuple[Mapping[str, Iterable[Any]], Mapping[str, Iterable[float]]]],
    ) -> "Database":
        """Build a database from ``{name: (key_columns, data_columns)}``.

        Key column values may be any hashable type; they are dictionary-encoded
        per attribute name, shared across relations (so equal values in two
        relations map to the same code — natural-join semantics).
        """
        # Build per-attribute dictionaries across all relations.
        dictionaries: dict[str, dict[Any, int]] = {}
        for _, (key_cols, _) in tables.items():
            for attr, values in key_cols.items():
                d = dictionaries.setdefault(attr, {})
                for v in values:
                    if v not in d:
                        d[v] = len(d)
        relations = {}
        for name, (key_cols, data_cols) in tables.items():
            key_attrs = tuple(key_cols.keys())
            data_attrs = tuple(data_cols.keys())
            if key_attrs:
                keys = np.stack(
                    [np.array([dictionaries[a][v] for v in key_cols[a]], dtype=np.int64)
                     for a in key_attrs], axis=1)
            else:
                nrows = len(next(iter(data_cols.values())))
                keys = np.zeros((nrows, 0), dtype=np.int64)
            data = np.stack([np.asarray(list(data_cols[a]), dtype=np.float64)
                             for a in data_attrs], axis=1) if data_attrs else \
                np.zeros((keys.shape[0], 0))
            relations[name] = Relation(name, key_attrs, data_attrs, keys, data)
        return Database(relations)

    @staticmethod
    def from_arrays(
        tables: Mapping[str, tuple[Mapping[str, np.ndarray], np.ndarray, Sequence[str]]],
    ) -> "Database":
        """Fast path: ``{name: (key_arrays_int, data_matrix, data_attr_names)}``.

        Key arrays must already be non-negative integers with natural-join
        semantics (equal ints join).
        """
        relations = {}
        for name, (key_cols, data, data_attrs) in tables.items():
            key_attrs = tuple(key_cols.keys())
            keys = (np.stack([np.asarray(key_cols[a], dtype=np.int64) for a in key_attrs], axis=1)
                    if key_attrs else np.zeros((data.shape[0], 0), dtype=np.int64))
            relations[name] = Relation(name, key_attrs, tuple(data_attrs), keys,
                                       np.asarray(data))
        return Database(relations)


def encode_database(db: Database) -> Database:
    """Re-encode each key attribute to a dense ``0..card-1`` range (shared per attr)."""
    # Collect the union of values per attribute.
    values: dict[str, np.ndarray] = {}
    for rel in db:
        for a in rel.key_attrs:
            col = rel.key_col(a)
            values[a] = col if a not in values else np.concatenate([values[a], col])
    lut = {a: np.unique(v) for a, v in values.items()}
    relations = {}
    for rel in db:
        cols = [np.searchsorted(lut[a], rel.key_col(a)) for a in rel.key_attrs]
        keys = (np.stack(cols, axis=1) if cols
                else np.zeros((rel.num_rows, 0), dtype=np.int64))
        relations[rel.name] = Relation(rel.name, rel.key_attrs, rel.data_attrs,
                                       keys, rel.data)
    return Database(relations)


def _composite_codes(rel: Relation, attrs: Sequence[str],
                     cards: Mapping[str, int] | None = None) -> np.ndarray:
    """Row-wise composite key over ``attrs`` as a single int64 code (row-major mix).

    ``cards`` must be shared across every relation whose codes are compared
    (otherwise the mixing bases disagree); defaults to this relation's own
    maxima — only safe for single-relation grouping.
    """
    if not attrs:
        return np.zeros(rel.num_rows, dtype=np.int64)
    cols = [rel.key_col(a) for a in attrs]
    if cards is None:
        card_list = [int(c.max()) + 1 if c.size else 1 for c in cols]
    else:
        card_list = [int(cards[a]) for a in attrs]
    total = 1.0
    for c in card_list:
        total *= c
    if total > 2**62:
        raise ValueError("composite key space too large for int64 mixing")
    code = np.zeros(rel.num_rows, dtype=np.int64)
    for col, card in zip(cols, card_list):
        code = code * card + col
    return code


def full_reduce(db: Database, edges: Sequence[tuple[str, str]]) -> Database:
    """Semi-join reduce the database so no dangling tuples remain (Yannakakis).

    ``edges`` are (parent, child) pairs of a join tree. Two sweeps: leaves→root
    then root→leaves, filtering rows whose shared-attr key has no partner.
    """
    rels = dict(db.relations)

    def shared(a: str, b: str) -> tuple[str, ...]:
        return tuple(x for x in rels[a].key_attrs if x in rels[b].key_attrs)

    def semijoin(target: str, source: str) -> None:
        attrs = shared(target, source)
        if not attrs:
            return  # Cartesian edge: no filtering possible/needed.
        t, s = rels[target], rels[source]
        # Shared mixing bases: per-attribute cardinality over BOTH relations.
        cards = {a: max(int(t.key_col(a).max(initial=-1)),
                        int(s.key_col(a).max(initial=-1))) + 1 for a in attrs}
        t_code = _composite_codes(t, attrs, cards)
        s_code = np.unique(_composite_codes(s, attrs, cards))
        mask = np.isin(t_code, s_code)
        rels[target] = t.select_rows(mask)

    # children → parents (bottom-up), then parents → children (top-down).
    for parent, child in reversed(list(edges)):
        semijoin(parent, child)
    for parent, child in edges:
        semijoin(child, parent)
    out = Database(rels)
    for rel in out:
        if rel.num_rows == 0:
            raise ValueError(f"relation {rel.name} is empty after reduction")
    return out
