"""Public QR APIs: FiGaRo end-to-end and materialized-join baselines.

`figaro_qr` is the paper's pipeline: plan → counts → Algorithm 2 → post-process.
`figaro_qr_batched` is the serving form — one compiled dispatch factorizes B
feature-sets over the same join structure. Both are thin delegations onto the
process-wide `repro.api.default_session()` (the `repro.figaro` façade), so
repeat calls with same-signature plans hit its engine's cached executables;
new code should use `figaro.Session` / `JoinDataset` directly.
`materialized_qr` / `givens_qr_r` are the baselines the paper benchmarks
against (LAPACK Householder on the join output / textbook Givens rotations).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .join_tree import FigaroPlan, JoinTree
from .materialize import materialize_join
from .postprocess import householder_qr_r, normalize_sign


def _session():
    # Lazy: repro.api imports repro.core.engine; importing it at module top
    # would cycle through repro.core.__init__ during a cold `import repro.api`.
    from repro.api import default_session

    return default_session()

__all__ = [
    "figaro_qr",
    "figaro_qr_batched",
    "figaro_qr_fn",
    "materialized_qr",
    "givens_qr_r",
    "implicit_q_gram_check",
]


def figaro_qr(
    tree_or_plan: JoinTree | FigaroPlan,
    data=None,
    *,
    dtype=jnp.float32,
    method: str = "tsqr",
    leaf_rows: int = 256,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Upper-triangular R of the QR decomposition of the (unmaterialized) join."""
    return _session().qr(tree_or_plan, data, batched=False, dtype=dtype,
                         method=method, leaf_rows=leaf_rows,
                         use_kernel=use_kernel)


def figaro_qr_batched(
    tree_or_plan: JoinTree | FigaroPlan,
    data_batch,
    *,
    dtype=jnp.float32,
    method: str = "tsqr",
    leaf_rows: int = 256,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """R for a batch of feature-sets over one join structure: ``data_batch[i]``
    is [B, m_i, n_i]; returns [B, N, N] from a single compiled dispatch."""
    return _session().qr(tree_or_plan, data_batch, batched=True, dtype=dtype,
                         method=method, leaf_rows=leaf_rows,
                         use_kernel=use_kernel)


def figaro_qr_fn(plan: FigaroPlan, *, dtype=jnp.float32,
                 method: str = "tsqr", leaf_rows: int = 256,
                 use_kernel: bool = False):
    """A jitted closure ``data_list -> R`` for a fixed plan.

    One compiled program for counts + Algorithm 2 + post-processing, with the
    plan *closed over* so each call dispatches on the data buffers alone —
    the minimum-overhead form wall-clock benchmarks time (compile excluded).
    For plan-generic dispatch (one executable shared across same-signature
    plans, batching, donation) use `FigaroEngine` / `figaro_qr` instead.
    """
    from .figaro import figaro_r0
    from .postprocess import postprocess_r0

    def fn(data):
        r0 = figaro_r0(plan, data, dtype=dtype, use_kernel=use_kernel)
        return postprocess_r0(r0, method=method, leaf_rows=leaf_rows,
                              use_kernel=use_kernel)

    # Deliberately plan-closed: this factory exists for dispatch-minimal
    # wall-clock benchmarks; plan-generic dispatch lives in FigaroEngine.
    return jax.jit(fn)  # figaro-lint: disable=FIG002 -- plan-closed by design


def materialized_qr(tree: JoinTree, *, dtype=jnp.float64,
                    method: str = "lapack") -> jnp.ndarray:
    """Baseline: materialize the join, then classical QR (paper's MKL role)."""
    a = jnp.asarray(materialize_join(tree), dtype=dtype)
    if method == "lapack":
        r = jnp.linalg.qr(a, mode="r")[: a.shape[1]]
    elif method == "householder":
        r = householder_qr_r(a)
    elif method == "givens":
        r = givens_qr_r(a)
    else:
        raise ValueError(method)
    return normalize_sign(r)


def givens_qr_r(a: jnp.ndarray) -> jnp.ndarray:
    """Textbook Givens-rotation QR (one rotation per zeroed entry) -> R.

    The O(mn) rotations × O(n) work each that FiGaRo's block transforms replace.
    Kept for op-count comparisons and accuracy experiments on small inputs.
    """
    m, n = a.shape
    dtype = a.dtype

    def zero_entry(carry, idx):
        a = carry
        i, k = idx  # zero a[i, k] against a[i-1, k]
        xi = a[i - 1, k]
        xj = a[i, k]
        r = jnp.hypot(xi, xj)
        safe = r > 0
        c = jnp.where(safe, xi / jnp.where(safe, r, 1.0), 1.0)
        s = jnp.where(safe, -xj / jnp.where(safe, r, 1.0), 0.0)
        row_i = a[i - 1]
        row_j = a[i]
        a = a.at[i - 1].set(c * row_i - s * row_j)
        a = a.at[i].set(s * row_i + c * row_j)
        return a, None

    # Rotation schedule: for each column k, bubble zeros up from the bottom.
    idx = [(i, k) for k in range(n) for i in range(m - 1, k, -1)]
    if idx:
        idx = jnp.array(idx, dtype=jnp.int32)
        a, _ = jax.lax.scan(zero_entry, a.astype(dtype), idx)
    return jnp.triu(a[:n])


def implicit_q_gram_check(r: jnp.ndarray, gram: jnp.ndarray) -> jnp.ndarray:
    """‖RᵀR − AᵀA‖_F / ‖AᵀA‖_F — orthogonality proxy without materializing Q.

    (The paper computes Q lazily as A·R⁻¹; since Q never needs materializing,
    accuracy is checked on the Gram identity instead.)
    """
    return jnp.linalg.norm(r.T @ r - gram) / jnp.linalg.norm(gram)
