"""FiGaRo core: Givens QR decomposition over relational joins (paper's contribution)."""

from .relation import Database, Relation, full_reduce  # noqa: F401
from .join_tree import JoinTree, FigaroPlan, build_plan  # noqa: F401
from .materialize import materialize_join, join_output_rows  # noqa: F401
from .counts import compute_counts, compute_counts_reference  # noqa: F401
from .heads_tails import (  # noqa: F401
    head, tail, head_tail, segmented_head_tail, givens_sequence,
)
from .figaro import figaro_r0, figaro_r0_batched, figaro_r0_fn  # noqa: F401
from .engine import FigaroEngine, default_engine  # noqa: F401
from .postprocess import (  # noqa: F401
    householder_qr_r, blocked_qr_r, tsqr_r, postprocess_r0, normalize_sign,
)
from .qr import (  # noqa: F401
    figaro_qr, figaro_qr_batched, materialized_qr, givens_qr_r,
)
from .svd import (  # noqa: F401
    svd_over_join, pca_over_join, least_squares_over_join, PCAResult,
)
