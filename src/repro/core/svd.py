"""SVD, PCA, least squares and Cholesky over joins — all read off R (paper §1).

A = Q·R  ⇒  singular values of A == singular values of R; right-singular
vectors of A == those of R; RᵀR is the Cholesky factorization of AᵀA; the
least-squares solution against a label column is back-substitution on the R of
the label-extended matrix. None of it touches the join output.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .counts import compute_counts
from .join_tree import FigaroPlan, JoinTree, build_plan
from .qr import figaro_qr

__all__ = [
    "svd_over_join",
    "pca_over_join",
    "least_squares_over_join",
    "join_column_moments",
    "PCAResult",
]


def svd_over_join(tree_or_plan, *, dtype=jnp.float64, **qr_kwargs):
    """Singular values and right-singular vectors of the join matrix.

    Returns (s [N], Vt [N, N]); the implicit U is A·V·diag(1/s) (never built).
    """
    r = figaro_qr(tree_or_plan, dtype=dtype, **qr_kwargs)
    _, s, vt = jnp.linalg.svd(r)
    return s, vt


@dataclasses.dataclass
class PCAResult:
    components: jnp.ndarray  # [k, N] principal directions (rows)
    explained_variance: jnp.ndarray  # [k]
    mean: jnp.ndarray  # [N] column means over the join
    num_rows: jnp.ndarray  # scalar: |join|


def join_column_moments(plan: FigaroPlan, *, dtype=jnp.float64):
    """Factorized column sums & row count of the join (no materialization).

    Row r of relation i appears in exactly Φ°_i(key(r)) join rows, so
    Σ_join A[:, Y_i] = Σ_r data_i[r] · Φ°_i(key(r)) — a per-node weighted sum.
    """
    counts = compute_counts(plan, dtype=dtype)
    n = plan.num_cols
    sums = jnp.zeros((n,), dtype)
    for nd in plan.nodes:
        if nd.n == 0:
            continue
        w = counts[nd.idx]["phi_circ"][jnp.asarray(nd.row_to_group)]
        s = w @ jnp.asarray(nd.data, dtype)
        sums = sums.at[nd.col_start:nd.col_start + nd.n].add(s)
    total = counts[plan.root]["full"].sum()
    return sums, total


def pca_over_join(tree_or_plan, k: int | None = None, *, center: bool = True,
                  dtype=jnp.float64, **qr_kwargs) -> PCAResult:
    """PCA of the join matrix from R (+ factorized means when centering).

    cov = (AᵀA − J·μμᵀ)/(J−1) = (RᵀR − J·μμᵀ)/(J−1); eigendecomposition of an
    N×N matrix — independent of the join size.
    """
    plan = tree_or_plan if isinstance(tree_or_plan, FigaroPlan) else \
        build_plan(tree_or_plan)
    r = figaro_qr(plan, dtype=dtype, **qr_kwargs)
    n = plan.num_cols
    k = n if k is None else min(k, n)
    sums, total = join_column_moments(plan, dtype=dtype)
    mean = sums / total
    gram = r.T @ r
    if center:
        gram = gram - total * jnp.outer(mean, mean)
    cov = gram / jnp.maximum(total - 1.0, 1.0)
    evals, evecs = jnp.linalg.eigh(cov)  # ascending
    order = jnp.argsort(-evals)[:k]
    return PCAResult(components=evecs[:, order].T,
                     explained_variance=evals[order],
                     mean=mean, num_rows=total)


def least_squares_over_join(tree_or_plan, label_col: int, *,
                            ridge: float = 0.0, dtype=jnp.float64,
                            **qr_kwargs):
    """argmin_β ‖A[:, feats]·β − A[:, label]‖² over the (unmaterialized) join.

    Uses the R of the full matrix: with column order (feats…, label),
    β = R_ff⁻¹ · r_fl. `label_col` indexes the plan's global column layout.

    Returns (beta [N-1], residual_norm) — the closed-form linear-regression
    training the paper cites as the driving ML application.
    """
    plan = tree_or_plan if isinstance(tree_or_plan, FigaroPlan) else \
        build_plan(tree_or_plan)
    r = figaro_qr(plan, dtype=dtype, **qr_kwargs)
    n = plan.num_cols
    feat = jnp.array([j for j in range(n) if j != label_col])
    # Permute label last, re-triangularize the permuted R (cheap: N×N).
    perm = jnp.concatenate([feat, jnp.array([label_col])])
    rp = r[:, perm]
    rr = jnp.linalg.qr(rp, mode="r")[:n]
    r_ff = rr[: n - 1, : n - 1]
    r_fl = rr[: n - 1, n - 1]
    if ridge:
        g = r_ff.T @ r_ff + ridge * jnp.eye(n - 1, dtype=dtype)
        beta = jnp.linalg.solve(g, r_ff.T @ r_fl)
    else:
        beta = jax.scipy.linalg.solve_triangular(r_ff, r_fl, lower=False)
    resid = jnp.abs(rr[n - 1, n - 1])
    return beta, resid
