"""SVD, PCA, least squares and Cholesky over joins — all read off R (paper §1).

A = Q·R  ⇒  singular values of A == singular values of R; right-singular
vectors of A == those of R; RᵀR is the Cholesky factorization of AᵀA; the
least-squares solution against a label column is back-substitution on the R of
the label-extended matrix. None of it touches the join output.

All entry points are thin delegations onto the process-wide
`repro.api.default_session()` (the `repro.figaro` façade): one compiled
executable per plan signature covers plan → counts → rotations → post-process
→ downstream read, and `batched=True` serves a leading batch axis of
feature-sets per dispatch. New code should use `figaro.Session` /
`JoinDataset` (``ds.svd() / ds.pca(k=) / ds.lsq(y)``) directly.
"""

from __future__ import annotations

import jax.numpy as jnp

from .engine import PCAResult
from .join_tree import FigaroPlan


def _session():
    # Lazy: avoids a circular import through repro.core.__init__ (see qr.py).
    from repro.api import default_session

    return default_session()

__all__ = [
    "svd_over_join",
    "pca_over_join",
    "least_squares_over_join",
    "join_column_moments",
    "PCAResult",
]


def svd_over_join(tree_or_plan, data=None, *, batched: bool = False,
                  dtype=jnp.float64, **qr_kwargs):
    """Singular values and right-singular vectors of the join matrix.

    Returns (s [N], Vt [N, N]); the implicit U is A·V·diag(1/s) (never built).
    With ``batched=True`` and [B, m_i, n_i] data leaves: (s [B, N], Vt [B, N, N]).
    """
    return _session().svd(tree_or_plan, data, batched=batched, dtype=dtype,
                          **qr_kwargs)


def join_column_moments(plan: FigaroPlan, data=None, *, dtype=jnp.float64):
    """Factorized column sums & row count of the join (no materialization).

    Row r of relation i appears in exactly Φ°_i(key(r)) join rows, so
    Σ_join A[:, Y_i] = Σ_r data_i[r] · Φ°_i(key(r)) — a per-node weighted sum.
    """
    from .engine import _column_moments

    if data is None:
        data = plan.data
    return _column_moments(plan, data, dtype)


def pca_over_join(tree_or_plan, k: int | None = None, *, data=None,
                  center: bool = True, dtype=jnp.float64,
                  **qr_kwargs) -> PCAResult:
    """PCA of the join matrix from R (+ factorized means when centering).

    cov = (AᵀA − J·μμᵀ)/(J−1) = (RᵀR − J·μμᵀ)/(J−1); eigendecomposition of an
    N×N matrix — independent of the join size.
    """
    return _session().pca(tree_or_plan, data, k=k, center=center,
                          dtype=dtype, **qr_kwargs)


def least_squares_over_join(tree_or_plan, label_col: int, *, data=None,
                            ridge: float = 0.0, dtype=jnp.float64,
                            **qr_kwargs):
    """argmin_β ‖A[:, feats]·β − A[:, label]‖² over the (unmaterialized) join.

    Uses the R of the full matrix: with column order (feats…, label),
    β = R_ff⁻¹ · r_fl. `label_col` indexes the plan's global column layout.

    Returns (beta [N-1], residual_norm) — the closed-form linear-regression
    training the paper cites as the driving ML application.
    """
    return _session().least_squares(tree_or_plan, label_col, data,
                                    ridge=ridge, dtype=dtype, **qr_kwargs)
