"""`repro.figaro` — the public façade of the join-factorization stack.

Alias of `repro.api` (kept separate so ``from repro import figaro`` reads
like the paper: ``figaro.Session``, ``sess.ingest(...).join(...)``,
``ds.qr() / ds.svd() / ds.pca() / ds.lsq()``). See `repro.api` for the full
API reference and the legacy -> Session migration table.

Not to be confused with `repro.core.figaro`, the Algorithm-2 kernel this
façade ultimately dispatches.
"""

from repro.api import (JoinDataset, Session, TableSet,  # noqa: F401
                       default_session)
from repro.core.engine import FigaroEngine, PCAResult  # noqa: F401
from repro.core.plan_cache import PlanHolder  # noqa: F401
from repro.train.async_serve import (AsyncFigaroServer,  # noqa: F401
                                     FigaroFuture, SERVE_KINDS)

__all__ = ["Session", "TableSet", "JoinDataset", "default_session",
           "FigaroEngine", "PCAResult", "PlanHolder", "AsyncFigaroServer",
           "FigaroFuture", "SERVE_KINDS"]
