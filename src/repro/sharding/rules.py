"""Parameter/activation PartitionSpecs per architecture family.

Axis roles (launch/mesh.py):
  pod    — outermost data parallelism (multi-pod; gradient-compression boundary)
  data   — data parallelism; also FSDP weight sharding when ``cfg.fsdp``
  model  — tensor parallelism (attention heads, ff, vocab) and expert
           parallelism (when num_experts % |model| == 0)

KV-head caveat: the assigned archs have kv=8 < |model|=16, so KV projections
are replicated over `model` (standard GQA practice) while Q heads shard.

Scan-stacked block params carry a leading [n_blocks] axis -> specs get a
leading None.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["param_specs", "param_shardings", "batch_specs", "data_axes"]


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _join(*axes):
    """Combine axis names into one PartitionSpec entry (drop Nones)."""
    axes = tuple(a for a in axes if a is not None)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _rules(cfg: ModelConfig, mesh: Mesh) -> list[tuple[str, P]]:
    fs = "data" if cfg.fsdp and "data" in mesh.shape else None
    msz = mesh.shape.get("model", 1)
    kv_ok = cfg.n_kv_heads % msz == 0
    ep = cfg.moe is not None and cfg.moe.num_experts % msz == 0
    hd_heads = cfg.n_heads % msz == 0
    rw_heads = (cfg.d_model // (cfg.rwkv.head_size if cfg.rwkv else 64)) \
        % msz == 0
    # Column-parallel attention (heads over `model`) when head counts divide
    # the axis; otherwise row-parallel fallback (d_model over model(+data)) —
    # arctic/llava (56H), minicpm (36H), whisper (6H) on a 16-way axis.
    if hd_heads:
        wq = P(fs, "model", None)
        wo = P("model", None, fs)
    else:
        wq = P(_join("model", fs), None, None)
        wo = P(None, None, _join("model", fs))
    wkv = P(fs, "model", None) if kv_ok else \
        (P(fs, None, None) if hd_heads else P(_join("model", fs), None, None))
    return [
        (r"embed$", P("model", fs)),
        (r"lm_head$", P(fs, "model")),
        (r"patch_proj$", P(fs, "model")),
        (r"enc_pos$", P()),
        # attention
        (r"(mixer|cross)/wq$", wq),
        (r"(mixer|cross)/wk$", wkv),
        (r"(mixer|cross)/wv$", wkv),
        (r"(mixer|cross)/wo$", wo),
        (r"(q_norm|k_norm)$", P()),
        # dense mlp
        (r"mlp/w_gate$", P(fs, "model")),
        (r"mlp/w_up$", P(fs, "model")),
        (r"mlp/w_down$", P("model", fs)),
        # moe
        (r"moe/router$", P(fs, None)),
        (r"moe/w_gate$", P("model", fs, None) if ep else P(None, fs, "model")),
        (r"moe/w_up$", P("model", fs, None) if ep else P(None, fs, "model")),
        (r"moe/w_down$", P("model", None, fs) if ep else P(None, "model", fs)),
        # mamba
        (r"mixer/in_proj$", P(fs, "model")),
        (r"mixer/conv_w$", P(None, "model")),
        (r"mixer/conv_b$", P("model")),
        (r"mixer/x_proj$", P("model", None)),
        (r"mixer/dt_proj$", P(None, "model")),
        (r"mixer/dt_bias$", P("model")),
        (r"mixer/a_log$", P("model", None)),
        (r"mixer/d_skip$", P("model")),
        (r"mixer/out_proj$", P("model", fs)),
        # rwkv6 time-mix
        (r"mixer/w[rkvg]$", P(fs, "model")),
        (r"mixer/wo$", P("model", fs)),
        (r"mixer/bonus$", P("model" if rw_heads else None, None)),
        (r"mixer/(mu|mix_w1|mix_w2|w0|decay_w1|decay_w2|ln_x)$", P()),
        # rwkv channel-mix (under mlp/)
        (r"mlp/wk$", P(fs, "model")),
        (r"mlp/wv$", P("model", fs)),
        (r"mlp/wr$", P(fs, "model")),
        (r"mlp/(mu_k|mu_r)$", P()),
        # norms & leftovers
        (r"(norm1|norm2|norm_x|final_norm|enc_norm)/", P()),
        (r".*", P()),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any):
    """PartitionSpec pytree matching ``params_shape`` (shapes or arrays)."""
    rules = _rules(cfg, mesh)

    def spec_for(path, leaf) -> P:
        s = _path_str(path)
        stacked = bool(re.search(r"(^|/)(blocks|encoder)/", s))
        for pat, spec in rules:
            if re.search(pat, s):
                parts = list(spec)
                if stacked:
                    parts = [None] + parts
                ndim = len(leaf.shape)
                parts = parts[:ndim] + [None] * (ndim - len(parts))
                # Drop axis shardings that do not divide the dim at all
                # (uneven is fine — zero-size shards are not).
                fixed = []
                for dim, ax in zip(leaf.shape, parts):
                    if ax is None:
                        fixed.append(None)
                        continue
                    axsz = mesh.shape[ax] if isinstance(ax, str) else \
                        max(mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,)))
                    fixed.append(ax if dim >= axsz else None)
                return P(*fixed)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Any):
    specs = param_specs(cfg, mesh, params_shape)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, mesh: Mesh, *, shard_seq: bool = False):
    """Input shardings: batch over (pod, data); optionally seq over data
    (context-parallel long-context decode with global_batch=1)."""
    dp = data_axes(mesh)
    if shard_seq:
        return {"tokens": P(None, None)}
    return {
        "tokens": P(dp, None),
        "frames": P(dp, None, None),
        "patches": P(dp, None, None),
        "loss_mask": P(dp, None),
    }
