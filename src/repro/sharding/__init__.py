from .rules import param_specs, param_shardings, batch_specs, data_axes  # noqa: F401
