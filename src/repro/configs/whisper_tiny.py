"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (precomputed frames).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356].
Backbone only per the task spec: `input_specs()` supplies [B, 1500, d] frame
embeddings (the conv1d stack is a stub); 4 encoder + 4 decoder layers,
LayerNorm. Adaptation note (DESIGN.md): decoder uses RoPE instead of learned
positional embeddings; encoder keeps learned positions.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    n_blocks=4, block=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    encoder_blocks=4, encoder_block=(LayerSpec(mixer="attn", mlp="dense"),),
    encoder_len=1500, norm="layer",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    encoder_blocks=2, encoder_block=(LayerSpec(mixer="attn", mlp="dense"),),
    encoder_len=16, norm="layer", remat=False,
)
