"""Architecture registry: the 10 assigned archs (+ reduced smoke variants)."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec  # noqa: F401

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-tiny": "whisper_tiny",
    "arctic-480b": "arctic_480b",
    "mixtral-8x22b": "mixtral_8x22b",
    "minicpm-2b": "minicpm_2b",
    "command-r-35b": "command_r_35b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-8b": "qwen3_8b",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_NAMES: list[str] = list(_MODULES)


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Which (arch x shape) dry-run cells run; skips per the task spec."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k needs sub-quadratic attention; skipped for "
                       "pure full-attention archs (see DESIGN.md "
                       "§Arch-applicability)")
    return True, ""
