"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887; hf].
Super-block of 8 layers: attention at position 3, Mamba elsewhere; MoE on odd
positions (every other layer), dense MLP on even — 4 scanned super-blocks.
"""

from repro.models.config import LayerSpec, MambaConfig, ModelConfig, MoEConfig


def _block() -> tuple[LayerSpec, ...]:
    specs = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(specs)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
    n_blocks=4, block=_block(),
    moe=MoEConfig(num_experts=16, top_k=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    fsdp=True,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_blocks=1, block=_block(),
    moe=MoEConfig(num_experts=4, top_k=2),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, dt_rank=8),
    remat=False,
)
