"""llava-next-34b [vlm]: anyres tiling, vision frontend stubbed.

60L d_model=7168 56H (kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf]. Backbone only per the task spec:
`input_specs()` supplies precomputed patch embeddings [B, 2880, d] (anyres =
5 tiles x 576 patches); the vision tower is a stub. Patches prepend the text
sequence; loss masks them out.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000,
    n_blocks=60, block=(LayerSpec(mixer="attn", mlp="dense"),),
    patch_positions=2880, fsdp=True,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense"),),
    patch_positions=8, remat=False,
)
