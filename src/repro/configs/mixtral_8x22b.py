"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088].
SWA window 4096 => ring-buffer KV cache (the reason long_500k is runnable).
"""

from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    n_blocks=56, block=(LayerSpec(mixer="attn", mlp="moe"),),
    moe=MoEConfig(num_experts=8, top_k=2),
    swa_window=4096, fsdp=True,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="moe"),),
    moe=MoEConfig(num_experts=4, top_k=2),
    swa_window=8, remat=False,
)
