"""qwen3-8b [dense]: qk-norm, GQA.

36L d_model=4096 32H (kv=8) d_ff=12288 vocab=151936 [hf:Qwen/Qwen3-8B].
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288, vocab=151936,
    n_blocks=36, block=(LayerSpec(mixer="attn", mlp="dense"),),
    qk_norm=True,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense"),),
    qk_norm=True, remat=False,
)
