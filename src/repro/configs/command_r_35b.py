"""command-r-35b [dense]: GQA, no-bias, 256k vocab.

40L d_model=8192 64H (kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01].
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000,
    n_blocks=40, block=(LayerSpec(mixer="attn", mlp="dense"),),
    fsdp=True,
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense"),),
    remat=False,
)
