"""granite-3-8b [dense]: GQA.

40L d_model=4096 32H (kv=8) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base].
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800, vocab=49155,
    n_blocks=40, block=(LayerSpec(mixer="attn", mlp="dense"),),
    fsdp=True,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense"),),
    remat=False,
)
