"""arctic-480b [moe]: 128 experts top-2 + dense residual (parallel).

35L d_model=7168 56H (kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base]. Dense-MoE hybrid: every layer runs a
dense SwiGLU residual in parallel with the routed MoE (`mlp="dense+moe"`).
~0.5T params: bf16 params + bf16 optimizer moments + FSDP over the data axis
(see EXPERIMENTS.md for the single-pod memory verdict).
"""

from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
    n_blocks=35, block=(LayerSpec(mixer="attn", mlp="dense+moe"),),
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25),
    fsdp=True, param_dtype="bfloat16", opt_state_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense+moe"),),
    moe=MoEConfig(num_experts=4, top_k=2),
    remat=False,
)
