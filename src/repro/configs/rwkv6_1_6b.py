"""rwkv6-1.6b [ssm] "Finch": attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892]. head_size=64
(32 heads). Time-mix (wkv6) + channel-mix per layer; O(1)-state decode
(long_500k is the showcase shape).
"""

from repro.models.config import LayerSpec, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536,
    n_blocks=24, block=(LayerSpec(mixer="rwkv6", mlp="rwkv_cmix"),),
    rwkv=RWKVConfig(head_size=64),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    d_model=64, n_heads=8, n_kv_heads=8, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="rwkv6", mlp="rwkv_cmix"),),
    rwkv=RWKVConfig(head_size=8, lora_decay=8, lora_mix=4),
    remat=False,
)
