"""minicpm-2b [dense]: llama-like, MHA (kv=36), tied embeddings, WSD schedule.

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753 [arXiv:2404.06395].
The WSD (warmup-stable-decay) schedule lives in optim/schedules.py and is the
default for this arch in launch/train.py.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760, vocab=122753,
    n_blocks=40, block=(LayerSpec(mixer="attn", mlp="dense"),),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    n_blocks=2, block=(LayerSpec(mixer="attn", mlp="dense"),),
    tie_embeddings=True, remat=False,
)
