"""AdamW with global-norm clipping and configurable state dtype.

Self-contained (no optax in this environment). Moments are stored in
``cfg.opt_state_dtype`` — the 0.5T-param arctic config uses bf16 moments +
FSDP to fit (EXPERIMENTS.md §Dry-run discusses the memory verdict).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    dt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    out = jax.tree_util.tree_map(upd, grads, opt_state["mu"],
                                 opt_state["nu"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
