"""QR-orthogonalized optimizer updates — a beyond-paper use of FiGaRo's TSQR.

Muon-style orthogonalization of 2-D weight updates, but via the R factor from
the paper's post-processing machinery instead of Newton–Schulz iterations:
``orth(G) = G·R⁻¹`` where ``G = QR`` (so orth(G) = Q, the closest orthonormal
frame in the polar-ish sense for well-conditioned G). The R factor comes from
`core.postprocess.tsqr_r` — on a mesh, from `core.distributed.distributed_qr_r`
— i.e. the exact THIN/TSQR code path the paper uses for R₀ post-processing.

Opt-in (off by default) so the paper-faithful baseline stays clean.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.postprocess import tsqr_r


def orthogonalize(g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Return Q of the thin QR of g (tall orientation), via TSQR."""
    m, n = g.shape
    transpose = m < n
    a = g.T if transpose else g
    a32 = a.astype(jnp.float32)
    r = tsqr_r(a32, leaf_rows=max(256, a.shape[1]))
    # Solve a = q r  =>  q = a r^-1 (triangular solve, regularized).
    rr = r + eps * jnp.eye(r.shape[0], dtype=r.dtype)
    q = jax.scipy.linalg.solve_triangular(rr, a32.T, lower=False, trans=1).T
    q = q * jnp.sqrt(jnp.asarray(q.shape[1], jnp.float32))  # RMS-norm scale
    out = q.T if transpose else q
    return out.astype(g.dtype)


def orthogonalized_update(grads: Any, *, min_dim: int = 2) -> Any:
    """Apply TSQR orthogonalization to every 2-D leaf (others unchanged)."""

    def one(path, g):
        if g.ndim == 2 and min(g.shape) >= min_dim:
            return orthogonalize(g)
        if g.ndim == 3:  # scan-stacked [n_blocks, a, b]
            return jax.vmap(orthogonalize)(g)
        return g

    return jax.tree_util.tree_map_with_path(one, grads)
