"""LR schedules: cosine and WSD (warmup-stable-decay, the minicpm schedule)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return fn


def wsd(peak: float, warmup: int, stable: int, decay: int,
        floor: float = 0.01):
    """MiniCPM's warmup-stable-decay: linear warmup, flat plateau, then an
    exponential-ish decay tail — enables continued pretraining from the
    plateau (arXiv:2404.06395)."""

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        tail = peak * jnp.exp(jnp.log(jnp.maximum(floor, 1e-8)) * t)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, peak, tail))

    return fn
