from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm  # noqa: F401
from .schedules import warmup_cosine, wsd  # noqa: F401
from .compression import compressed_psum, init_residual  # noqa: F401
from .orthogonal import orthogonalize, orthogonalized_update  # noqa: F401
