"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 1000+ nodes the cross-pod (DCN) all-reduce dominates step time; int8
quantization with error feedback cuts those bytes 4x at negligible quality
cost. Used inside `shard_map` over the `pod` axis (launch/train.py flag
``--grad-compression``); the within-pod reduction stays full precision.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads: Any, residual: Any, axis: str) -> tuple[Any, Any]:
    """All-reduce mean of ``grads`` over ``axis`` in int8 with error feedback.

    Returns (reduced grads, new residual). The residual carries this step's
    quantization error into the next step (error feedback guarantees the
    compression bias telescopes away).
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, scale = _quantize(gf)
        err = gf - q.astype(jnp.float32) * scale
        # int8 payload all-reduce (sum), scales all-gathered (tiny).
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.pmean(scale, axis)  # shared scale approximation
        out = qsum.astype(jnp.float32) * ssum / axis_size(axis)
        return out.astype(g.dtype), err.astype(r.dtype)

    out = jax.tree_util.tree_map(one, grads, residual)
    red = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return red, res


def init_residual(grads_shape: Any, dtype=jnp.float32) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, dtype), grads_shape)
