"""Deterministic, resumable token pipeline (synthetic corpus).

Production properties this models:
  * **Deterministic skip-ahead**: batch at step s is a pure function of
    (seed, s) — resuming from a checkpoint at step s replays nothing.
  * **Per-host sharding**: each host draws only its slice of the global batch
    (``host_id``/``num_hosts``), so a straggler host only delays its own feed.
  * **Prefetch**: a background thread keeps a small queue of ready batches.

The synthetic corpus is a mixture of a Zipf unigram stream and short repeated
motifs — enough signal that a ~10M-param model visibly learns (loss drops)
in examples/train_lm.py.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

from repro.sanitizer.threads import san_thread

import numpy as np

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2):
        assert global_batch % num_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- deterministic batch synthesis ---------------------------------------

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s, v = self.local_batch, self.seq_len, self.vocab
        # Zipf-ish unigrams
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks ** 1.1
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, s), p=probs)
        # Inject repeated motifs (learnable bigram structure).
        motif = rng.integers(0, v, size=(8,))
        for i in range(b):
            pos = rng.integers(0, max(s - 16, 1))
            reps = (s - pos) // 8
            if reps > 0:
                toks[i, pos:pos + 8 * min(reps, 2)] = np.tile(
                    motif, min(reps, 2))
        return {"tokens": toks.astype(np.int32)}

    # -- prefetching iterator -------------------------------------------------

    def start(self, start_step: int) -> Iterator[dict[str, np.ndarray]]:
        self._stop.clear()

        def producer():
            s = start_step
            while not self._stop.is_set():
                try:
                    self._queue.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._thread = san_thread(producer, daemon=True)
        self._thread.start()

        def consumer():
            while True:
                yield self._queue.get()

        return consumer()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
