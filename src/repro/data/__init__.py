from .pipeline import TokenPipeline  # noqa: F401
from . import relational  # noqa: F401
