"""Relational dataset generators shaped like the paper's benchmarks (§8).

Scaled-down analogues of the three real datasets (Table 1):

  * ``retailer_like``  — snowflake: fact Inventory(location, item, date) with
    dimension chains Location->Census and Item, Weather (key-fkey).
  * ``favorita_like``  — star: fact Sales with dimensions Stores, Items,
    Transactions, Oil, Holidays (key-fkey).
  * ``yelp_like``      — star with *many-to-many* joins: Review(user, business)
    against User and Business x (Category, CheckIn, Hours): join >> input.
  * ``cartesian``      — two relations, join == Cartesian product (§1.1 and
    the Fig-5 / Tab-3 synthetic experiments).
  * ``accuracy_db``    — the reverse-engineering construction of Exp 4: a
    database whose join-QR has a *known ground-truth* R block.

Sizes are parameterized so benchmarks can sweep "percentage of dataset"
exactly like Fig 4.

Every generator returns a ready `JoinTree`; the one-liner onto the
`repro.figaro` façade is::

    from repro import figaro
    from repro.data.relational import retailer_like

    ds = figaro.Session().from_tree(retailer_like(scale=1000))
    r = ds.qr()                      # or ds.svd() / ds.pca(k=) / ds.lsq(y)
"""

from __future__ import annotations

import numpy as np

from repro.core.join_tree import JoinTree
from repro.core.relation import Database, full_reduce

__all__ = ["retailer_like", "favorita_like", "yelp_like", "cartesian",
           "accuracy_db"]


def _rand_data(rng, m, n):
    return rng.uniform(-3.0, 3.0, size=(m, n))  # paper's U[-3, 3)


def retailer_like(scale: int = 1000, *, cols: int = 4, seed: int = 0,
                  root: str = "good") -> JoinTree:
    """Snowflake; `root` in {good, bad} mirrors Table 2's join-tree choice,
    and ``root="auto"`` lets figaro-plan (`repro.planner.choose_root`) pick —
    on this schema it recovers the paper's good orientation.

    ``figaro.Session().from_tree(retailer_like(...))`` gives the fluent
    compute handle (examples/join_ml.py runs all three ML tasks off it).
    """
    rng = np.random.default_rng(seed)
    n_loc, n_item, n_date = max(scale // 50, 4), max(scale // 20, 6), \
        max(scale // 10, 8)
    m_fact = scale * 4
    tables = {
        "Inventory": ({"loc": rng.integers(0, n_loc, m_fact),
                       "item": rng.integers(0, n_item, m_fact),
                       "date": rng.integers(0, n_date, m_fact)},
                      _rand_data(rng, m_fact, 1), ["inv0"]),
        "Location": ({"loc": np.arange(n_loc),
                      "zip": rng.integers(0, max(n_loc // 2, 2), n_loc)},
                     _rand_data(rng, n_loc, cols), [f"l{i}" for i in range(cols)]),
        "Census": ({"zip": np.arange(max(n_loc // 2, 2))},
                   _rand_data(rng, max(n_loc // 2, 2), cols),
                   [f"c{i}" for i in range(cols)]),
        "Item": ({"item": np.arange(n_item)},
                 _rand_data(rng, n_item, cols), [f"i{i}" for i in range(cols)]),
        "Weather": ({"loc": np.repeat(np.arange(n_loc), n_date // 2 or 1),
                     "date": np.tile(np.arange(n_date // 2 or 1), n_loc)},
                    _rand_data(rng, n_loc * (n_date // 2 or 1), cols),
                    [f"w{i}" for i in range(cols)]),
    }
    db = Database.from_arrays(tables)
    if root in ("good", "auto"):
        edges = [("Inventory", "Item"), ("Inventory", "Weather"),
                 ("Inventory", "Location"), ("Location", "Census")]
        rootn = "Inventory"
    else:  # bad: fact table deep in the tree
        edges = [("Location", "Census"), ("Location", "Inventory"),
                 ("Inventory", "Item"), ("Inventory", "Weather")]
        rootn = "Location"
    db = full_reduce(db, edges)
    if root == "auto":
        from repro.planner import choose_root  # jax-free, no import cycle

        rootn = choose_root(db, edges)
    return JoinTree.from_edges(db, rootn, edges)


def favorita_like(scale: int = 1000, *, cols: int = 3, seed: int = 1) -> JoinTree:
    rng = np.random.default_rng(seed)
    n_store, n_item, n_date = max(scale // 40, 4), max(scale // 20, 5), \
        max(scale // 10, 8)
    m = scale * 4
    tables = {
        "Sales": ({"store": rng.integers(0, n_store, m),
                   "item": rng.integers(0, n_item, m),
                   "date": rng.integers(0, n_date, m)},
                  _rand_data(rng, m, 1), ["units"]),
        "Stores": ({"store": np.arange(n_store)},
                   _rand_data(rng, n_store, cols), [f"s{i}" for i in range(cols)]),
        "Items": ({"item": np.arange(n_item)},
                  _rand_data(rng, n_item, cols), [f"i{i}" for i in range(cols)]),
        "Transactions": ({"store": np.repeat(np.arange(n_store), n_date),
                          "date": np.tile(np.arange(n_date), n_store)},
                         _rand_data(rng, n_store * n_date, 1), ["txn"]),
        "Oil": ({"date": np.arange(n_date)},
                _rand_data(rng, n_date, 1), ["oil"]),
        "Holidays": ({"date": np.arange(n_date)},
                     _rand_data(rng, n_date, 1), ["hol"]),
    }
    db = Database.from_arrays(tables)
    edges = [("Sales", "Stores"), ("Sales", "Items"),
             ("Sales", "Transactions"), ("Transactions", "Oil"),
             ("Oil", "Holidays")]
    # Oil->Holidays keeps the tree a snowflake over `date` without making
    # Sales the only hub (both share `date`; join-tree property holds).
    db = full_reduce(db, edges)
    return JoinTree.from_edges(db, "Sales", edges)


def yelp_like(scale: int = 300, *, cols: int = 3, seed: int = 2) -> JoinTree:
    """Many-to-many: |join| >> |input| (the paper's best-case regime).

    The api parity suite (tests/test_api.py) pins the `figaro.Session` path
    bit-identical to the legacy entry points on this schema.
    """
    rng = np.random.default_rng(seed)
    n_user, n_biz = max(scale // 10, 5), max(scale // 15, 4)
    m_rev = scale * 2
    tables = {
        "Review": ({"user": rng.integers(0, n_user, m_rev),
                    "biz": rng.integers(0, n_biz, m_rev)},
                   _rand_data(rng, m_rev, 1), ["stars"]),
        "User": ({"user": np.arange(n_user)},
                 _rand_data(rng, n_user, cols), [f"u{i}" for i in range(cols)]),
        "Business": ({"biz": np.arange(n_biz)},
                     _rand_data(rng, n_biz, cols), [f"b{i}" for i in range(cols)]),
        # many-to-many: several categories / checkins per business
        "Category": ({"biz": rng.integers(0, n_biz, n_biz * 5)},
                     _rand_data(rng, n_biz * 5, 1), ["cat"]),
        "CheckIn": ({"biz": rng.integers(0, n_biz, n_biz * 7)},
                    _rand_data(rng, n_biz * 7, 1), ["chk"]),
    }
    db = Database.from_arrays(tables)
    edges = [("Review", "User"), ("Review", "Business"),
             ("Business", "Category"), ("Business", "CheckIn")]
    db = full_reduce(db, edges)
    return JoinTree.from_edges(db, "Review", edges)


def cartesian(p: int, q: int, *, n1: int = 2, n2: int = 2,
              seed: int = 3) -> JoinTree:
    rng = np.random.default_rng(seed)
    tables = {
        "S": ({}, _rand_data(rng, p, n1), [f"s{i}" for i in range(n1)]),
        "T": ({}, _rand_data(rng, q, n2), [f"t{i}" for i in range(n2)]),
    }
    db = Database.from_arrays(tables)
    return JoinTree.from_edges(db, "S", [("S", "T")])


def accuracy_db(p: int, q: int, n: int, *, seed: int = 4
                ) -> tuple[JoinTree, np.ndarray]:
    """Exp-4 construction: returns (tree, R_fixed ground truth).

    T := Q_T·R_fixed/√p for a random orthonormal Q_T and a chosen
    upper-triangular R_fixed; S gets zero column sums, so the exact R of the
    Cartesian product S×T is block-diagonal with the T-block equal to
    √p·(R_fixed/√p) = R_fixed — the arbitrary ground truth of Table 3.
    """
    rng = np.random.default_rng(seed)
    r_fixed = np.triu(rng.normal(size=(n, n)))
    r_fixed[np.diag_indices(n)] = np.abs(r_fixed[np.diag_indices(n)]) + 0.5
    qmat, _ = np.linalg.qr(rng.normal(size=(q, n)))
    t_mat = qmat @ (r_fixed / np.sqrt(p))
    s_mat = rng.normal(size=(p, n))
    s_mat -= s_mat.mean(axis=0, keepdims=True)  # zero column sums
    tables = {
        "S": ({}, s_mat, [f"s{i}" for i in range(n)]),
        "T": ({}, t_mat, [f"t{i}" for i in range(n)]),
    }
    db = Database.from_arrays(tables)
    tree = JoinTree.from_edges(db, "S", [("S", "T")])
    return tree, r_fixed
