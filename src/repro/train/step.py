"""Train/eval steps: sharded loss+grad+update with optional microbatching.

``make_train_step`` returns a function suitable both for real execution and
for the dry-run's ``jax.jit(...).lower().compile()`` — all sharding is
declared via in_shardings (params/opt-state from sharding/rules.py, batch
from batch_specs) and activation constraints at block boundaries.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.orthogonal import orthogonalized_update
from repro.sharding.rules import data_axes

__all__ = ["TrainState", "init_state", "make_train_step", "make_eval_step"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig) -> TrainState:
    params = tf.init_params(key, cfg)
    return TrainState(params=params, opt_state=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def _constrain_batch(batch, mesh: Mesh):
    dp = data_axes(mesh)

    def c(x):
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)

    return jax.tree_util.tree_map(c, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    *,
    microbatch: int | None = None,
    orthogonal_update: bool = False,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Build the jittable train step (fwd+bwd+AdamW update).

    ``microbatch``: split the per-step batch into this many sequential
    micro-steps with gradient accumulation (lax.scan) — compute/memory knob.
    ``orthogonal_update``: TSQR-orthogonalize 2-D gradients (beyond-paper,
    powered by the paper's THIN machinery; see optim/orthogonal.py).
    """

    def loss(params, batch):
        return tf.loss_fn(params, cfg, batch)

    def grads_of(params, batch):
        (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return l, metrics, g

    def step_fn(state: TrainState, batch: Any):
        batch = _constrain_batch(batch, mesh)
        if microbatch and microbatch > 1:
            dp = data_axes(mesh)

            def split(x):
                b = x.shape[0]
                # (B,) -> (B/micro, micro) -> (micro, B/micro): row j*micro+m
                # lands in micro m, so every micro-step draws one row per
                # device block — the batch dim stays sharded over `dp` and the
                # sequential micro axis stays unpartitioned.
                x = x.reshape((b // microbatch, microbatch) + x.shape[1:])
                x = jnp.swapaxes(x, 0, 1)
                return jax.lax.with_sharding_constraint(
                    x, P(None, dp, *([None] * (x.ndim - 2))))

            micro = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb):
                l, m, g = grads_of(state.params, mb)
                gsum, lsum = carry
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), ms = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            g = jax.tree_util.tree_map(lambda x: x / microbatch, gsum)
            l = lsum / microbatch
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        else:
            l, metrics, g = grads_of(state.params, batch)
        if orthogonal_update:
            g = orthogonalized_update(g)
        new_params, new_opt, opt_metrics = adamw_update(
            g, state.opt_state, state.params, opt_cfg)
        metrics = dict(metrics, loss=l, **opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn


def make_eval_step(cfg: ModelConfig, mesh: Mesh):
    def eval_fn(params, batch):
        batch = _constrain_batch(batch, mesh)
        loss, metrics = tf.loss_fn(params, cfg, batch)
        return dict(metrics, loss=loss)

    return eval_fn
