"""Async-first FiGaRo serving: request queue, futures, pipelined dispatch.

The paper's serving leverage — one cached Givens pipeline answering many
users' feature-sets over a fixed join structure — needs more than a blocking
callable: with one-shot synchronous dispatch, host-side request prep, H2D
transfer, executable launch, and result readback all serialize, and callers
must hand-assemble full batches themselves. `AsyncFigaroServer` turns the
serving layer into a small pipeline:

  * ``submit(request) -> FigaroFuture`` enqueues one request (per-node
    [m_i, n_i] leaves) or a sub-batch ([B, m_i, n_i] leaves, B=0 included)
    onto a micro-batching queue;
  * a dispatcher thread coalesces pending requests up to ``max_batch`` rows,
    pads the coalesced batch to its bucketed capacity
    (`launch.mesh.serving_batch_capacity` — powers of two, aligned to the
    serving mesh axis) and dispatches through the `FigaroEngine`. Because
    jax dispatch is asynchronous, with ``queue_depth >= 2`` the *next*
    batch's staging (`engine.stage` — H2D of donated input slabs) overlaps
    the in-flight executable: engine-level double buffering;
  * a completion thread blocks on readiness and resolves futures strictly in
    submission order. Exceptions propagate per-request: a request that fails
    validation resolves only its own future, and if a coalesced dispatch
    fails at run time, each batched request is re-dispatched alone so one
    poisoned request cannot fail its batchmates;
  * ``append(node, rows)`` joins the same stream — it drains in-flight work,
    then refreshes the shared `plan_cache.PlanHolder` (zero retraces while
    live sizes stay within capacity), so the owning `JoinDataset`'s plan and
    ``stats()`` never fork from the server's.

The synchronous `FigaroServer` (`train.serve`) remains as a thin
``submit(...).result()`` wrapper over this machinery.
"""

from __future__ import annotations

import concurrent.futures
import functools
import queue
import threading
import weakref

import jax
import numpy as np

from repro.core.join_tree import FigaroPlan
from repro.core.plan_cache import PlanHolder, pad_data
from repro.sanitizer.locks import san_condition, san_lock
from repro.sanitizer.races import shared_state
from repro.sanitizer.threads import san_thread

__all__ = ["SERVE_KINDS", "validate_serve_kind", "FigaroFuture",
           "AsyncFigaroServer"]

#: The serving kinds every serving surface supports (`make_figaro_server`,
#: `Session.serve`, `JoinDataset.serve`) — validated eagerly, in one place.
SERVE_KINDS = ("qr", "svd", "pca", "lsq")


def validate_serve_kind(kind: str, *, label_col=None,
                        check_label: bool = False) -> None:
    """Eager serve-kind validation shared by every serving entry point.

    A bad ``kind`` must fail at construction with the full list of supported
    kinds — not at (or after) the first dispatch. ``check_label=True`` also
    enforces the lsq label requirement.
    """
    if kind not in SERVE_KINDS:
        raise ValueError(f"unknown serve kind {kind!r}; supported kinds: "
                         f"{', '.join(SERVE_KINDS)}")
    if check_label and kind == "lsq" and label_col is None:
        raise ValueError("kind='lsq' needs label_col")


class FigaroFuture(concurrent.futures.Future):
    """Result handle for one submitted request (or sub-batch).

    A thin `concurrent.futures.Future` (stdlib semantics for
    ``result(timeout)`` / ``exception(timeout)`` / ``done()`` /
    ``add_done_callback``), resolved by the server's completion thread in
    submission order. ``result()`` re-raises the request's own exception if
    it failed — validation errors and poisoned-dispatch errors are
    per-request, batchmates are unaffected.
    """

    def _resolve(self, value=None, error: BaseException | None = None):
        if error is not None:
            self.set_exception(error)
        else:
            self.set_result(value)


class _Request:
    """One queue entry: a validated (or failed-at-validation) request."""

    __slots__ = ("future", "arrays", "b", "single", "sig", "plan", "error")

    def __init__(self):
        self.future = FigaroFuture()
        self.arrays = None  # capacity-shaped [b, m_i, n_i] leaves
        self.b = 0
        self.single = False  # squeeze the leading axis on resolve
        self.sig = None  # coalescing-compatibility key
        self.plan: FigaroPlan | None = None
        self.error: BaseException | None = None

    def _fail(self, error: BaseException) -> None:
        if not self.future.done():
            self.future._resolve(error=error)


_SHUTDOWN = object()


def _slice_out(out, offset: int, b: int, single: bool):
    """This request's slice of a coalesced batch output."""
    if single:
        return jax.tree.map(lambda x: x[offset], out)
    return jax.tree.map(lambda x: x[offset:offset + b], out)


# The worker loops hold only a weakref to the server (plus its queues), so an
# abandoned server can be garbage-collected; its finalizer posts _SHUTDOWN and
# the threads exit instead of leaking for the life of the process.

def _wait_gate(server_ref):
    """Wait out a pause() hold WITHOUT keeping the server strongly
    referenced: a paused, abandoned server must stay collectable (its
    finalizer posts the shutdown sentinel) — blocking inside a server method
    would pin it alive, and its threads, forever. Returns the live server
    once the gate is open, or None if it was collected meanwhile."""
    while True:
        server = server_ref()
        if server is None:
            return None
        gate = server._run_gate
        del server
        if gate.wait(timeout=0.2):
            return server_ref()


def _dispatch_loop(server_ref, in_q, out_q):
    leftover = None
    while True:
        item = leftover if leftover is not None else in_q.get()
        leftover = None
        server = _wait_gate(server_ref) if item is not _SHUTDOWN else None
        if item is _SHUTDOWN or server is None:
            # Shut down on the queue handles, NOT through the server: when
            # the finalizer of a GC'd server posts _SHUTDOWN, the weakref is
            # already dead — the completion thread must still be released,
            # and any still-queued requests must fail rather than hang their
            # futures (close() drains first, so this only fires for GC).
            dead = RuntimeError("server closed or garbage-collected before "
                                "the request was dispatched")
            while True:
                if item is not _SHUTDOWN and item is not None:
                    item._fail(dead)
                try:
                    item = in_q.get_nowait()
                except queue.Empty:
                    break
            out_q.put(_SHUTDOWN)
            return
        try:
            leftover = server._dispatch_one(item)
        except BaseException as e:  # defensive: the loop must survive
            server._fail_item(item, e)
        del server


def _complete_loop(server_ref, out_q):
    while True:
        got = out_q.get()
        server = server_ref() if got is not _SHUTDOWN else None
        if got is _SHUTDOWN or server is None:
            # A dead weakref means the server was collected with groups
            # still in flight (nobody kept a server reference, only
            # futures): fail them — silently returning would leave those
            # futures unresolved forever. close() drains before shutdown,
            # so the sentinel path normally finds the queue empty.
            dead = RuntimeError("server closed or garbage-collected before "
                                "the request was answered")
            while True:
                if got is not _SHUTDOWN and got is not None:
                    for it in got[0]:
                        it._fail(dead)
                try:
                    got = out_q.get_nowait()
                except queue.Empty:
                    return
        try:
            server._resolve_group(*got)
        except BaseException as e:  # defensive: resolve rather than hang
            for it in got[0]:
                if not it.future.done():
                    it.future._resolve(error=e)
                    server._done_one()
            server._depth_sem.release()
        del server


@shared_state({"_outstanding": "_cond", "_closed": "_close_lock",
               "_threads": "_thread_lock"})
class AsyncFigaroServer:
    """Pipelined micro-batching serving endpoint for one join structure.

    Construct through `make_figaro_server` / ``ds.serve(kind=...)`` — see
    the module docstring for the pipeline. The public surface:

    ``submit(request)``
        Enqueue per-node request leaves ([m_i, n_i] for one request,
        [B, m_i, n_i] for a sub-batch; rows at the live size are zero-padded
        to capacity, any other row count fails that request's future).
        Returns a `FigaroFuture`.
    ``server(data_batch)``
        Synchronous convenience: ``submit(data_batch).result()``.
    ``append(node, rows)``
        Drain in-flight work, then append ``rows = (key_columns,
        data_rows)`` to relation ``node`` through the shared `PlanHolder` —
        the owning `JoinDataset` (and every sibling server) sees the same
        refreshed plan. True = still within capacity (zero retraces).
    ``flush()`` / ``close()`` / ``pause()`` / ``resume()``
        Drain outstanding requests; shut the worker threads down; hold /
        release the coalescer (pause + submit + resume dispatches one
        maximally-coalesced batch deterministically — useful for warm-up and
        for tests asserting coalesced-batch identities).
    """

    def __init__(self, holder: PlanHolder, dispatch_fn, *, engine=None,
                 axis_size: int = 1, max_batch: int = 32,
                 queue_depth: int = 2):
        if holder.plan is None:
            raise ValueError("AsyncFigaroServer needs a holder with a built "
                             "plan")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        from repro.launch.mesh import serving_batch_capacity

        self._holder = holder
        self._dispatch_fn = dispatch_fn  # (plan, batch, batch_capacity) -> out
        self._capacity_for = functools.partial(serving_batch_capacity,
                                               axis_size=axis_size)
        # Stage (async H2D) only on the single-device path: under a mesh the
        # engine re-places the padded batch with the mesh sharding itself.
        self._engine_stage = (engine.stage if engine is not None
                              and axis_size == 1 else None)
        self.max_batch = max_batch
        self.queue_depth = queue_depth
        self._in_q: queue.Queue = queue.Queue()
        self._out_q: queue.Queue = queue.Queue()
        self._depth_sem = threading.Semaphore(queue_depth)
        self._run_gate = threading.Event()
        self._run_gate.set()
        # Sanitizer-aware locks (FIG007), created before the state they
        # guard so FIGARO_SAN=1 can resolve them mid-__init__.
        self._cond = san_condition("server._cond")
        self._close_lock = san_lock("server._close_lock")  # closed vs enqueue
        self._thread_lock = san_lock("server._thread_lock")
        self._outstanding = 0
        self._closed = False
        self._threads: list[threading.Thread] | None = None
        self._finalizer = weakref.finalize(self, self._in_q.put, _SHUTDOWN)

    # -- plan lifecycle (shared with the owning JoinDataset) -----------------

    @property
    def plan(self) -> FigaroPlan:
        """The currently-served plan — the shared holder's, never a fork.

        Every request captures this plan at *submit* time (``item.plan``),
        and dispatch uses the captured plan — so a holder-level swap (an
        append refresh, or an adaptive re-root via `PlanHolder.replace`)
        never changes the plan a pending future is answered with: the swap
        paths drain first, and anything submitted before the drain resolves
        bit-identically to the pre-swap plan."""
        return self._holder.plan

    def append(self, node: str, rows) -> bool:
        """Append ``rows = (key_columns, data_rows)`` to relation ``node``.

        Drains in-flight work first (queued requests were validated against
        the old capacities), then refreshes the shared plan holder. Returns
        True when the refresh stayed within the plan's capacities — the next
        dispatch reuses the cached executable, zero retraces. Appends through
        a dataset with adaptive re-rooting (``ds.append``) may additionally
        swap the orientation at the same drain point; requests submitted
        after the swap validate against — and are answered on — the new
        plan's layout."""
        return self._holder.refresh({node: rows})

    # -- submission ----------------------------------------------------------

    def submit(self, request) -> FigaroFuture:
        """Enqueue one request ([m_i, n_i] leaves) or a sub-batch
        ([B, m_i, n_i]); returns a `FigaroFuture` resolved in submission
        order. Validation failures resolve this future alone."""
        item = _Request()
        try:
            self._prepare(item, request)
        except Exception as e:
            item.error = e
        # The closed check and the enqueue are one atomic step against
        # close(): without the lock, a submit racing close() could enqueue
        # its item AFTER the shutdown sentinel and hang its future forever.
        with self._close_lock:
            if self._closed:
                raise RuntimeError("server is closed")
            with self._cond:
                self._outstanding += 1
            self._ensure_threads()
            self._in_q.put(item)
        return item.future

    def __call__(self, data_batch):
        """Synchronous dispatch: ``submit(data_batch).result()``."""
        return self.submit(data_batch).result()

    def _prepare(self, item: _Request, request) -> None:
        plan = self._holder.plan
        data = tuple(request)
        if len(data) != len(plan.spec.nodes):
            raise ValueError(
                f"expected one data leaf per relation "
                f"({len(plan.spec.nodes)}: {list(plan.spec.names)}), "
                f"got {len(data)}")
        ndims = {np.ndim(d) for d in data}
        if ndims == {2}:
            item.single = True
            data = tuple(np.asarray(d)[None] for d in data)
        elif ndims != {3}:
            raise ValueError(
                "request leaves must all be [rows_i, n_i] (one request) or "
                f"all [B, rows_i, n_i] (a sub-batch); got ndims {sorted(ndims)}")
        bs = {int(np.shape(d)[0]) for d in data}
        if len(bs) != 1:
            raise ValueError(f"request leaves disagree on the batch size: "
                             f"{sorted(bs)}")
        sizes = [(int(ix.row_mask.sum()) if ix.row_mask is not None else sp.m,
                  sp) for sp, ix in zip(plan.spec.nodes, plan.index)]
        if not all(np.shape(d)[-2] == sp.m for d, (_, sp) in zip(data, sizes)):
            for d, (live, sp) in zip(data, sizes):
                if np.shape(d)[-2] not in (live, sp.m):
                    raise ValueError(
                        f"{sp.name}: request batch has {np.shape(d)[-2]} "
                        f"rows; expected the live size ({live}) or the "
                        f"capacity ({sp.m}) — rebuild request buffers after "
                        f"append()")
            data = pad_data(data, plan.spec)
        item.arrays = data
        item.b = bs.pop()
        item.plan = plan
        item.sig = (id(plan), tuple(
            np.dtype(getattr(d, "dtype", None) or np.asarray(d).dtype).str
            for d in data))

    # -- worker plumbing -----------------------------------------------------

    def _ensure_threads(self) -> None:
        # No unlocked fast-path read: `_threads` is written under
        # `_thread_lock`, so the check must hold it too (the uncontended
        # acquire is cheap, and the lockset race detector would rightly flag
        # the bare read once a second thread has gone through here).
        with self._thread_lock:
            if self._threads is not None:
                return
            ref = weakref.ref(self)
            threads = [
                san_thread(_dispatch_loop,
                           args=(ref, self._in_q, self._out_q),
                           name="figaro-serve-dispatch", daemon=True),
                san_thread(_complete_loop, args=(ref, self._out_q),
                           name="figaro-serve-complete", daemon=True),
            ]
            for t in threads:
                t.start()
            self._threads = threads

    def _dispatch_one(self, first: _Request):
        """Coalesce a group starting at ``first``, dispatch it, hand it to
        the completion thread. Returns a popped-but-incompatible request to
        seed the next group (or _SHUTDOWN, passed through). The pause() gate
        was already waited out by the dispatch loop (without a strong server
        reference), so the queue behind ``first`` is fully drained here."""
        group = [first]
        live_sig = first.sig if first.error is None else None
        total_b = first.b if first.error is None else 0
        leftover = None
        while total_b < self.max_batch:
            try:
                nxt = self._in_q.get_nowait()
            except queue.Empty:
                break
            # Stop at a shutdown sentinel, an incompatible request, or a
            # sub-batch that would push the group past max_batch (a single
            # oversized submit still dispatches alone — it cannot be split);
            # the popped item seeds the next group, preserving FIFO order.
            if nxt is _SHUTDOWN or (nxt.error is None and (
                    (live_sig is not None and nxt.sig != live_sig)
                    or total_b + nxt.b > self.max_batch)):
                leftover = nxt
                break
            group.append(nxt)
            if nxt.error is None:
                live_sig = live_sig or nxt.sig
                total_b += nxt.b
        live = [it for it in group if it.error is None]
        payload = None
        self._depth_sem.acquire()  # ≤ queue_depth coalesced batches in flight
        if live:
            try:
                if len(live) == 1:
                    data = live[0].arrays
                else:
                    data = tuple(
                        np.concatenate([np.asarray(it.arrays[j])
                                        for it in live])
                        for j in range(len(live[0].arrays)))
                if self._engine_stage is not None:
                    data = self._engine_stage(data)
                out = self._dispatch_fn(live[0].plan, data,
                                        self._capacity_for(total_b) or None)
                payload = (out, None)
            except Exception as e:
                payload = (None, e)
        self._out_q.put((group, live, payload))
        return leftover

    def _resolve_group(self, group, live, payload) -> None:
        out, err = payload if payload is not None else (None, None)
        if err is None and out is not None:
            try:
                jax.block_until_ready(out)
            except Exception as e:
                err, out = e, None
        results, errors = {}, {}
        if live and err is None and out is not None:
            offset = 0
            for it in live:
                results[id(it)] = _slice_out(out, offset, it.b, it.single)
                offset += it.b
        elif len(live) > 1:
            # A coalesced dispatch failed: isolate the poisoned request(s) by
            # re-dispatching each request alone — batchmates still succeed.
            for it in live:
                try:
                    o = self._dispatch_fn(it.plan, it.arrays,
                                          self._capacity_for(it.b) or None)
                    jax.block_until_ready(o)
                    results[id(it)] = _slice_out(o, 0, it.b, it.single)
                except Exception as e:
                    errors[id(it)] = e
        elif live:
            errors[id(live[0])] = err
        for it in group:  # strictly submission order
            if it.error is not None:
                it.future._resolve(error=it.error)
            elif id(it) in results:
                it.future._resolve(value=results[id(it)])
            else:
                it.future._resolve(error=errors.get(id(it), err))
            self._done_one()
        self._depth_sem.release()

    def _fail_item(self, item, error: BaseException) -> None:
        if isinstance(item, _Request) and not item.future.done():
            item.future._resolve(error=error)
            self._done_one()

    def _done_one(self) -> None:
        with self._cond:
            self._outstanding -= 1
            self._cond.notify_all()

    # -- flow control --------------------------------------------------------

    def flush(self) -> None:
        """Block until every submitted request has been answered.

        Releases a `pause` hold first: flush demands every queued request be
        answered, which a held coalescer could never do — without this,
        ``append`` (which drains every server attached to the plan holder,
        paused or not) would deadlock on a paused server's queued work."""
        self.resume()
        with self._cond:
            self._cond.wait_for(lambda: self._outstanding == 0)

    def pause(self) -> None:
        """Hold the coalescer: submitted requests queue up but do not
        dispatch until `resume` — pre-loading the queue this way yields one
        maximally-coalesced batch. `flush` / `append` / `close` release the
        hold (they require the queue to drain)."""
        self._run_gate.clear()

    def resume(self) -> None:
        self._run_gate.set()

    def close(self) -> None:
        """Drain outstanding work and stop the worker threads."""
        with self._close_lock:  # `_closed` is only ever read under the lock
            if self._closed:
                return
        self.flush()  # releases any pause() hold first
        threads = None
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            with self._thread_lock:
                threads = self._threads
            if threads is not None:
                self._in_q.put(_SHUTDOWN)
        if threads is not None:
            for t in threads:
                t.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
