from .step import TrainState, init_state, make_train_step, make_eval_step  # noqa: F401
from .serve import make_prefill, make_decode_step, cache_specs, sample_loop  # noqa: F401
