"""Serving: prefill / decode steps, a batched greedy/temperature sampler, and
the batched FiGaRo factorization server.

``make_prefill`` / ``make_decode_step`` are the functions the dry-run lowers
for the prefill_* / decode_* / long_* shapes. The KV cache is sharded batch-
over-(pod,data) normally, and sequence-over-data for global_batch==1
long-context decode (context parallelism — GSPMD inserts the online-softmax
combine collectives).

``make_figaro_server`` is the linear-algebra-over-joins counterpart: one join
structure (a `FigaroPlan`), many concurrent users' feature-sets — each dispatch
vmaps Algorithm 2 + post-processing over a leading batch axis through a
`FigaroEngine` with donated request buffers, so serving cost per request is
one cached executable launch.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import FigaroEngine
from repro.core.join_tree import FigaroPlan
from repro.core.plan_cache import pad_data, refresh_plan
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.sharding.rules import data_axes

__all__ = ["make_prefill", "make_decode_step", "cache_specs", "sample_loop",
           "make_figaro_server", "FigaroServer", "SERVE_KINDS"]

#: Supported `make_figaro_server` kinds (validated eagerly at construction).
SERVE_KINDS = ("qr", "svd", "pca", "lsq")


class FigaroServer:
    """Callable serving endpoint for one join structure, with an online
    append path when the plan is a capacity plan.

    ``server(data_batch)`` answers B requests per dispatch (see
    `make_figaro_server`). ``server.append(node, rows)`` appends rows to one
    relation (``rows = (key_columns, data_rows)`` as in
    `plan_cache.refresh_plan`) and swaps in the refreshed plan: as long as
    the new live sizes fit the plan's bucketed capacities, the next dispatch
    reuses the cached executable — zero retraces under streaming appends.

    Capacity contract for requests: batch leaves are [B, rows_i, n_i] in the
    plan's (sorted) row order with ``rows_i`` either the node's live size or
    its full capacity; live-sized leaves are zero-padded up to capacity here
    (the dead rows are masked out inside the pipeline regardless).
    """

    def __init__(self, plan: FigaroPlan, dispatch):
        self._plan = plan
        self._dispatch = dispatch

    @property
    def plan(self) -> FigaroPlan:
        """The currently-served plan (replaced by `append`)."""
        return self._plan

    def __call__(self, data_batch):
        if any(ix.row_mask is not None for ix in self._plan.index):
            data_batch = self._pad_requests(data_batch)
        return self._dispatch(self._plan, data_batch)

    def _pad_requests(self, data_batch):
        """Zero-pad live-sized request leaves up to capacity.

        Exactly live-sized or exactly capacity-sized leaves are accepted;
        anything else raises — silently zero-filling a stale-sized batch
        (e.g. one built for the live sizes *before* an `append`) would treat
        the missing rows as all-zero features and corrupt the answer. Leaves
        already at capacity pass through untouched (no host round trip on
        the hot serving path).
        """
        data_batch = tuple(data_batch)
        sizes = [(int(ix.row_mask.sum()) if ix.row_mask is not None else sp.m,
                  sp) for sp, ix in zip(self._plan.spec.nodes,
                                        self._plan.index)]
        if all(d.shape[-2] == sp.m for d, (_, sp) in zip(data_batch, sizes)):
            return data_batch  # already capacity-shaped
        for d, (live, sp) in zip(data_batch, sizes):
            if d.shape[-2] not in (live, sp.m):
                raise ValueError(
                    f"{sp.name}: request batch has {d.shape[-2]} rows; "
                    f"expected the live size ({live}) or the capacity "
                    f"({sp.m}) — rebuild request buffers after append()")
        return pad_data(data_batch, self._plan.spec)

    def append(self, node: str, rows) -> bool:
        """Append ``rows = (key_columns, data_rows)`` to relation ``node``.

        Returns True when the refresh stayed within the plan's capacities
        (same signature — the next dispatch is launch-only) and False when
        the capacities grew (one recompile on the next dispatch).
        """
        new_plan = refresh_plan(self._plan, {node: rows})
        same_signature = new_plan.spec == self._plan.spec
        self._plan = new_plan
        return same_signature


def make_figaro_server(plan: FigaroPlan, *, kind: str = "qr",
                       label_col: int | None = None, k: int | None = None,
                       ridge: float = 0.0,
                       dtype=jnp.float32, method: str = "tsqr",
                       leaf_rows: int = 256,
                       engine: FigaroEngine | None = None,
                       mesh: Mesh | None = None, shard_axis: str = "data"):
    """Batched FiGaRo serving endpoint for one join structure.

    Returns a `FigaroServer` — ``server(data_batch)`` takes per-node
    [B, m_i, n_i] request buffers and answers B requests per dispatch:

      kind="qr"   -> R      [B, N, N]
      kind="svd"  -> (s [B, N], Vt [B, N, N])
      kind="pca"  -> PCAResult with a leading batch axis (top-``k``)
      kind="lsq"  -> (betas [B, N-1], residuals [B]) against ``label_col``

    Every kind — lsq and pca included — answers the whole batch with ONE
    cached executable launch (the engine's genuinely-batched vmapped bodies).
    With a ``mesh``, the request-batch axis is additionally sharded over
    ``mesh[shard_axis]`` via `shard_map`: one executable per (plan signature,
    mesh signature) serves the global batch across all devices, with the
    batch padded/bucketed to the axis size inside the engine.

    With a capacity plan (`plan_cache.build_capacity_plan`) the server also
    exposes ``server.append(node, rows)`` for online data refreshes; appends
    that keep the bucketed signature never retrace.

    The engine donates request buffers (they are consumed by the dispatch that
    answers them) and compiles once per plan signature — subsequent batches,
    and other plans with the same signature, are launch-only.

    `repro.figaro` (`Session.serve` / `JoinDataset.serve`) is the façade over
    this constructor — it fills engine/mesh/dtype from the session config and
    resolves ``label_col`` by column name.
    """
    # Validate up front — a bad kind must fail at construction with the full
    # list of supported kinds, not at (or after) the first dispatch.
    if kind not in SERVE_KINDS:
        raise ValueError(f"unknown serve kind {kind!r}; supported kinds: "
                         f"{', '.join(SERVE_KINDS)}")
    if kind == "lsq" and label_col is None:
        raise ValueError("kind='lsq' needs label_col")
    if not isinstance(plan, FigaroPlan):
        from repro.core.engine import _plan_arg_error

        raise TypeError(_plan_arg_error("plan", plan))
    engine = engine if engine is not None else FigaroEngine(donate_data=True)
    shard = None if mesh is None else (mesh, shard_axis)

    common = dict(batched=True, shard=shard, dtype=dtype, method=method,
                  leaf_rows=leaf_rows)
    dispatch = {
        "qr": lambda plan, batch: engine.qr(plan, batch, **common),
        "svd": lambda plan, batch: engine.svd(plan, batch, **common),
        "pca": lambda plan, batch: engine.pca(plan, batch, k=k, **common),
        "lsq": lambda plan, batch: engine.least_squares(
            plan, label_col, batch, ridge=ridge, **common),
    }[kind]
    return FigaroServer(plan, dispatch)


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill_fn(params, batch):
        return tf.prefill(params, cfg, batch, max_len)

    return prefill_fn


def make_decode_step(cfg: ModelConfig):
    def decode_fn(params, cache, tokens):
        return tf.decode_step(params, cfg, cache, tokens)

    return decode_fn


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, shard_seq: bool = False,
                kv_seq_over_model: bool = True):
    """PartitionSpec pytree for the decode cache.

    Batch-sharded by default; ``shard_seq`` shards attention KV slots over
    `data` (long_500k, global_batch=1). SSM states are O(1) in seq — they
    stay batch-sharded (or replicated at batch 1).

    ``kv_seq_over_model`` (§Perf iteration C2): when the kv-head count does
    not divide the model axis (all assigned archs: kv=8 < 16), the KV slots
    shard over `model` — blockwise attention then runs on local slots and
    only the online-softmax stats (m, l, [B,H,1,hd] partials) cross shards.
    The pre-hillclimb layout sharded head_dim instead, which forced a
    re-gather of every KV block inside the attention scan (measured
    43 GB/device/token on command-r decode_32k).
    """
    dp = data_axes(mesh)
    msz = mesh.shape.get("model", 1)
    # kv heads shard over `model` when divisible; otherwise shard the KV
    # slots (sequence) over `model` — or, pre-hillclimb, the head_dim.
    kv_ax = "model" if cfg.n_kv_heads % msz == 0 else None
    seq_model_ax = None
    if kv_ax is None and kv_seq_over_model:
        hd_ax = None
        seq_model_ax = "model"
    else:
        hd_ax = None if kv_ax else "model"

    def _fit(spec: P, shape) -> P:
        """Drop axis shardings that do not divide the dim (reduced configs on
        the production mesh would otherwise hit uneven-tiling errors)."""
        fixed = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            fixed.append(ax if dim >= size and dim % size == 0 else None)
        return P(*fixed)

    def spec(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        ndim = len(leaf.shape)
        if "pos" in names[-1:]:
            return P()
        batch_ax = None if shard_seq else dp
        if names[-1] in ("k", "v"):  # [n_blocks, B, S, kv, hd]
            if shard_seq:  # batch == 1: context parallelism over data(+model)
                seq_ax = ("data", "model") if seq_model_ax else "data"
            else:
                seq_ax = seq_model_ax
            return _fit(P(None, batch_ax, seq_ax, kv_ax, hd_ax), leaf.shape)
        if names[-1] in ("conv", "shift"):  # [n_blocks, B, w, di]
            return _fit(P(None, batch_ax, None, "model"), leaf.shape)
        if names[-1] == "ssm":  # [n_blocks, B, di, d_state]
            return _fit(P(None, batch_ax, "model", None), leaf.shape)
        if names[-1] == "state":  # rwkv [n_blocks, B, h, hd, hd]
            return _fit(P(None, batch_ax, "model", None, None), leaf.shape)
        return P(*([None] * ndim))

    return spec


def sample_loop(params, cfg: ModelConfig, batch, *, steps: int,
                max_len: int, temperature: float = 0.0, key=None):
    """Greedy / temperature sampling driver (examples + integration tests)."""
    logits, cache = tf.prefill(params, cfg, batch, max_len)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    decode = jax.jit(make_decode_step(cfg))
    for i in range(steps):
        toks.append(tok)
        logits, cache = decode(params, cache, tok)
        if temperature > 0:
            key = jax.random.fold_in(key, i)
            tok = jax.random.categorical(key, logits / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)
