"""Serving: prefill / decode steps, a batched greedy/temperature sampler, and
the batched FiGaRo factorization server.

``make_prefill`` / ``make_decode_step`` are the functions the dry-run lowers
for the prefill_* / decode_* / long_* shapes. The KV cache is sharded batch-
over-(pod,data) normally, and sequence-over-data for global_batch==1
long-context decode (context parallelism — GSPMD inserts the online-softmax
combine collectives).

``make_figaro_server`` is the linear-algebra-over-joins counterpart: one join
structure (a `FigaroPlan`), many concurrent users' feature-sets — each dispatch
vmaps Algorithm 2 + post-processing over a leading batch axis through a
`FigaroEngine` with donated request buffers, so serving cost per request is
one cached executable launch. The server is async-first
(`repro.train.async_serve`): ``submit(request)`` returns a `FigaroFuture`,
pending requests coalesce into bucketed micro-batches, and queue depth >= 2
overlaps the next batch's H2D staging with the in-flight dispatch; the
synchronous `FigaroServer` call is a ``submit(...).result()`` wrapper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import FigaroEngine
from repro.core.join_tree import FigaroPlan
from repro.core.plan_cache import PlanHolder
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.sharding.rules import data_axes
from repro.train.async_serve import (AsyncFigaroServer, FigaroFuture,
                                     SERVE_KINDS, validate_serve_kind)

__all__ = ["make_prefill", "make_decode_step", "cache_specs", "sample_loop",
           "make_figaro_server", "FigaroServer", "AsyncFigaroServer",
           "FigaroFuture", "SERVE_KINDS", "validate_serve_kind"]


class FigaroServer(AsyncFigaroServer):
    """The synchronous face of `AsyncFigaroServer` — behavior-preserving for
    pre-async callers.

    ``server(data_batch)`` is exactly ``server.submit(data_batch).result()``:
    the request rides the same micro-batching queue and pipelined dispatch,
    the call just blocks for its own answer. ``server.append(node, rows)``
    (``rows = (key_columns, data_rows)`` as in `plan_cache.refresh_plan`)
    drains in-flight work and refreshes the shared plan holder: as long as
    the new live sizes fit the plan's bucketed capacities, the next dispatch
    reuses the cached executable — zero retraces under streaming appends.

    Capacity contract for requests: batch leaves are [B, rows_i, n_i] in the
    plan's (sorted) row order with ``rows_i`` either the node's live size or
    its full capacity; live-sized leaves are zero-padded up to capacity
    (the dead rows are masked out inside the pipeline regardless).
    """


def make_figaro_server(plan: FigaroPlan | PlanHolder, *, kind: str = "qr",
                       label_col: int | None = None, k: int | None = None,
                       ridge: float = 0.0,
                       dtype=jnp.float32, method: str = "tsqr",
                       leaf_rows: int = 256, use_kernel: bool = False,
                       assembly: str = "padded",
                       engine: FigaroEngine | None = None,
                       mesh: Mesh | None = None, shard_axis: str = "data",
                       max_batch: int = 32,
                       queue_depth: int = 2) -> FigaroServer:
    """Batched FiGaRo serving endpoint for one join structure.

    Returns a `FigaroServer` (an `AsyncFigaroServer` whose ``__call__``
    blocks) — ``server.submit(request)`` enqueues per-node [m_i, n_i]
    request leaves (or a [B, m_i, n_i] sub-batch) and returns a
    `FigaroFuture`; ``server(data_batch)`` answers synchronously:

      kind="qr"   -> R      [B, N, N]
      kind="svd"  -> (s [B, N], Vt [B, N, N])
      kind="pca"  -> PCAResult with a leading batch axis (top-``k``)
      kind="lsq"  -> (betas [B, N-1], residuals [B]) against ``label_col``

    Pending requests are coalesced up to ``max_batch`` rows and the batch is
    padded to its bucketed capacity (powers of two, aligned to the mesh
    axis), so every kind — lsq and pca included — answers the whole
    coalesced batch with ONE cached executable launch, and the executable
    cache tracks batch *buckets*, not every live batch size. ``queue_depth``
    coalesced batches may be in flight at once: at depth >= 2 the next
    batch's staging (async H2D of donated input slabs) overlaps the
    in-flight dispatch — engine-level double buffering.
    With a ``mesh``, the request-batch axis is additionally sharded over
    ``mesh[shard_axis]`` via `shard_map`: one executable per (plan signature,
    mesh signature) serves the global batch across all devices.

    With a capacity plan (`plan_cache.build_capacity_plan`) the server also
    exposes ``server.append(node, rows)`` for online data refreshes; appends
    that keep the bucketed signature never retrace. Pass a
    `plan_cache.PlanHolder` to share plan state with other surfaces (this is
    what ``JoinDataset.serve`` does — dataset and server then see one plan,
    never a fork).

    The engine donates request buffers (they are consumed by the dispatch that
    answers them) and compiles once per plan signature — subsequent batches,
    and other plans with the same signature, are launch-only.

    `repro.figaro` (`Session.serve` / `JoinDataset.serve`) is the façade over
    this constructor — it fills engine/mesh/dtype from the session config and
    resolves ``label_col`` by column name.
    """
    # Validate up front — a bad kind must fail at construction with the full
    # list of supported kinds, not at (or after) the first dispatch.
    validate_serve_kind(kind, label_col=label_col, check_label=True)
    if isinstance(plan, PlanHolder):
        holder = plan
    else:
        if not isinstance(plan, FigaroPlan):
            from repro.core.engine import _plan_arg_error

            raise TypeError(_plan_arg_error("plan", plan))
        holder = PlanHolder(plan)
    engine = engine if engine is not None else FigaroEngine(donate_data=True)
    shard = None if mesh is None else (mesh, shard_axis)

    # use_kernel / assembly ride the static half of every dispatch, so the
    # serving executables are the fused-kernel / band-assembly programs when
    # the session (or caller) asked for them — same cache-key discipline as
    # direct engine calls.
    common = dict(batched=True, shard=shard, dtype=dtype, method=method,
                  leaf_rows=leaf_rows, use_kernel=use_kernel,
                  assembly=assembly)
    dispatch = {
        "qr": lambda plan, batch, cap: engine.qr(
            plan, batch, batch_capacity=cap, **common),
        "svd": lambda plan, batch, cap: engine.svd(
            plan, batch, batch_capacity=cap, **common),
        "pca": lambda plan, batch, cap: engine.pca(
            plan, batch, batch_capacity=cap, k=k, **common),
        "lsq": lambda plan, batch, cap: engine.least_squares(
            plan, label_col, batch, batch_capacity=cap, ridge=ridge,
            **common),
    }[kind]
    server = FigaroServer(holder, dispatch, engine=engine,
                          axis_size=1 if mesh is None
                          else int(mesh.shape[shard_axis]),
                          max_batch=max_batch, queue_depth=queue_depth)
    holder.attach(server)
    return server


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill_fn(params, batch):
        return tf.prefill(params, cfg, batch, max_len)

    return prefill_fn


def make_decode_step(cfg: ModelConfig):
    def decode_fn(params, cache, tokens):
        return tf.decode_step(params, cfg, cache, tokens)

    return decode_fn


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, shard_seq: bool = False,
                kv_seq_over_model: bool = True):
    """PartitionSpec pytree for the decode cache.

    Batch-sharded by default; ``shard_seq`` shards attention KV slots over
    `data` (long_500k, global_batch=1). SSM states are O(1) in seq — they
    stay batch-sharded (or replicated at batch 1).

    ``kv_seq_over_model`` (§Perf iteration C2): when the kv-head count does
    not divide the model axis (all assigned archs: kv=8 < 16), the KV slots
    shard over `model` — blockwise attention then runs on local slots and
    only the online-softmax stats (m, l, [B,H,1,hd] partials) cross shards.
    The pre-hillclimb layout sharded head_dim instead, which forced a
    re-gather of every KV block inside the attention scan (measured
    43 GB/device/token on command-r decode_32k).
    """
    dp = data_axes(mesh)
    msz = mesh.shape.get("model", 1)
    # kv heads shard over `model` when divisible; otherwise shard the KV
    # slots (sequence) over `model` — or, pre-hillclimb, the head_dim.
    kv_ax = "model" if cfg.n_kv_heads % msz == 0 else None
    seq_model_ax = None
    if kv_ax is None and kv_seq_over_model:
        hd_ax = None
        seq_model_ax = "model"
    else:
        hd_ax = None if kv_ax else "model"

    def _fit(spec: P, shape) -> P:
        """Drop axis shardings that do not divide the dim (reduced configs on
        the production mesh would otherwise hit uneven-tiling errors)."""
        fixed = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            fixed.append(ax if dim >= size and dim % size == 0 else None)
        return P(*fixed)

    def spec(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        ndim = len(leaf.shape)
        if "pos" in names[-1:]:
            return P()
        batch_ax = None if shard_seq else dp
        if names[-1] in ("k", "v"):  # [n_blocks, B, S, kv, hd]
            if shard_seq:  # batch == 1: context parallelism over data(+model)
                seq_ax = ("data", "model") if seq_model_ax else "data"
            else:
                seq_ax = seq_model_ax
            return _fit(P(None, batch_ax, seq_ax, kv_ax, hd_ax), leaf.shape)
        if names[-1] in ("conv", "shift"):  # [n_blocks, B, w, di]
            return _fit(P(None, batch_ax, None, "model"), leaf.shape)
        if names[-1] == "ssm":  # [n_blocks, B, di, d_state]
            return _fit(P(None, batch_ax, "model", None), leaf.shape)
        if names[-1] == "state":  # rwkv [n_blocks, B, h, hd, hd]
            return _fit(P(None, batch_ax, "model", None, None), leaf.shape)
        return P(*([None] * ndim))

    return spec


def sample_loop(params, cfg: ModelConfig, batch, *, steps: int,
                max_len: int, temperature: float = 0.0, key=None):
    """Greedy / temperature sampling driver (examples + integration tests)."""
    logits, cache = tf.prefill(params, cfg, batch, max_len)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    decode = jax.jit(make_decode_step(cfg))
    for i in range(steps):
        toks.append(tok)
        logits, cache = decode(params, cache, tok)
        if temperature > 0:
            key = jax.random.fold_in(key, i)
            tok = jax.random.categorical(key, logits / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)
