"""Algorithm 2 (FiGaRo) + end-to-end QR over joins (Theorem 6.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.figaro import figaro_r0
from repro.core.join_tree import JoinTree, build_plan
from repro.core.materialize import materialize_join
from repro.core.postprocess import normalize_sign
from repro.core.qr import figaro_qr, materialized_qr
from repro.data.relational import (cartesian, favorita_like, retailer_like,
                                   yelp_like)

from helpers import TOPOLOGIES, random_acyclic_db


# -- Theorem 6.1: R0 properties ----------------------------------------------


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
def test_r0_gram_identity(rng, topology):
    """A[:,Ȳ] = Q·[R0;0] for orthogonal Q  ⟺  R0ᵀR0 == AᵀA (exactly)."""
    _, tree, plan = random_acyclic_db(topology, rng)
    a = np.asarray(materialize_join(tree))
    r0 = np.asarray(figaro_r0(plan, dtype=jnp.float64))
    g_ref = a.T @ a
    err = np.abs(g_ref - r0.T @ r0).max() / max(np.abs(g_ref).max(), 1e-30)
    assert err < 1e-11, err


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
def test_r0_row_bound(rng, topology):
    """Theorem 6.1(1): R0 has at most M non-zero rows (M = total input rows)."""
    db, tree, plan = random_acyclic_db(topology, rng)
    r0 = np.asarray(figaro_r0(plan, dtype=jnp.float64))
    nz = (np.abs(r0).max(axis=1) > 1e-13).sum()
    assert nz <= db.total_rows


def test_r0_independent_of_join_size(rng):
    """R0's row count scales with the INPUT, not the join output."""
    tree_small = cartesian(8, 8, seed=11)
    tree_big = cartesian(64, 64, seed=11)  # join is 64x larger
    r0_small = figaro_r0(build_plan(tree_small), dtype=jnp.float64)
    r0_big = figaro_r0(build_plan(tree_big), dtype=jnp.float64)
    assert r0_big.shape[0] <= 8 * r0_small.shape[0] + 4


# -- end-to-end: R matches QR of the materialized join ------------------------


@pytest.mark.parametrize("method", ["householder", "tsqr", "blocked",
                                    "lapack"])
def test_figaro_qr_matches_materialized(rng, method):
    _, tree, plan = random_acyclic_db("snowflake4", rng)
    r_fig = np.asarray(figaro_qr(plan, dtype=jnp.float64, method=method,
                                 leaf_rows=16))
    r_mat = np.asarray(materialized_qr(tree, method="lapack"))
    err = np.abs(r_fig - r_mat).max() / np.abs(r_mat).max()
    assert err < 1e-9, (method, err)


@pytest.mark.parametrize("maker,kw", [
    (retailer_like, dict(scale=60)),
    (favorita_like, dict(scale=60)),
    (yelp_like, dict(scale=40)),
])
def test_figaro_qr_on_paper_style_schemas(maker, kw):
    tree = maker(**kw)
    plan = build_plan(tree)
    r_fig = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    r_mat = np.asarray(materialized_qr(tree, method="lapack"))
    err = np.abs(r_fig - r_mat).max() / np.abs(r_mat).max()
    assert err < 1e-8, err


def test_join_tree_choice_invariance(rng):
    """Table 2: different join trees change runtime but NOT the result R."""
    db, _, _ = random_acyclic_db("snowflake4", rng)
    edges = TOPOLOGIES["snowflake4"][0]
    r_by_root = {}
    for root in ("S1", "S2", "S3"):
        # re-root: JoinTree.from_edges handles arbitrary root on the same edges
        tree = JoinTree.from_edges(db, root, edges)
        plan = build_plan(tree)
        r = np.asarray(figaro_qr(plan, dtype=jnp.float64))
        r_by_root[root] = r
    # Rs are over the same columns iff column order matches across plans;
    # compare via the Gram matrix which is column-order-canonicalized by name.
    base = r_by_root["S1"]
    for root in ("S2", "S3"):
        r = r_by_root[root]
        assert np.allclose(np.sort(np.abs(np.diag(base))),
                           np.sort(np.abs(np.diag(r))), rtol=1e-9) or \
            base.shape == r.shape
        # singular values are join-tree invariant
        np.testing.assert_allclose(np.linalg.svd(base, compute_uv=False),
                                   np.linalg.svd(r, compute_uv=False),
                                   rtol=1e-9)


def test_cartesian_product_example_sec11(rng):
    """§1.1: Cartesian product of two unary relations."""
    p, q = 7, 5
    tree = cartesian(p, q, n1=1, n2=1, seed=5)
    plan = build_plan(tree)
    a = np.asarray(materialize_join(tree))
    assert a.shape == (p * q, 2)
    r0 = np.asarray(figaro_r0(plan, dtype=jnp.float64))
    # §1.1: A'' has only p+q non-zero values here (2 cols): rows ≤ p+q
    nz_rows = (np.abs(r0).max(axis=1) > 1e-13).sum()
    assert nz_rows <= p + q
    err = np.abs(a.T @ a - r0.T @ r0).max() / np.abs(a.T @ a).max()
    assert err < 1e-12


# -- float32 accuracy sanity (the TPU dtype) ----------------------------------


def test_float32_figaro_reasonable(rng):
    _, tree, plan = random_acyclic_db("star3", rng)
    r32 = np.asarray(figaro_qr(plan, dtype=jnp.float32))
    r64 = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    err = np.abs(r32 - r64).max() / np.abs(r64).max()
    assert err < 1e-4, err


# -- property test: random databases ------------------------------------------


@settings(max_examples=25, deadline=None)
@given(topology=st.sampled_from(list(TOPOLOGIES)), seed=st.integers(0, 2**31))
def test_property_figaro_equals_materialized_qr(topology, seed):
    rng = np.random.default_rng(seed)
    try:
        _, tree, plan = random_acyclic_db(topology, rng, max_rows=6)
    except ValueError:
        return
    a = np.asarray(materialize_join(tree))
    if a.shape[0] < a.shape[1]:  # thin QR needs m >= n for unique R
        return
    r_fig = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    # The Gram identity holds unconditionally (orthogonal-transform invariant).
    g_ref = a.T @ a
    g_err = np.abs(r_fig.T @ r_fig - g_ref).max() / max(np.abs(g_ref).max(),
                                                        1e-30)
    assert g_err < 1e-10, g_err
    # Entrywise R agreement degrades with cond(A)² (R is the Cholesky factor);
    # scale the tolerance accordingly and skip the near-singular draws.
    s = np.linalg.svd(a, compute_uv=False)
    cond = s[0] / max(s[-1], 1e-300)
    if cond > 1e6:
        return
    r_mat = np.asarray(normalize_sign(jnp.linalg.qr(jnp.array(a), mode="r")))
    err = np.abs(r_fig - r_mat).max() / max(np.abs(r_mat).max(), 1e-30)
    assert err < 1e-12 * cond ** 2 + 1e-9, (err, cond)
