"""Fault-tolerant driver: train -> checkpoint -> restart -> resume."""

import os

import numpy as np

from repro.launch.train import main as train_main


def test_driver_trains_and_auto_resumes(tmp_path, capsys):
    ckpt = str(tmp_path / "ckpt")
    rc = train_main([
        "--arch", "granite-3-8b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3",
        "--log-every", "2", "--warmup", "2",
    ])
    assert rc == 0
    out1 = capsys.readouterr().out
    assert "step     6" in out1
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(ckpt) if f.endswith(".npz"))
    assert 6 in steps
    # Restart: must auto-resume from step 6 and run only steps 7..10.
    rc = train_main([
        "--arch", "granite-3-8b", "--smoke", "--steps", "10", "--batch", "4",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "100",
        "--log-every", "2", "--warmup", "2",
    ])
    assert rc == 0
    out2 = capsys.readouterr().out
    assert "resumed from step 6" in out2
    assert "steps 6->10" in out2


def test_driver_no_checkpointing(capsys):
    rc = train_main(["--arch", "rwkv6-1.6b", "--smoke", "--steps", "3",
                     "--batch", "2", "--seq", "16", "--log-every", "1"])
    assert rc == 0
    assert "loss" in capsys.readouterr().out
