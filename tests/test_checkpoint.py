"""Fault-tolerance: checkpoint save/restore, atomicity, GC, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state


def _tiny_state():
    cfg = get_config("granite-3-8b", smoke=True)
    return init_state(jax.random.PRNGKey(0), cfg, AdamWConfig())


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _tiny_state()
    mgr.save(3, state, blocking=True)
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"a": jnp.arange(10)}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.ones(3)}, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_partial_files(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"a": jnp.ones(5)}, blocking=True)
    files = os.listdir(tmp_path)
    assert not any(f.endswith(".tmp.npz") for f in files)
    assert "step_00000007.npz" in files


def test_metadata_records_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(11, {"a": jnp.ones(2)}, blocking=True,
             extra_meta={"mesh": "16x16"})
    meta = json.load(open(tmp_path / "step_00000011.json"))
    assert meta["step"] == 11 and meta["mesh"] == "16x16"


def test_elastic_restore_respects_target_sharding(tmp_path):
    """Leaves are device-agnostic: restore places onto the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=True)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    tgt = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored = mgr.restore(1, tgt, sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"], np.float32))


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2))}, blocking=True)
    import pytest
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})
