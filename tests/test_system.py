"""End-to-end behaviour of the whole system (paper pipeline + LM framework)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config
from repro.core.join_tree import build_plan
from repro.core.materialize import join_output_rows, materialize_join
from repro.core.qr import figaro_qr, materialized_qr
from repro.data.relational import yelp_like
from repro.launch.roofline import PEAK_FLOPS, Roofline, collective_bytes


def test_end_to_end_figaro_vs_materialized_many_to_many():
    """The paper's headline: same R as the materialized-join QR, computed
    from the (much smaller) input database."""
    tree = yelp_like(scale=80)
    plan = build_plan(tree)
    a = materialize_join(tree)
    assert a.shape[0] > 4 * sum(nd.data.shape[0] for nd in plan.nodes)
    r_fig = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    r_mat = np.asarray(materialized_qr(tree))
    err = np.abs(r_fig - r_mat).max() / np.abs(r_mat).max()
    assert err < 1e-8, err


def test_join_output_rows_matches_materialized():
    tree = yelp_like(scale=50)
    assert join_output_rows(tree) == materialize_join(tree).shape[0]


def test_cell_matrix_is_complete():
    """The assigned 10×4 = 40 cells: all defined, skips only where the task
    spec directs (long_500k for pure full-attention archs)."""
    n_total, n_skip = 0, 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            n_total += 1
            ok, why = cell_is_runnable(cfg, shape)
            if not ok:
                n_skip += 1
                assert shape.name == "long_500k", (arch, shape.name)
                assert not cfg.subquadratic
    assert n_total == 40
    assert n_skip == 7  # whisper/arctic/minicpm/command-r/granite/qwen3/llava
    for arch in ("rwkv6-1.6b", "jamba-v0.1-52b", "mixtral-8x22b"):
        assert get_config(arch).subquadratic


def test_collective_bytes_parser():
    hlo = """
  ENTRY main {
    %x = f32[128,512]{1,0} parameter(0)
    %ag = f32[256,512] all-gather(f32[128,512] %x), replica_groups={}
    %ar = f32[128,512] all-reduce(f32[128,512] %x), to_apply=%add
    %rs = f32[64,512] reduce-scatter(f32[128,512] %x), dimensions={0}
    %cp = f32[128,512] collective-permute(f32[128,512] %x), pairs={{0,1}}
    %dot = f32[512,512] dot(f32[128,512] %x, f32[128,512] %x)
  }
  """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 512 * 4
    assert out["all-reduce"] == 128 * 512 * 4
    assert out["reduce-scatter"] == 128 * 512 * 4
    assert out["collective-permute"] == 128 * 512 * 4
    assert out["all-to-all"] == 0


def test_roofline_terms():
    rl = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                  flops_per_device=PEAK_FLOPS, bytes_per_device=819e9 * 2,
                  coll_bytes_per_device=50e9 * 0.5, coll_breakdown={},
                  peak_memory_per_device=1e9,
                  model_flops=PEAK_FLOPS * 256 * 0.5,
                  compute_s=1.0, memory_s=2.0, collective_s=0.5)
    assert rl.compute_s == 1.0
    assert rl.memory_s == 2.0
    assert rl.collective_s == 0.5
    assert rl.dominant == "memory"
    assert rl.step_s == 2.0
    assert rl.mfu == 0.25  # 0.5 useful flops / 2.0s step at peak
