"""Dry-run integration: lower+compile on the production meshes (512 host
devices in a subprocess), reduced configs for CI speed. The full-size 40-cell
sweep is the deliverable recorded in EXPERIMENTS.md §Dry-run."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dryrun(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--out", ""] + args
    out = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_smoke_cell_single_pod():
    out = _dryrun(["--arch", "granite-3-8b", "--shape", "train_4k",
                   "--mesh", "single", "--smoke"])
    assert "[ok]" in out


def test_smoke_cell_multi_pod():
    out = _dryrun(["--arch", "rwkv6-1.6b", "--shape", "long_500k",
                   "--mesh", "multi", "--smoke"])
    assert "[ok]" in out


def test_skip_rule_applies():
    out = _dryrun(["--arch", "qwen3-8b", "--shape", "long_500k",
                   "--mesh", "single", "--smoke"])
    assert "[skip]" in out
