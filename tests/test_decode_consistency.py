"""Prefill+decode must reproduce the train-mode forward logits exactly
(same params, same tokens) — KV caches, SSM states, RWKV states, sliding
windows and cross-attention all have to line up for this to hold."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf

ARCHS = ["granite-3-8b", "rwkv6-1.6b", "jamba-v0.1-52b", "whisper-tiny",
         "mixtral-8x22b", "llava-next-34b"]


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    cfg = get_config(name, smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              param_dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, s, extra = 2, 17, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0,
                              cfg.vocab)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :s]}
    if cfg.is_enc_dec:
        fr = jax.random.normal(jax.random.PRNGKey(2),
                               (b, cfg.encoder_len, cfg.d_model), jnp.float32)
        batch_full["frames"] = fr
        batch_pre["frames"] = fr
    if cfg.patch_positions:
        pa = jax.random.normal(jax.random.PRNGKey(3),
                               (b, cfg.patch_positions, cfg.d_model),
                               jnp.float32)
        batch_full["patches"] = pa
        batch_pre["patches"] = pa
    logits_full, _, off = tf.forward(params, cfg, batch_full)
    lg, cache = tf.prefill(params, cfg, batch_pre,
                           s + extra + cfg.patch_positions)
    errs = [np.abs(np.asarray(lg) -
                   np.asarray(logits_full[:, off + s - 1])).max()]
    for j in range(extra):
        lg, cache = tf.decode_step(params, cfg, cache, toks[:, s + j][:, None])
        errs.append(np.abs(np.asarray(lg) -
                           np.asarray(logits_full[:, off + s + j])).max())
    scale = np.abs(np.asarray(logits_full)).max()
    assert max(errs) < 2e-3 * max(scale, 1.0), (name, errs)


def test_cache_position_advances():
    cfg = get_config("granite-3-8b", smoke=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 5), 0,
                                          cfg.vocab)}
    _, cache = tf.prefill(params, cfg, batch, 16)
    assert int(cache["pos"]) == 5
    tok = jnp.zeros((1, 1), jnp.int32)
    _, cache = tf.decode_step(params, cfg, cache, tok)
    assert int(cache["pos"]) == 6


def test_swa_decode_window_bounded():
    """Mixtral's sliding-window cache: decoding far past the window keeps
    logits finite and, once the window has slid, early tokens stop mattering."""
    cfg = get_config("mixtral-8x22b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              param_dtype="float32", swa_window=8)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 12
    t1 = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab)  # differ only at pos 0
    l1, _, _ = tf.forward(params, cfg, {"tokens": t1})
    l2, _, _ = tf.forward(params, cfg, {"tokens": t2})
    # With window 8 and a 2-layer stack, position 11 can still see pos 0
    # transitively through depth; so only check finiteness + shape here.
    assert np.isfinite(np.asarray(l1)).all()
    assert l1.shape == l2.shape
