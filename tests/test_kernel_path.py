"""Fused kernel path + band assembly through the full façade.

`figaro_r0(use_kernel=True)` routes every join-tree node through the
`kernels.node_fused` Pallas kernel (interpret=True on CPU) and
``assembly="band"`` materializes R₀ band-by-band instead of padding every
slab to full width. Both are numerics-preserving options riding the static
half of the dispatch signature, so they must agree with the XLA/padded path
at dtype tolerance through every surface: `Session`/`JoinDataset` compute
methods, capacity-padded plans with dead rows, batched and mesh-sharded
dispatch, and the async server — with zero extra retraces on repeats.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import figaro
from repro.core.engine import FigaroEngine
from repro.core.figaro import assembly_traffic, figaro_r0
from repro.core.join_tree import build_plan
from repro.core.plan_cache import build_capacity_plan, bucket_spec
from repro.data.relational import cartesian, retailer_like, yelp_like

TREES = {
    "retailer": lambda: retailer_like(scale=60, cols=2),
    "yelp": lambda: yelp_like(scale=40, cols=2),  # many-to-many
    "cartesian": lambda: cartesian(7, 5, n1=2, n2=2),
}

ATOL = 1e-9  # f64 pipeline; kernel accumulates in f64 for f64 I/O


def _sessions():
    """(kernel+band session, XLA+padded session) on private engines."""
    k = figaro.Session(engine=FigaroEngine(donate_data=False), bucket=False,
                      use_kernel=True, assembly="band")
    x = figaro.Session(engine=FigaroEngine(donate_data=False), bucket=False)
    return k, x


# -- façade parity: qr / svd / pca / lsq, kernel+band vs XLA+padded ----------


@pytest.mark.parametrize("name", list(TREES))
def test_facade_qr_parity(name):
    tree = TREES[name]()
    sk, sx = _sessions()
    r_k = sk.from_tree(tree).qr(dtype=jnp.float64)
    r_x = sx.from_tree(tree).qr(dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_x), atol=ATOL)


@pytest.mark.parametrize("name", list(TREES))
def test_facade_svd_pca_lsq_parity(name):
    tree = TREES[name]()
    sk, sx = _sessions()
    dk, dx = sk.from_tree(tree), sx.from_tree(tree)

    s_k, vt_k = dk.svd(dtype=jnp.float64)
    s_x, vt_x = dx.svd(dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_x), atol=ATOL)
    np.testing.assert_allclose(np.asarray(vt_k), np.asarray(vt_x), atol=ATOL)

    p_k = dk.pca(k=2, dtype=jnp.float64)
    p_x = dx.pca(k=2, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(p_k.components),
                               np.asarray(p_x.components), atol=ATOL)
    np.testing.assert_allclose(np.asarray(p_k.explained_variance),
                               np.asarray(p_x.explained_variance), atol=ATOL)

    b_k, res_k = dk.lsq(0, dtype=jnp.float64)
    b_x, res_x = dx.lsq(0, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(b_k), np.asarray(b_x), atol=ATOL)
    np.testing.assert_allclose(np.asarray(res_k), np.asarray(res_x),
                               atol=ATOL)


# -- capacity plans: dead (padded) rows stay exactly zero --------------------


@pytest.mark.parametrize("name", list(TREES))
def test_capacity_plan_dead_rows_exactly_zero(name):
    tree = TREES[name]()
    cap = build_capacity_plan(tree, headroom=3)
    eng = FigaroEngine(donate_data=False)
    r0_x = np.asarray(eng.r0(cap, dtype=jnp.float64))
    r0_k = np.asarray(eng.r0(cap, dtype=jnp.float64, use_kernel=True,
                             assembly="band"))
    np.testing.assert_allclose(r0_k, r0_x, atol=ATOL)
    # headroom=3 guarantees dead slots; their R0 rows must be EXACTLY zero
    # through the kernel path (masking rides the kernel's data_scale input,
    # not a separate pre-pass).
    dead = ~np.any(r0_x, axis=1)
    assert dead.any(), "capacity plan with headroom should have dead rows"
    assert not np.any(r0_k[dead]), "kernel path leaked into dead R0 rows"

    r_x = np.asarray(eng.qr(cap, dtype=jnp.float64))
    r_k = np.asarray(eng.qr(cap, dtype=jnp.float64, use_kernel=True,
                            assembly="band"))
    np.testing.assert_allclose(r_k, r_x, atol=ATOL)


# -- batched / sharded dispatch + zero extra retraces ------------------------


def test_batched_and_sharded_kernel_dispatch_zero_retraces():
    tree = retailer_like(scale=60, cols=2)
    cap = build_capacity_plan(tree, headroom=3)
    eng = FigaroEngine(donate_data=False)
    rng = np.random.default_rng(0)
    B = 3
    batch = tuple(
        jnp.asarray(np.stack([np.asarray(d, np.float64) * (1 + 0.1 * b)
                              for b in range(B)]))
        for d in cap.data)

    rb_k = eng.qr(cap, batch, batched=True, dtype=jnp.float64,
                  use_kernel=True, assembly="band")
    rb_x = eng.qr(cap, batch, batched=True, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(rb_k), np.asarray(rb_x), atol=ATOL)

    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh()
    rs_k = eng.qr(cap, batch, batched=True, shard=mesh, dtype=jnp.float64,
                  use_kernel=True, assembly="band")
    np.testing.assert_allclose(np.asarray(rs_k), np.asarray(rb_x), atol=ATOL)

    # Every signature is now compiled: repeats are launch-only.
    traces = eng.trace_counts()
    _ = eng.qr(cap, batch, batched=True, dtype=jnp.float64,
               use_kernel=True, assembly="band")
    _ = eng.qr(cap, batch, batched=True, shard=mesh, dtype=jnp.float64,
               use_kernel=True, assembly="band")
    assert eng.trace_counts() == traces, "kernel-path repeat retraced"


def test_kernel_and_assembly_are_distinct_cache_entries():
    tree = cartesian(7, 5, n1=2, n2=2)
    plan = build_plan(tree)
    eng = FigaroEngine(donate_data=False)
    for use_kernel in (False, True):
        for asm in ("padded", "band"):
            eng.qr(plan, dtype=jnp.float64, use_kernel=use_kernel,
                   assembly=asm)
    assert eng.trace_count("qr") == 4  # four static corners, four traces
    for use_kernel in (False, True):  # repeats: zero extra
        for asm in ("padded", "band"):
            eng.qr(plan, dtype=jnp.float64, use_kernel=use_kernel,
                   assembly=asm)
    assert eng.trace_count("qr") == 4


# -- async server ------------------------------------------------------------


def test_async_server_kernel_parity():
    tree = retailer_like(scale=60, cols=2)
    sk, sx = _sessions()
    dk, dx = sk.from_tree(tree), sx.from_tree(tree)
    req = tuple(np.asarray(d, np.float64) for d in dk.plan.data)
    srv_k = dk.serve("qr", dtype=jnp.float64)
    srv_x = dx.serve("qr", dtype=jnp.float64)
    try:
        r_k = srv_k.submit(req).result()
        r_x = srv_x.submit(req).result()
    finally:
        srv_k.close()
        srv_x.close()
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_x), atol=ATOL)


# -- band assembly layout + traffic model ------------------------------------


@pytest.mark.parametrize("name", list(TREES))
def test_band_assembly_bit_identical(name):
    tree = TREES[name]()
    plan = build_plan(tree)
    r_pad = figaro_r0(plan, dtype=jnp.float64, assembly="padded")
    r_band = figaro_r0(plan, dtype=jnp.float64, assembly="band")
    np.testing.assert_array_equal(np.asarray(r_pad), np.asarray(r_band))


@pytest.mark.parametrize("name", list(TREES))
def test_band_assembly_traffic_reduction(name):
    spec = build_plan(TREES[name]()).spec
    assert assembly_traffic(spec, assembly="band") <= \
        assembly_traffic(spec, assembly="padded")
    # Bands tile R0's rows exactly once: every R0 row belongs to one band.
    covered = np.zeros(spec.r0_rows, bool)
    for b in spec.bands:
        assert 0 <= b.col0 and b.col0 + b.width <= spec.num_cols
        assert not covered[b.row0:b.row0 + b.rows].any(), "band overlap"
        covered[b.row0:b.row0 + b.rows] = True
    assert covered.all(), "bands leave R0 rows uncovered"


def test_bands_recomputed_under_bucketing():
    spec = build_plan(retailer_like(scale=60, cols=2)).spec
    bucketed = bucket_spec(spec, headroom=3)
    assert bucketed.bands != spec.bands  # capacities changed the layout
    assert bucketed.bands == type(bucketed)(  # derived, never stale
        nodes=bucketed.nodes, preorder=bucketed.preorder, root=bucketed.root,
        num_cols=bucketed.num_cols, total_rows=bucketed.total_rows,
        r0_rows=bucketed.r0_rows, names=bucketed.names).bands


def test_bad_assembly_rejected():
    plan = build_plan(cartesian(3, 3, n1=1, n2=1))
    with pytest.raises(ValueError, match="assembly"):
        figaro_r0(plan, dtype=jnp.float64, assembly="diagonal")
