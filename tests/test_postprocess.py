"""§7 post-processing: R0 -> R triangularization variants (incl. THIN/TSQR)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.postprocess import (blocked_qr_r, householder_qr_r,
                                    normalize_sign, tsqr_r)
from repro.core.qr import givens_qr_r


def _variants(x, **kw):
    return {
        "householder": householder_qr_r(x),
        "blocked": blocked_qr_r(x, panel=kw.get("panel", 8)),
        "tsqr": tsqr_r(x, leaf_rows=kw.get("leaf_rows", 16)),
        "lapack": jnp.linalg.qr(x, mode="r"),
    }


@pytest.mark.parametrize("m,n", [(12, 3), (70, 9), (33, 32), (128, 16)])
def test_qr_variants_agree(rng, m, n):
    x = jnp.array(rng.normal(size=(m, n)))
    rs = {k: np.asarray(normalize_sign(v)) for k, v in _variants(x).items()}
    base = rs.pop("lapack")
    for name, r in rs.items():
        np.testing.assert_allclose(r, base, atol=1e-9 * np.abs(base).max(),
                                   err_msg=name)


def test_givens_dense_qr(rng):
    x = jnp.array(rng.normal(size=(20, 6)))
    r = np.asarray(normalize_sign(givens_qr_r(x)))
    ref = np.asarray(normalize_sign(jnp.linalg.qr(x, mode="r")))
    np.testing.assert_allclose(r, ref, atol=1e-10 * np.abs(ref).max())


def test_normalize_sign_makes_diag_positive(rng):
    x = jnp.array(rng.normal(size=(30, 7)))
    r = np.asarray(normalize_sign(jnp.linalg.qr(x, mode="r")))
    assert (np.diag(r) >= 0).all()


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32,
                                   jnp.float64])
def test_normalize_sign_preserves_dtype(rng, dtype):
    """The sign vector is built in r.dtype — low-precision R (bf16/f16
    serving) must come back un-upcast, with the same |values|."""
    r = jnp.asarray(rng.normal(size=(9, 9)), dtype=dtype)
    out = normalize_sign(r)
    assert out.dtype == dtype, (out.dtype, dtype)
    np.testing.assert_array_equal(np.abs(np.asarray(out, np.float64)),
                                  np.abs(np.asarray(r, np.float64)))
    assert (np.diag(np.asarray(out, np.float64)) >= 0).all()


def test_tsqr_leaf_insensitivity(rng):
    """TSQR's combine order (leaf size) must not change R — the same freedom
    the paper's THIN exploits across threads."""
    x = jnp.array(rng.normal(size=(200, 10)))
    rs = [np.asarray(normalize_sign(tsqr_r(x, leaf_rows=lr)))
          for lr in (16, 32, 64, 200)]
    for r in rs[1:]:
        np.testing.assert_allclose(r, rs[0], atol=1e-9 * np.abs(rs[0]).max())


def test_gram_preserved_by_all_variants(rng):
    x = jnp.array(rng.normal(size=(50, 8)))
    g = np.asarray(x.T @ x)
    for name, r in _variants(x).items():
        rn = np.asarray(r)
        np.testing.assert_allclose(rn.T @ rn, g, rtol=1e-9, atol=1e-9,
                                   err_msg=name)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 60), n=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_property_tsqr_equals_lapack(m, n, seed):
    if m < n:
        return
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(m, n)))
    r1 = np.asarray(normalize_sign(tsqr_r(x, leaf_rows=8)))
    r2 = np.asarray(normalize_sign(jnp.linalg.qr(x, mode="r")))
    np.testing.assert_allclose(r1, r2, atol=1e-8 * max(np.abs(r2).max(), 1))
