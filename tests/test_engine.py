"""Compiled FiGaRo engine: plan-as-pytree jit, batched serving, cache hits,
and the scatter-free R₀ assembly path."""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import FigaroEngine
from repro.core.figaro import figaro_r0, figaro_r0_batched
from repro.core.join_tree import build_plan
from repro.core.materialize import materialize_join
from repro.data.relational import cartesian

from helpers import random_acyclic_db

# Batched-vs-per-sample coverage: a path join, a star join, and a Cartesian
# edge (constant keys => the degenerate single-group path).
BATCH_TOPOLOGIES = {
    "path": ("chain3", False),
    "star": ("star3", False),
    "cartesian": ("chain2", True),
}


def _plan(topology, rng):
    name, cart = BATCH_TOPOLOGIES[topology]
    _, tree, plan = random_acyclic_db(name, rng, cartesian=cart)
    return tree, plan


def _batch(plan, rng, b, dtype):
    return tuple(
        np.stack([rng.normal(size=np.asarray(d).shape) for _ in range(b)])
        .astype(dtype) for d in plan.data)


# -- acceptance: batched == per-sample on >= 3 join topologies ----------------


@pytest.mark.parametrize("topology", list(BATCH_TOPOLOGIES))
@pytest.mark.parametrize("dtype,tol", [(np.float32, 1e-5),
                                       (np.float64, 1e-10)])
def test_batched_r0_matches_per_sample(rng, topology, dtype, tol):
    _, plan = _plan(topology, rng)
    batch = _batch(plan, rng, 4, dtype)
    rb = np.asarray(figaro_r0_batched(plan, batch, dtype=dtype))
    scale = max(np.abs(rb).max(), 1.0)
    for i in range(4):
        ri = np.asarray(figaro_r0(plan, [d[i] for d in batch], dtype=dtype))
        assert np.abs(rb[i] - ri).max() / scale < tol, (topology, i)


@pytest.mark.parametrize("topology", list(BATCH_TOPOLOGIES))
def test_engine_batched_qr_matches_per_sample(rng, topology):
    _, plan = _plan(topology, rng)
    # donate_data=False: the per-sample loop below re-reads `batch` after the
    # batched dispatch, which would read donated buffers on TPU (FIG011).
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 3, np.float64)
    rb = np.asarray(engine.qr(plan, batch, batched=True, dtype=jnp.float64))
    for i in range(3):
        ri = np.asarray(engine.qr(plan, [d[i] for d in batch],
                                  dtype=jnp.float64))
        np.testing.assert_allclose(rb[i], ri, atol=1e-10 * max(
            np.abs(ri).max(), 1.0), err_msg=topology)


def test_batched_gram_invariant(rng):
    """Sample 0 of the batch is the plan's own data: R₀ᵀR₀ == AᵀA against the
    materialized join, per batch element."""
    tree, plan = _plan("star", rng)
    a = np.asarray(materialize_join(tree))
    other = tuple(
        np.stack([np.asarray(d), 2.0 * np.asarray(d)]) for d in plan.data)
    rb = np.asarray(figaro_r0_batched(plan, other, dtype=jnp.float64))
    g = a.T @ a
    err0 = np.abs(rb[0].T @ rb[0] - g).max() / max(np.abs(g).max(), 1e-30)
    err1 = np.abs(rb[1].T @ rb[1] - 4.0 * g).max() / max(np.abs(g).max(), 1e-30)
    assert err0 < 1e-11 and err1 < 1e-10, (err0, err1)


# -- acceptance: one compilation per plan signature ---------------------------


def test_engine_cache_hit_same_plan(rng):
    _, plan = _plan("path", rng)
    engine = FigaroEngine()
    engine.qr(plan, dtype=jnp.float64)
    assert engine.trace_count("qr") == 1
    engine.qr(plan, dtype=jnp.float64)  # same plan, same signature
    assert engine.trace_count("qr") == 1


def test_engine_cache_hit_across_plans_same_signature(rng):
    """A *different* plan object with equal static spec + data shapes must not
    retrace — the signature, not the identity, keys the executable cache."""
    _, plan = _plan("star", rng)
    engine = FigaroEngine()
    engine.qr(plan, dtype=jnp.float64)
    plan2 = plan.with_data([2.0 * np.asarray(d) for d in plan.data])
    r2 = engine.qr(plan2, dtype=jnp.float64)
    assert engine.trace_count("qr") == 1, "same-signature plan retraced"
    # and it really used plan2's data
    r1 = engine.qr(plan, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(r2), 2.0 * np.asarray(r1),
                               atol=1e-9 * np.abs(np.asarray(r1)).max())


def test_engine_retraces_on_new_signature(rng):
    _, plan_a = _plan("path", rng)
    _, plan_b = _plan("star", rng)  # different topology => different spec
    engine = FigaroEngine()
    engine.qr(plan_a, dtype=jnp.float64)
    engine.qr(plan_b, dtype=jnp.float64)
    assert engine.trace_count("qr") == 2
    engine.qr(plan_a, dtype=jnp.float64)
    engine.qr(plan_b, dtype=jnp.float64)
    assert engine.trace_count("qr") == 2


def test_engine_batched_cache_hit(rng):
    _, plan = _plan("cartesian", rng)
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 2, np.float64)
    engine.r0(plan, batch, batched=True, dtype=jnp.float64)
    engine.r0(plan, batch, batched=True, dtype=jnp.float64)
    assert engine.trace_count("r0_batched") == 1


# -- acceptance: scatter-free R0 assembly, plan passes through jit ------------


def test_r0_assembly_is_scatter_free(rng):
    """The R₀ emission path must contain no scatter / dynamic_update_slice —
    only concatenation/padding. (scatter-add from the counts' segment_sum is
    fine: that's Algorithm 1's reduction, not R₀ assembly.)"""
    for topology in BATCH_TOPOLOGIES:
        _, plan = _plan(topology, rng)
        jaxpr = str(jax.make_jaxpr(
            lambda p, d: figaro_r0(p, list(d), dtype=jnp.float64))(
                plan.without_data(), plan.data))
        assert "dynamic_update_slice" not in jaxpr, topology
        assert not re.search(r"\bscatter\[", jaxpr), topology


def test_figaro_r0_jits_with_plan_argument(rng):
    """The plan crosses the jit boundary as a pytree argument; the traced
    function is plan-generic (no closure rebuild per plan)."""
    _, plan = _plan("star", rng)
    traces = []

    @jax.jit
    def f(p, d):
        traces.append(1)  # figaro-lint: disable=FIG010 -- once-per-trace append IS the retrace probe
        return figaro_r0(p, list(d), dtype=jnp.float64)

    r_a = f(plan.without_data(), plan.data)
    plan2 = plan.with_data([3.0 * np.asarray(d) for d in plan.data])
    r_b = f(plan2.without_data(), plan2.data)
    assert len(traces) == 1
    np.testing.assert_allclose(np.asarray(r_b), 3.0 * np.asarray(r_a),
                               atol=1e-9 * np.abs(np.asarray(r_a)).max())


def test_plan_pytree_roundtrip(rng):
    _, plan = _plan("path", rng)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert plan2.spec == plan.spec
    r1 = np.asarray(figaro_r0(plan, dtype=jnp.float64))
    r2 = np.asarray(figaro_r0(plan2, dtype=jnp.float64))
    np.testing.assert_array_equal(r1, r2)


# -- engine downstream reads on the Cartesian-edge schema ---------------------


def test_make_figaro_server_batched_qr_and_lsq(rng):
    from repro.train.serve import make_figaro_server

    _, plan = _plan("star", rng)
    batch = _batch(plan, rng, 3, np.float64)
    serve_qr = make_figaro_server(plan, kind="qr", dtype=jnp.float64)
    rb = np.asarray(serve_qr(batch))
    engine = FigaroEngine()
    for i in range(3):
        ri = np.asarray(engine.qr(plan, [d[i] for d in batch],
                                  dtype=jnp.float64))
        np.testing.assert_allclose(rb[i], ri,
                                   atol=1e-10 * max(np.abs(ri).max(), 1.0))

    if plan.num_cols >= 2:
        serve_lsq = make_figaro_server(plan, kind="lsq",
                                       label_col=plan.num_cols - 1,
                                       dtype=jnp.float64)
        betas, resids = serve_lsq(batch)
        assert betas.shape == (3, plan.num_cols - 1)
        assert resids.shape == (3,)


def test_engine_svd_cartesian_edge():
    tree = cartesian(9, 6, n1=2, n2=2, seed=3)
    plan = build_plan(tree)
    engine = FigaroEngine()
    s, vt = engine.svd(plan, dtype=jnp.float64)
    a = np.asarray(materialize_join(tree))
    np.testing.assert_allclose(np.asarray(s),
                               np.linalg.svd(a, compute_uv=False), rtol=1e-9)
    assert engine.trace_count("svd") == 1
    engine.svd(plan, dtype=jnp.float64)
    assert engine.trace_count("svd") == 1


# -- genuinely-batched pca / least_squares ------------------------------------


def test_engine_batched_least_squares_matches_per_sample(rng):
    _, plan = _plan("star", rng)
    label = plan.num_cols - 1
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 3, np.float64)
    betas, resids = engine.least_squares(plan, label, batch, batched=True,
                                         ridge=0.4, dtype=jnp.float64)
    assert engine.trace_count("least_squares_batched") == 1
    for i in range(3):
        b_i, r_i = engine.least_squares(plan, label, [d[i] for d in batch],
                                        ridge=0.4, dtype=jnp.float64)
        np.testing.assert_allclose(np.asarray(betas[i]), np.asarray(b_i),
                                   atol=1e-10)
        np.testing.assert_allclose(np.asarray(resids[i]), np.asarray(r_i),
                                   atol=1e-10)


def test_engine_batched_pca_matches_per_sample(rng):
    _, plan = _plan("path", rng)
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 3, np.float64)
    res = engine.pca(plan, batch, batched=True, k=2, dtype=jnp.float64)
    assert engine.trace_count("pca_batched") == 1
    assert res.explained_variance.shape == (3, 2)
    for i in range(3):
        ref = engine.pca(plan, [d[i] for d in batch], k=2, dtype=jnp.float64)
        np.testing.assert_allclose(
            np.asarray(res.explained_variance[i]),
            np.asarray(ref.explained_variance), atol=1e-10)
        np.testing.assert_allclose(np.asarray(res.mean[i]),
                                   np.asarray(ref.mean), atol=1e-12)


def test_lsq_server_is_single_dispatch(rng):
    """kind='lsq' must answer the whole batch through the batched executable,
    never a per-sample Python loop of engine dispatches."""
    from repro.train.serve import make_figaro_server

    _, plan = _plan("star", rng)
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 4, np.float64)
    serve = make_figaro_server(plan, kind="lsq", label_col=plan.num_cols - 1,
                               dtype=jnp.float64, engine=engine)
    betas, resids = serve(batch)
    assert betas.shape == (4, plan.num_cols - 1) and resids.shape == (4,)
    assert engine.trace_count("least_squares_batched") == 1
    assert engine.trace_count("least_squares") == 0
    serve(batch)
    assert engine.trace_count("least_squares_batched") == 1


# -- regression: ridge residual & PCA eigenvalue clamp ------------------------


def test_least_squares_ridge_residual_is_true_residual(rng):
    """resid must be ‖Aβ − y‖ of the *ridge* solution — |rr[n-1,n-1]| alone
    understates it for every regularized regression."""
    tree, plan = _plan("path", rng)
    a = np.asarray(materialize_join(tree))
    n = plan.num_cols
    if n < 2:
        pytest.skip("needs >= 2 columns")
    x, y = a[:, : n - 1], a[:, n - 1]
    ridge = 0.7
    beta_ref = np.linalg.solve(x.T @ x + ridge * np.eye(n - 1), x.T @ y)
    resid_ref = np.linalg.norm(x @ beta_ref - y)
    engine = FigaroEngine()
    beta, resid = engine.least_squares(plan, n - 1, ridge=ridge,
                                       dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(beta), beta_ref, atol=1e-9)
    np.testing.assert_allclose(float(resid), resid_ref, rtol=1e-9)


def test_pca_explained_variance_nonnegative_near_constant_column(rng):
    """The centered-Gram subtraction can leave tiny negative eigenvalues; the
    engine must clamp them at 0 before the top-k select."""
    _, plan = _plan("star", rng)
    data = [np.array(d, dtype=np.float64, copy=True) for d in plan.data]
    data[0][:, 0] = 1.0  # constant column over the join -> zero variance
    engine = FigaroEngine()
    res = engine.pca(plan.with_data(data), dtype=jnp.float64)
    ev = np.asarray(res.explained_variance)
    assert (ev >= 0.0).all(), ev
    # descending order must survive the clamp
    assert (np.diff(ev) <= 1e-12).all(), ev


# -- sharded dispatch plumbing on the in-process (1-device) mesh --------------


def test_sharded_dispatch_single_device_mesh(rng):
    """shard= on a 1-device data mesh is the degenerate case of the sharded
    serving layer: same results as the unsharded batched dispatch, separate
    executable-cache entry (mesh signature), shard without batched rejected.
    Real multi-device coverage lives in tests/_sharded_driver.py."""
    from repro.launch.mesh import make_data_mesh

    _, plan = _plan("star", rng)
    engine = FigaroEngine(donate_data=False)
    batch = _batch(plan, rng, 3, np.float64)
    mesh = make_data_mesh()
    r_plain = np.asarray(engine.qr(plan, batch, batched=True,
                                   dtype=jnp.float64))
    r_shard = np.asarray(engine.qr(plan, batch, batched=True, shard=mesh,
                                   dtype=jnp.float64))
    np.testing.assert_allclose(r_shard, r_plain, atol=1e-12)
    assert engine.trace_count("qr_batched") == 2  # mesh vs None signatures
    engine.qr(plan, batch, batched=True, shard=mesh, dtype=jnp.float64)
    assert engine.trace_count("qr_batched") == 2
    with pytest.raises(ValueError, match="batched"):
        engine.qr(plan, [d[0] for d in batch], shard=mesh, dtype=jnp.float64)
    with pytest.raises(ValueError, match="axis"):
        engine.qr(plan, batch, batched=True, shard=(mesh, "model"),
                  dtype=jnp.float64)


def test_sharded_dispatch_empty_batch(rng):
    """B=0: the pad-by-repeating-the-trailing-request bucketing would index
    an empty batch out of range — the engine must return correctly-shaped
    empty results instead."""
    from repro.launch.mesh import make_data_mesh

    _, plan = _plan("star", rng)
    engine = FigaroEngine(donate_data=False)
    mesh = make_data_mesh()
    n = plan.num_cols
    empty = tuple(np.zeros((0,) + np.asarray(d).shape, np.float64)
                  for d in plan.data)
    r = engine.qr(plan, empty, batched=True, shard=mesh, dtype=jnp.float64)
    assert np.asarray(r).shape == (0, n, n)
    betas, resids = engine.least_squares(plan, n - 1, empty, batched=True,
                                         shard=mesh, dtype=jnp.float64)
    assert np.asarray(betas).shape == (0, n - 1)
    assert np.asarray(resids).shape == (0,)


def test_sharded_dispatch_single_request_batch(rng):
    """B=1 (the smallest bucketable batch) matches the unsharded dispatch."""
    from repro.launch.mesh import make_data_mesh

    _, plan = _plan("star", rng)
    engine = FigaroEngine(donate_data=False)
    mesh = make_data_mesh()
    batch = _batch(plan, rng, 1, np.float64)
    r_shard = np.asarray(engine.qr(plan, batch, batched=True, shard=mesh,
                                   dtype=jnp.float64))
    r_plain = np.asarray(engine.qr(plan, [d[0] for d in batch],
                                   dtype=jnp.float64))
    assert r_shard.shape[0] == 1
    np.testing.assert_allclose(r_shard[0], r_plain, atol=1e-12)
