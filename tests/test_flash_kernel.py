"""kernels/flash_attn vs ref.py oracle (interpret mode) + model integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn import ref as fa_ref
from repro.models import transformer as tf


def _ref_folded(q, k, v, qpos, kpos, causal, window):
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qh = (q.reshape(b, tq, hkv, g, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * g, tq, hd))
    kh = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * hkv, tk, hd), g, 0)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * hkv, tk, hd), g, 0)
    qp = jnp.broadcast_to(qpos[None], (b * hkv * g, tq))
    kp = jnp.broadcast_to(kpos[None], (b * hkv * g, tk))
    out = fa_ref.flash_attention_ref(qh, kh, vh, qp, kp, causal=causal,
                                     window=window)
    return (out.reshape(b, hkv, g, tq, hd).transpose(0, 3, 1, 2, 4)
            .reshape(b, tq, hq, hd))


@pytest.mark.parametrize("b,tq,tk,hq,hkv,hd,causal,window", [
    (1, 8, 8, 2, 2, 128, True, None),
    (2, 128, 128, 4, 2, 128, True, None),
    (1, 100, 260, 4, 4, 128, True, None),   # unaligned; tk > tq (KV cache)
    (2, 128, 384, 8, 2, 128, True, 96),     # GQA + sliding window
    (1, 64, 64, 2, 1, 256, False, None),    # non-causal (encoder)
])
def test_flash_vs_ref(rng, b, tq, tk, hq, hkv, hd, causal, window):
    q = jnp.array(rng.normal(size=(b, tq, hq, hd)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, tk, hkv, hd)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, tk, hkv, hd)), jnp.float32)
    qpos = jnp.arange(tk - tq, tk, dtype=jnp.int32)
    kpos = jnp.arange(tk, dtype=jnp.int32)
    out_k = fa_ops.flash_attention(q, k, v, qpos, kpos, causal=causal,
                                   window=window, block_q=64, block_kv=128)
    out_r = _ref_folded(q, k, v, qpos, kpos, causal, window)
    assert float(jnp.abs(out_k - out_r).max()) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(rng, dtype):
    q = jnp.array(rng.normal(size=(1, 64, 4, 128)), dtype)
    k = jnp.array(rng.normal(size=(1, 64, 4, 128)), dtype)
    v = jnp.array(rng.normal(size=(1, 64, 4, 128)), dtype)
    pos = jnp.arange(64, dtype=jnp.int32)
    out_k = fa_ops.flash_attention(q, k, v, pos, pos, block_q=64,
                                   block_kv=64)
    out_r = _ref_folded(q, k, v, pos, pos, True, None)
    assert out_k.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(out_k.astype(jnp.float32)
                         - out_r.astype(jnp.float32)).max()) < tol


def test_model_forward_with_flash_kernel_matches_default():
    cfg = get_config("granite-3-8b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              param_dtype="float32", head_dim=128)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                          cfg.vocab)}
    l1, _, _ = tf.forward(params, cfg, batch)
    cfg_f = dataclasses.replace(cfg, use_flash_kernel=True)
    l2, _, _ = tf.forward(params, cfg_f, batch)
    err = float(jnp.abs(l1 - l2).max())
    assert err < 1e-3 * float(jnp.abs(l1).max()), err
