"""Multi-device correctness (8 host devices, fresh subprocess — the XLA
device count must be pinned before jax initializes, so it cannot run
in-process with the rest of the suite)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, script], cwd=ROOT, env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_figaro_and_tsqr():
    out = _run(os.path.join("tests", "_distributed_driver.py"))
    assert "DISTRIBUTED-OK" in out
