"""`repro.figaro` façade: Session/JoinDataset parity with the legacy entry
points, plan-lifecycle (zero-retrace appends), engine LRU bounds, and the
clear-error contracts."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import figaro
from repro.core.engine import FigaroEngine, plan_for
from repro.core.join_tree import JoinTree, build_plan
from repro.core.qr import figaro_qr
from repro.core.relation import Database
from repro.core.svd import (least_squares_over_join, pca_over_join,
                            svd_over_join)
from repro.data.relational import cartesian, retailer_like, yelp_like

TREES = {
    "retailer": lambda: retailer_like(scale=60, cols=2),
    "yelp": lambda: yelp_like(scale=40, cols=2),  # many-to-many
    "cartesian": lambda: cartesian(7, 5, n1=2, n2=2),
}


def _star_tables(m_fact: int):
    """Star schema with exactly 8 distinct fact keys for any m_fact >= 8, so
    different fact sizes in one power-of-two bucket share a capacity spec."""
    rng = np.random.default_rng(m_fact)
    return {
        "Orders": ({"cust": np.arange(m_fact) % 8,
                    "prod": np.arange(m_fact) % 4},
                   rng.normal(size=(m_fact, 2)), ["amount", "qty"]),
        "Customers": ({"cust": np.arange(8)},
                      rng.normal(size=(8, 2)), ["age", "income"]),
        "Products": ({"prod": np.arange(4)},
                     rng.normal(size=(4, 1)), ["price"]),
    }


_STAR_EDGES = [("Orders", "Customers"), ("Orders", "Products")]


def _star_ds(session, m_fact=20):
    return session.ingest(_star_tables(m_fact)).join("Orders", _STAR_EDGES)


# -- golden parity: the façade is bit-identical to the legacy paths ----------


@pytest.mark.parametrize("name", list(TREES))
def test_qr_parity_bit_identical(name):
    tree = TREES[name]()
    ds = figaro.Session(bucket=False).from_tree(tree)
    r_legacy = np.asarray(figaro_qr(build_plan(tree), dtype=jnp.float64))
    np.testing.assert_array_equal(
        np.asarray(ds.qr(dtype=jnp.float64)), r_legacy, err_msg=name)


@pytest.mark.parametrize("name", list(TREES))
def test_svd_pca_lsq_parity_bit_identical(name):
    tree = TREES[name]()
    plan = build_plan(tree)
    ds = figaro.Session(bucket=False).from_tree(tree)

    s, vt = ds.svd()
    s_ref, vt_ref = svd_over_join(plan)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(vt), np.asarray(vt_ref))

    pca = ds.pca(k=2)
    pca_ref = pca_over_join(plan, k=2)
    np.testing.assert_array_equal(np.asarray(pca.explained_variance),
                                  np.asarray(pca_ref.explained_variance))
    np.testing.assert_array_equal(np.asarray(pca.components),
                                  np.asarray(pca_ref.components))
    np.testing.assert_array_equal(np.asarray(pca.mean),
                                  np.asarray(pca_ref.mean))

    label = plan.num_cols - 1
    beta, resid = ds.lsq(label, ridge=0.3)
    beta_ref, resid_ref = least_squares_over_join(plan, label, ridge=0.3)
    np.testing.assert_array_equal(np.asarray(beta), np.asarray(beta_ref))
    np.testing.assert_array_equal(np.asarray(resid), np.asarray(resid_ref))


def test_qr_parity_engine_path_and_bucketed():
    """Direct engine dispatch == ds.qr, and the bucketed (capacity) session
    agrees with the exact path to float64 round-off."""
    tree = TREES["retailer"]()
    plan = build_plan(tree)
    engine = FigaroEngine(donate_data=False)
    r_engine = np.asarray(engine.qr(plan, dtype=jnp.float64))
    np.testing.assert_array_equal(
        np.asarray(figaro.Session(bucket=False).from_tree(tree)
                   .qr(dtype=jnp.float64)), r_engine)
    r_cap = np.asarray(figaro.Session(bucket=True, headroom=8)
                       .from_tree(tree).qr(dtype=jnp.float64))
    np.testing.assert_allclose(r_cap, r_engine,
                               atol=1e-10 * max(np.abs(r_engine).max(), 1.0))


def test_batched_auto_detect_matches_per_sample():
    """A leading batch axis flips to the batched dispatch; per-row results
    match the per-sample dispatch bit for bit."""
    sess = figaro.Session()
    ds = _star_ds(sess)
    rng = np.random.default_rng(1)
    cap_shapes = [np.asarray(d).shape for d in ds.plan.data]
    batch = tuple(np.stack([rng.normal(size=s) for _ in range(3)])
                  for s in cap_shapes)
    rb = np.asarray(ds.qr(batch, dtype=jnp.float64))
    assert rb.shape == (3, ds.plan.num_cols, ds.plan.num_cols)
    assert sess.engine.trace_count("qr_batched") == 1
    for i in range(3):
        ri = np.asarray(ds.qr([d[i] for d in batch], dtype=jnp.float64))
        np.testing.assert_allclose(rb[i], ri,
                                   atol=1e-10 * max(np.abs(ri).max(), 1.0))


# -- bucketed sessions: near-miss shapes share one executable ----------------


def test_bucket_true_shares_executable_across_near_miss_shapes():
    sess = figaro.Session(bucket=True)
    ds_a = _star_ds(sess, m_fact=20)  # fact rows bucket to 32
    ds_b = _star_ds(sess, m_fact=24)  # near-miss: same bucket, same schema
    ds_a.qr(dtype=jnp.float64)
    assert sess.engine.trace_count("qr") == 1
    ds_b.qr(dtype=jnp.float64)
    assert sess.engine.trace_count("qr") == 1, \
        "near-miss shapes in one bucket must share the executable"
    assert ds_a.plan.spec == ds_b.plan.spec


def test_bucket_false_distinct_shapes_compile_separately():
    sess = figaro.Session(bucket=False)
    _star_ds(sess, m_fact=20).qr(dtype=jnp.float64)
    _star_ds(sess, m_fact=24).qr(dtype=jnp.float64)
    assert sess.engine.trace_count("qr") == 2


# -- plan lifecycle: lazy build, zero-retrace appends, stats -----------------


def test_plan_is_lazy_and_append_before_compute_grows_tables():
    ds = _star_ds(figaro.Session(headroom=8))
    assert ds.stats()["plan_built"] is False
    assert ds.append("Orders", {"cust": np.array([0, 1]),
                                "prod": np.array([0, 1])},
                     np.ones((2, 2)))
    assert ds.stats()["plan_built"] is False  # still no plan
    assert ds.stats()["nodes"]["Orders"]["live_rows"] == 22
    r = ds.qr(dtype=jnp.float64)  # first compute builds the capacity plan
    st = ds.stats()
    assert st["plan_built"] and r.shape == (5, 5)
    assert st["nodes"]["Orders"]["live_rows"] == 22
    assert st["nodes"]["Orders"]["capacity_rows"] >= 22 + 8


def test_append_within_capacity_is_zero_retrace():
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    ds.qr(dtype=jnp.float64)
    traces = sess.engine.trace_count("qr")
    in_cap = ds.append("Orders", {"cust": np.array([2, 3]),
                                  "prod": np.array([2, 3])},
                       np.ones((2, 2)) * 0.5)
    assert in_cap is True
    r = np.asarray(ds.qr(dtype=jnp.float64))
    st = ds.stats()
    assert st["traces"]["qr"] == traces, "append must not retrace"
    assert st["appends"] == 1 and st["regrows"] == 0
    # the appended rows are really in the answer
    tree_now = ds.tree
    r_ref = np.asarray(figaro_qr(build_plan(tree_now), dtype=jnp.float64))
    np.testing.assert_allclose(r, r_ref,
                               atol=1e-10 * max(np.abs(r_ref).max(), 1.0))


def test_bucket_false_regrow_keeps_exact_capacities():
    """A bucket=False dataset must keep capacities == live sizes across
    regrows — refresh_plan's power-of-two regrowth must not leak in (it
    would silently flip the dataset onto the bucketed masked path)."""
    sess = figaro.Session(bucket=False)
    ds = _star_ds(sess)
    ds.qr(dtype=jnp.float64)
    for step in range(2):  # every append overflows: one retrace each
        assert ds.append("Orders", {"cust": np.array([0]),
                                    "prod": np.array([0])},
                         np.ones((1, 2))) is False
        ds.qr(dtype=jnp.float64)
        st = ds.stats()
        orders = st["nodes"]["Orders"]
        assert orders["capacity_rows"] == orders["live_rows"] == 21 + step
        assert st["regrows"] == step + 1
        assert st["traces"]["qr"] == 2 + step
    tree_now = ds.tree
    np.testing.assert_array_equal(
        np.asarray(ds.qr(dtype=jnp.float64)),
        np.asarray(figaro_qr(build_plan(tree_now), dtype=jnp.float64)))


def test_append_past_capacity_regrows_once():
    sess = figaro.Session(headroom=0)
    ds = _star_ds(sess, m_fact=32)  # fact sits exactly on its bucket
    ds.qr(dtype=jnp.float64)
    traces = sess.engine.trace_count("qr")
    in_cap = ds.append("Orders", {"cust": np.array([0]),
                                  "prod": np.array([0])}, np.ones((1, 2)))
    assert in_cap is False
    ds.qr(dtype=jnp.float64)
    st = ds.stats()
    assert st["traces"]["qr"] == traces + 1  # exactly one regrow retrace
    assert st["regrows"] == 1


def test_live_sized_requests_padded_stale_rejected():
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    rng = np.random.default_rng(2)
    live = tuple(rng.normal(size=(ds.tree.db[n].num_rows,
                                  ds.tree.db[n].num_data_cols))
                 for n in ds.tree.preorder())
    r_live = np.asarray(ds.qr(live, dtype=jnp.float64))  # padded up inside
    cap = tuple(np.zeros(np.asarray(d).shape) for d in ds.plan.data)
    for c, l in zip(cap, live):
        c[: l.shape[0]] = l
    np.testing.assert_array_equal(r_live,
                                  np.asarray(ds.qr(cap, dtype=jnp.float64)))
    ds.append("Orders", {"cust": np.array([0]), "prod": np.array([0])},
              np.ones((1, 2)))
    with pytest.raises(ValueError, match="rebuild request buffers"):
        ds.qr(live, dtype=jnp.float64)  # stale: built before the append
    with pytest.raises(ValueError, match="one data leaf per relation"):
        ds.qr(live[:-1], dtype=jnp.float64)  # missing a relation's leaf
    with pytest.raises(ValueError, match="one data leaf per relation"):
        ds.qr(live + (np.zeros((2, 2)),), dtype=jnp.float64)  # extra leaf


# -- serving -----------------------------------------------------------------


def test_dataset_serve_round_trip():
    sess = figaro.Session()
    ds = _star_ds(sess)
    server = ds.serve(kind="lsq", label_col="price", ridge=0.2,
                      dtype=jnp.float64)
    rng = np.random.default_rng(3)
    batch = tuple(np.stack([rng.normal(size=np.asarray(d).shape)
                            for _ in range(2)]) for d in ds.plan.data)
    betas, resids = server(batch)
    assert np.asarray(betas).shape == (2, ds.plan.num_cols - 1)
    assert np.asarray(resids).shape == (2,)
    # served through the session engine's batched executable
    assert sess.engine.trace_count("least_squares_batched") == 1


def test_serve_kind_validated_eagerly_with_kinds_list():
    from repro.train.serve import make_figaro_server

    ds = _star_ds(figaro.Session())
    with pytest.raises(ValueError, match=r"cholesky.*qr.*svd.*pca.*lsq"):
        make_figaro_server(ds.plan, kind="cholesky")
    with pytest.raises(ValueError, match="supported kinds"):
        ds.serve(kind="nope")
    with pytest.raises(ValueError, match="label_col"):
        make_figaro_server(ds.plan, kind="lsq")
    # one source of truth for the kind list, exported on the façade
    assert figaro.SERVE_KINDS == ("qr", "svd", "pca", "lsq")


def test_serve_submit_future_and_no_plan_fork():
    """ds.serve() is async-first (submit -> FigaroFuture) and shares the
    dataset's plan holder: server.append updates ds.plan/ds.stats() and
    vice versa — regression for the pre-async silent plan-state fork."""
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    fut = server.submit(tuple(np.asarray(d) for d in ds.plan.data))
    assert np.asarray(fut.result(timeout=60)).shape \
        == (ds.plan.num_cols, ds.plan.num_cols)

    live0 = ds.stats()["nodes"]["Orders"]["live_rows"]
    assert server.append("Orders", ({"cust": np.array([0]),
                                     "prod": np.array([0])},
                                    np.ones((1, 2))))
    st = ds.stats()
    assert st["nodes"]["Orders"]["live_rows"] == live0 + 1, \
        "server.append left the dataset's stats stale"
    assert st["appends"] == 1
    assert ds.plan is server.plan
    assert ds.append("Orders", {"cust": np.array([1]),
                                "prod": np.array([1])}, np.ones((1, 2)))
    assert server.plan is ds.plan, "ds.append left the server's plan stale"
    server.close()


# -- column naming -----------------------------------------------------------


def test_lsq_by_column_name_matches_index():
    ds = _star_ds(figaro.Session())
    assert ds.columns == ("Orders.amount", "Orders.qty", "Customers.age",
                          "Customers.income", "Products.price")
    b_name, r_name = ds.lsq("price")
    b_qual, r_qual = ds.lsq("Products.price")
    b_idx, r_idx = ds.lsq(4)
    np.testing.assert_array_equal(np.asarray(b_name), np.asarray(b_idx))
    np.testing.assert_array_equal(np.asarray(b_qual), np.asarray(b_idx))
    np.testing.assert_array_equal(np.asarray(r_name), np.asarray(r_idx))
    del r_qual


def test_column_index_errors():
    ds = _star_ds(figaro.Session())
    with pytest.raises(KeyError, match="unknown column"):
        ds.column_index("nope")
    with pytest.raises(IndexError):
        ds.column_index(99)
    amb = figaro.Session().ingest({
        "A": ({"k": np.arange(3)}, np.ones((3, 1)), ["x"]),
        "B": ({"k": np.arange(3)}, np.ones((3, 1)), ["x"]),
    }).join("A", [("A", "B")])
    with pytest.raises(KeyError, match="ambiguous"):
        amb.column_index("x")
    assert amb.column_index("B.x") == 1


# -- engine LRU bounds ---------------------------------------------------------


def test_engine_lru_eviction_bounds_cache():
    engine = FigaroEngine(donate_data=False, max_cached=1)
    plan_a = build_plan(cartesian(6, 5))
    plan_b = build_plan(cartesian(9, 7))
    engine.qr(plan_a, dtype=jnp.float64)
    engine.qr(plan_b, dtype=jnp.float64)  # evicts A's executable
    assert engine.trace_count("qr") == 2
    assert engine.eviction_count("qr") == 1
    assert engine.cache_size("qr") == 1
    engine.qr(plan_b, dtype=jnp.float64)  # LRU hit, no recompile
    assert engine.trace_count("qr") == 2
    engine.qr(plan_a, dtype=jnp.float64)  # evicted: must recompile
    assert engine.trace_count("qr") == 3
    assert engine.eviction_count("qr") == 2


def test_engine_lru_cap_two_keeps_both_alternating():
    engine = FigaroEngine(donate_data=False, max_cached=2)
    plan_a = build_plan(cartesian(6, 5))
    plan_b = build_plan(cartesian(9, 7))
    for _ in range(3):
        engine.qr(plan_a, dtype=jnp.float64)
        engine.qr(plan_b, dtype=jnp.float64)
    assert engine.trace_count("qr") == 2
    assert engine.eviction_count() == 0


def test_engine_unbounded_by_default_and_validation():
    engine = FigaroEngine(donate_data=False)
    assert engine.max_cached is None
    with pytest.raises(ValueError, match="max_cached"):
        FigaroEngine(max_cached=0)
    with pytest.raises(ValueError, match="max_cached"):
        figaro.Session(engine=engine, max_cached=2)
    with pytest.raises(ValueError, match="donate_data"):
        figaro.Session(engine=engine, donate_data=True)
    assert figaro.Session(max_cached=3).engine.max_cached == 3
    assert figaro.Session(donate_data=True).engine.donate_data is True
    assert figaro.Session().engine.donate_data is False


# -- clear errors for wrong argument types -----------------------------------


def test_plan_for_rejects_database_and_raw_tables():
    db = Database.from_arrays(
        {"S": ({}, np.ones((3, 2)), ["a", "b"])})
    with pytest.raises(TypeError, match="tree_or_plan.*Database"):
        plan_for(db)
    with pytest.raises(TypeError, match="tree_or_plan.*dict"):
        plan_for({"S": np.ones((3, 2))})
    tree = JoinTree.from_edges(db, "S", [])
    assert plan_for(tree).num_cols == 2  # JoinTree still accepted


def test_engine_dispatch_rejects_non_plan():
    engine = FigaroEngine(donate_data=False)
    with pytest.raises(TypeError, match="'plan'.*dict"):
        engine.qr({"S": np.ones((3, 2))})
    db = Database.from_arrays({"S": ({}, np.ones((3, 2)), ["a", "b"])})
    with pytest.raises(TypeError, match="'plan'.*Database"):
        engine.svd(db)
    with pytest.raises(TypeError, match="'plan'"):
        from repro.train.serve import make_figaro_server

        make_figaro_server(db, kind="qr")


def test_ingest_and_from_tree_type_errors():
    sess = figaro.Session()
    with pytest.raises(TypeError, match="ingest"):
        sess.ingest(np.ones((3, 2)))
    with pytest.raises(TypeError, match="from_tree"):
        sess.from_tree({"root": None})


# -- legacy delegation surface -------------------------------------------------


def test_legacy_entry_points_share_default_session_engine():
    from repro.api import default_session
    from repro.core.engine import default_engine

    sess = default_session()
    assert sess.engine is default_engine()
    assert sess.bucket is False  # legacy behavior: no implicit bucketing
    tree = cartesian(5, 4)
    before = sess.engine.trace_count("qr")
    figaro_qr(tree, dtype=jnp.float64)
    figaro_qr(tree, dtype=jnp.float64)
    assert sess.engine.trace_count("qr") == before + 1  # shared cache


def test_figaro_alias_module():
    assert figaro.Session is __import__("repro.api", fromlist=["Session"]).Session
    assert figaro.FigaroEngine is FigaroEngine
