"""Sharding rules: production-mesh PartitionSpecs are consistent & complete.

Uses AbstractMesh — spec construction must not require 256 real devices.

The sharded *serving* tests (FigaroEngine ``shard=`` dispatch, the butterfly
combine, mesh-dispatched partitioned QR) need real multi-device meshes, so
they run ``tests/_sharded_driver.py`` in a fresh subprocess with the XLA host
device count forced to 3 (non-power-of-two) and 4 — the flag must be set
before jax initializes.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import AxisType, make_abstract_mesh
from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf
from repro.sharding.rules import data_axes, param_specs

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("n", [3, 4])
def test_sharded_serving_multi_device(n):
    """Sharded batched dispatch + distributed combines on a forced n-device
    CPU mesh (n=3 exercises the non-power-of-two butterfly schedule)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)  # the driver pins its own device count
    out = subprocess.run(
        [sys.executable, os.path.join("tests", "_sharded_driver.py"), str(n)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert f"SHARDED-OK {n}" in out.stdout


def _abstract_mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_abstract_mesh(shape, axes,
                              axis_types=(AxisType.Auto,) * len(axes))


@pytest.mark.parametrize("name", ARCH_NAMES)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divide_dims(name, multi_pod):
    """Every sharded dim must be divisible-or-larger than its axis product —
    zero-size shards would break compilation at 16x16."""
    cfg = get_config(name)
    mesh = _abstract_mesh(multi_pod)
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(cfg, mesh, shapes)

    def check(path, leaf, spec):
        assert isinstance(spec, P), (path, spec)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim >= size and dim % size == 0, \
                (name, path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("name", ["qwen3-8b", "arctic-480b", "rwkv6-1.6b"])
def test_big_tensors_are_sharded(name):
    """The embedding and FF weights must not be replicated at 16x16."""
    cfg = get_config(name)
    mesh = _abstract_mesh()
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(cfg, mesh, shapes)
    flat = {"/".join(str(getattr(p, "key", p)) for p in path): spec
            for path, spec in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert any(ax is not None for ax in flat["embed"]), flat["embed"]
    big = [k for k in flat if any(t in k for t in
                                  ("w_gate", "w_up", "w_down", "wk", "wv"))]
    assert big
    for k in big:
        assert any(ax is not None for ax in flat[k]), (k, flat[k])


def test_data_axes():
    assert data_axes(_abstract_mesh()) == ("data",)
    assert data_axes(_abstract_mesh(multi_pod=True)) == ("pod", "data")


def test_moe_expert_parallel_vs_tp_fallback():
    """arctic (128e) shards experts over model axis; mixtral (8e < 16)
    falls back to TP on the ff dim."""
    mesh = _abstract_mesh()
    for name, expert_sharded in [("arctic-480b", True),
                                 ("mixtral-8x22b", False)]:
        cfg = get_config(name)
        shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        specs = param_specs(cfg, mesh, shapes)
        flat = {"/".join(str(getattr(p, "key", p)) for p in path): spec
                for path, spec in jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]}
        key = next(k for k in flat if k.endswith("moe/w_up"))
        spec = flat[key]
        # stacked leading axis -> spec[0] is None; expert dim is spec[1]
        if expert_sharded:
            assert spec[1] == "model", (name, spec)
        else:
            assert spec[1] != "model", (name, spec)
