"""figaro-plan: exact statistics, the cost model's ranking properties,
orientation invariance of the factorization, auto root choice at zero extra
retraces, and adaptive re-rooting (hysteresis, live-server swap).

The re-rooting tests use a 3-relation chain F1(x,u) - D(x,y) - F2(y,v) whose
leaf relations carry *local* key attributes (u / v), so a leaf's distinct-key
count K can outgrow the middle relation's — the only way a chain's cheapest
root can move (under full reduction the middle of a pure chain always has the
largest K). F2 is wider than F1 (8 vs 4 data columns), so appending rows to
F2 with fresh ``v`` keys grows the cost of every orientation that has to
project F2's block, and the ranking flips from root=F1 to root=F2.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import figaro
from repro.core.join_tree import JoinTree
from repro.core.relation import Database, full_reduce
from repro.data.relational import cartesian, retailer_like, yelp_like
from repro.planner import (DatabaseStats, Replanner, choose_root,
                           enumerate_roots, orientation_cost, plan_cost,
                           rank_orientations, validate_names)
from repro.planner.cost import ROTATION_PASSES
from repro.planner.orient import orient_edges
from repro.planner.stats import normalize_edges, stats_for


def _star_tables(m_fact: int = 24):
    rng = np.random.default_rng(m_fact)
    return {
        "Orders": ({"cust": np.arange(m_fact) % 8,
                    "prod": np.arange(m_fact) % 4},
                   rng.normal(size=(m_fact, 2)), ["amount", "qty"]),
        "Customers": ({"cust": np.arange(8)},
                      rng.normal(size=(8, 2)), ["age", "income"]),
        "Products": ({"prod": np.arange(4)},
                     rng.normal(size=(4, 1)), ["price"]),
    }


_STAR_EDGES = [("Orders", "Customers"), ("Orders", "Products")]


# -- statistics: exact, cached, incrementally maintained ----------------------


def test_stats_exact_vs_numpy_ground_truth():
    db = Database.from_arrays(_star_tables())
    stats = DatabaseStats.collect(db, _STAR_EDGES)
    for name in db.names:
        rel = db[name]
        st = stats.relations[name]
        assert st.num_rows == rel.num_rows
        assert st.num_data_cols == rel.num_data_cols
        assert st.distinct_keys == np.unique(rel.keys, axis=0).shape[0]
    # per-edge distinct counts / fan-outs against direct np.unique
    orders = db["Orders"]
    cust = np.unique(orders.key_col("cust")).size
    assert stats.relations["Orders"].distinct(("cust",)) == cust
    assert stats.edge_fan_out("Orders", "Customers") \
        == orders.num_rows / cust


def test_incremental_update_equals_recollect():
    tables = _star_tables()
    db = Database.from_arrays(tables)
    stats = DatabaseStats.collect(db, _STAR_EDGES)
    # append 5 Orders rows (2 duplicate keys, 3 fresh) incrementally...
    new_keys = np.array([[0, 0], [7, 3], [9, 0], [9, 1], [11, 2]])
    stats.update("Orders", new_keys)
    # ...and compare to a from-scratch collection over the grown relation
    keys, data, cols = tables["Orders"]
    grown = dict(tables)
    grown["Orders"] = (
        {"cust": np.concatenate([keys["cust"], new_keys[:, 0]]),
         "prod": np.concatenate([keys["prod"], new_keys[:, 1]])},
        np.vstack([data, np.zeros((5, 2))]), cols)
    fresh = DatabaseStats.collect(Database.from_arrays(grown), _STAR_EDGES)
    st, fr = stats.relations["Orders"], fresh.relations["Orders"]
    assert st.num_rows == fr.num_rows
    for attrs in st.uniques:
        np.testing.assert_array_equal(st.uniques[attrs], fr.uniques[attrs])
    with pytest.raises(ValueError, match="columns"):
        stats.update("Orders", np.zeros((1, 3), dtype=np.int64))
    with pytest.raises(ValueError, match="unknown relation"):
        stats.update("Nope", new_keys)


def test_stats_cached_per_db_instance_and_edge_set():
    db = Database.from_arrays(_star_tables())
    s1 = stats_for(db, _STAR_EDGES)
    # same edge set in any order / orientation hits the same cache entry
    s2 = stats_for(db, [("Products", "Orders"), ("Customers", "Orders")])
    assert s1 is s2
    assert normalize_edges([("B", "A"), ("A", "B"), ("A", "C")]) \
        == (("A", "B"), ("A", "C"))


# -- cost model ---------------------------------------------------------------


def _two_relation_db(fan_out: int, n_keys: int = 5):
    rng = np.random.default_rng(fan_out)
    return Database.from_arrays({
        "A": ({"k": np.arange(n_keys)}, rng.normal(size=(n_keys, 2)),
              ["a0", "a1"]),
        "B": ({"k": np.repeat(np.arange(n_keys), fan_out)},
              rng.normal(size=(n_keys * fan_out, 2)), ["b0", "b1"]),
    })


def test_cost_monotone_in_fan_out():
    """Growing a relation's fan-out (rows per shared key, K fixed) strictly
    grows the cost of every orientation."""
    edges = [("A", "B")]
    prev = None
    for f in (1, 2, 4, 8):
        db = _two_relation_db(f)
        stats = stats_for(db, edges)
        assert stats.edge_fan_out("B", "A") == float(f)  # exact
        totals = {oc.root: oc.total for oc in rank_orientations(db, edges)}
        if prev is not None:
            assert totals["A"] > prev["A"] and totals["B"] > prev["B"]
        prev = totals


def test_root_pays_no_projection_pass():
    db = Database.from_arrays(_star_tables())
    stats = stats_for(db, _STAR_EDGES)
    oc = orientation_cost(stats, orient_edges(db.names, _STAR_EDGES,
                                              "Orders"))
    for nc in oc.nodes:
        if nc.is_root:
            assert nc.name == "Orders" and nc.project == 0.0
        else:
            assert nc.project == ROTATION_PASSES * nc.K * nc.width
    assert oc.total == pytest.approx(sum(nc.total for nc in oc.nodes))


def test_plan_cost_matches_orientation_ranking():
    tree = retailer_like(scale=100)
    ranking = rank_orientations(tree.db, tree.edges())
    by_root = {oc.root: oc.total for oc in ranking}
    assert plan_cost(tree) == pytest.approx(by_root[tree.root])


def test_auto_root_recovers_paper_good_orientation():
    tree = retailer_like(scale=200, root="good")
    assert choose_root(tree.db, tree.edges()) == "Inventory"
    assert retailer_like(scale=200, root="auto").root == "Inventory"


# -- orientation invariance: any root, same factorization --------------------


@pytest.mark.parametrize("fixture", ["retailer", "yelp", "cartesian"])
def test_singular_values_invariant_across_all_orientations(fixture):
    """R differs between orientations only by a column permutation (and
    signs), so its singular values must agree across every enumerated root."""
    tree = {"retailer": lambda: retailer_like(scale=60),
            "yelp": lambda: yelp_like(scale=40),
            "cartesian": lambda: cartesian(6, 5)}[fixture]()
    db, edges = tree.db, tree.edges()
    reference = None
    for root, _ in enumerate_roots(db.names, edges):
        sess = figaro.Session()
        ds = sess.ingest(db).join(edges, root=root, reduce=False)
        r = np.asarray(ds.qr(dtype=jnp.float64), dtype=np.float64)
        s = np.linalg.svd(r, compute_uv=False)
        if reference is None:
            reference = s
        else:
            np.testing.assert_allclose(
                s, reference, rtol=1e-8,
                atol=1e-10 * reference.max(),
                err_msg=f"{fixture}: spectrum moved when rooted at {root}")


# -- facade: eager validation, join() signature, explain ---------------------


def test_unknown_names_raise_eager_value_error():
    sess = figaro.Session()
    ts = sess.ingest(_star_tables())
    with pytest.raises(ValueError, match=r"unknown relation 'Orderz'.*"
                                         r"ingested relations are"):
        ts.join("Orderz", _STAR_EDGES)
    with pytest.raises(ValueError, match="unknown relation 'Custmers'"):
        ts.join([("Orders", "Custmers"), ("Orders", "Products")])
    # the same message comes out of direct tree construction
    db = full_reduce(Database.from_arrays(_star_tables()), _STAR_EDGES)
    with pytest.raises(ValueError, match="ingested relations are"):
        JoinTree.from_edges(db, "Orderz", _STAR_EDGES)
    with pytest.raises(ValueError, match="unknown relations 'X', 'Y'"):
        validate_names(db.names, [("X", "Y")])
    # disconnected relation: named, not silently dropped
    with pytest.raises(ValueError, match="do not connect.*Products"):
        ts.join([("Orders", "Customers")])


def test_join_signature_shapes_agree():
    """join(edges) / join(edges, root="auto") / join(edges, root=r) /
    legacy join(r, edges) all build the same tree for the same root."""
    tables = _star_tables()
    trees = [figaro.Session().ingest(tables).join(*a, **kw).tree
             for a, kw in [((_STAR_EDGES,), {}),
                           ((_STAR_EDGES,), dict(root="auto")),
                           ((_STAR_EDGES,), dict(root="Orders")),
                           (("Orders", _STAR_EDGES), {}),
                           ((), dict(root="Orders", edges=_STAR_EDGES))]]
    assert {t.root for t in trees} == {"Orders"}
    assert {tuple(t.preorder()) for t in trees} == {tuple(trees[0].preorder())}
    ts = figaro.Session().ingest(tables)
    with pytest.raises(TypeError, match="missing 'edges'"):
        ts.join("Orders")
    with pytest.raises(TypeError, match="multiple values for 'root'"):
        ts.join("Orders", _STAR_EDGES, root="Orders")
    with pytest.raises(TypeError, match="multiple values for 'edges'"):
        ts.join(_STAR_EDGES, edges=_STAR_EDGES)


def test_explain_ranks_every_orientation():
    tree = retailer_like(scale=100)
    ds = figaro.Session().ingest(tree.db).join(tree.edges(), reduce=False)
    text = ds.explain()
    for name in tree.db.names:
        assert f"root={name}" in text
    assert "*" in text and "1. root=Inventory" in text
    assert "per-node breakdown" in text
    assert "currently running (Inventory)" in text


# -- auto root: zero extra retraces vs the hand-rooted join ------------------


def test_auto_join_costs_zero_extra_retraces():
    """Hand-rooted and auto joins over the same edges build the same plan
    signature, so on a shared engine the second compiles nothing."""
    tree = retailer_like(scale=100, root="good")
    sess = figaro.Session()
    ds_hand = sess.ingest(tree.db).join(tree.edges(), root="Inventory",
                                        reduce=False)
    r_hand = np.asarray(ds_hand.qr(dtype=jnp.float64))
    traces_after_hand = sess.engine.trace_count()
    ds_auto = sess.ingest(tree.db).join(tree.edges(), reduce=False)
    assert ds_auto.tree.root == "Inventory"
    r_auto = np.asarray(ds_auto.qr(dtype=jnp.float64))
    assert sess.engine.trace_count() == traces_after_hand, \
        "root='auto' must not retrace when it picks the hand-chosen root"
    np.testing.assert_array_equal(r_auto, r_hand)


# -- adaptive re-rooting ------------------------------------------------------


def _flip_tables(rng, *, f2_cols: int = 8):
    """F1(x,u; 4 cols) - D(x,y; 1 col) - F2(y,v; f2_cols): root starts at F1
    (largest K*width mass); F2 appends with fresh ``v`` keys move it."""
    nx, ny, m_d, m_f1, m_f2 = 20, 15, 40, 200, 10
    dx = rng.integers(0, nx, m_d)
    dy = rng.integers(0, ny, m_d)
    return {
        "F1": ({"x": rng.choice(np.unique(dx), m_f1), "u": np.arange(m_f1)},
               rng.normal(size=(m_f1, 4)), [f"f{i}" for i in range(4)]),
        "D": ({"x": dx, "y": dy}, rng.normal(size=(m_d, 1)), ["d0"]),
        "F2": ({"y": rng.choice(np.unique(dy), m_f2), "v": np.arange(m_f2)},
               rng.normal(size=(m_f2, f2_cols)),
               [f"g{i}" for i in range(f2_cols)]),
    }


_FLIP_EDGES = [("F1", "D"), ("D", "F2")]


def _grow_f2(ds, rng, rows: int, next_v: int) -> tuple[bool, int]:
    """Append ``rows`` F2 rows with existing y keys and fresh v keys (keeps
    the database fully reduced: no cross-relation coordination needed)."""
    ys = np.unique(ds.tree.db["F2"].key_col("y"))
    in_cap = ds.append("F2", {"y": rng.choice(ys, rows),
                              "v": np.arange(next_v, next_v + rows)},
                       rng.normal(size=(rows, ds.tree.db["F2"].num_data_cols)))
    return in_cap, next_v + rows


def test_append_triggers_hysteresis_gated_reroot():
    rng = np.random.default_rng(0)
    sess = figaro.Session(headroom=4)
    ds = sess.ingest(_flip_tables(rng)).join(_FLIP_EDGES, hysteresis=0.4)
    assert ds.tree.root == "F1"
    _ = ds.qr(dtype=jnp.float64)  # build + compile on the initial root
    grow = np.random.default_rng(7)
    in_cap, _ = _grow_f2(ds, grow, 400, next_v=10)
    assert not in_cap, "a re-root must report an invalidated signature"
    st = ds.stats()
    assert st["root"] == "F2" and st["reroots"] == 1
    assert st["append_volume"] == {"F2": 400}
    assert ds.columns[0].startswith("F2."), \
        "column order must follow the re-rooted tree's preorder"
    # the re-rooted dataset computes the same join factorization as a fresh
    # hand-rooted session over the same (grown) database
    s_new = np.linalg.svd(np.asarray(ds.qr(dtype=jnp.float64)),
                          compute_uv=False)
    ref = figaro.Session().ingest(ds.tree.db).join(
        _FLIP_EDGES, root="F1", reduce=False)
    s_ref = np.linalg.svd(np.asarray(ref.qr(dtype=jnp.float64)),
                          compute_uv=False)
    np.testing.assert_allclose(s_new, s_ref, rtol=1e-8)


def test_pre_plan_appends_re_choose_root_for_free():
    """Appends before the first compute shift the planner's choice without
    any re-root machinery — nothing is built yet."""
    rng = np.random.default_rng(0)
    sess = figaro.Session(headroom=4)
    ds = sess.ingest(_flip_tables(rng)).join(_FLIP_EDGES)
    grow = np.random.default_rng(7)
    assert _grow_f2(ds, grow, 400, next_v=10)[0]  # table grow, no plan yet
    _ = ds.plan
    st = ds.stats()
    assert st["root"] == "F2" and st["reroots"] == 0


def test_hysteresis_blocks_marginal_flips_and_flapping():
    # Direct policy check: a challenger inside the margin never wins.
    rng = np.random.default_rng(0)
    db = full_reduce(Database.from_arrays(_flip_tables(rng)), _FLIP_EDGES)
    ranking = rank_orientations(db, _FLIP_EDGES)
    best, second = ranking[0], ranking[1]
    margin = second.total / best.total - 1.0
    blocked = Replanner(stats=stats_for(db, _FLIP_EDGES),
                        names=tuple(db.names),
                        edges=normalize_edges(_FLIP_EDGES),
                        current_root=second.root,
                        hysteresis=margin + 0.05)
    assert blocked.proposal() is None
    eager = Replanner(stats=blocked.stats, names=blocked.names,
                      edges=blocked.edges, current_root=second.root,
                      hysteresis=max(margin - 0.05, 0.0))
    assert eager.proposal() == best.root

    # End to end: alternating symmetric appends must never flap the root.
    rng = np.random.default_rng(1)
    tables = _flip_tables(rng, f2_cols=4)  # F1 and F2 now equally wide
    sess = figaro.Session(headroom=4)
    ds = sess.ingest(tables).join(_FLIP_EDGES)
    _ = ds.qr(dtype=jnp.float64)
    root0 = ds.tree.root
    grow = np.random.default_rng(2)
    next_v, next_u = 10, 200
    for _step in range(3):
        _, next_v = _grow_f2(ds, grow, 40, next_v)
        xs = np.unique(ds.tree.db["F1"].key_col("x"))
        ds.append("F1", {"x": grow.choice(xs, 40),
                         "u": np.arange(next_u, next_u + 40)},
                  grow.normal(size=(40, 4)))
        next_u += 40
    st = ds.stats()
    assert st["reroots"] == 0 and st["root"] == root0, \
        f"alternating appends flapped the root: {st['root']}"


def test_reroot_swap_is_invisible_to_in_flight_futures(rng):
    """Requests submitted before an append that triggers a re-root are
    answered on the plan they were submitted against, bit-identically;
    requests after the swap run on the new orientation."""
    build = np.random.default_rng(0)
    sess = figaro.Session(headroom=4)
    ds = sess.ingest(_flip_tables(build)).join(_FLIP_EDGES, hysteresis=0.4)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    req = tuple(rng.normal(size=np.asarray(d).shape) for d in ds.plan.data)
    baseline = np.asarray(server.submit(req).result(timeout=60))

    server.pause()
    in_flight = server.submit(req)  # queued against the pre-swap plan
    grow = np.random.default_rng(7)
    in_cap, _ = _grow_f2(ds, grow, 400, next_v=10)  # drains, then re-roots
    assert not in_cap and ds.stats()["reroots"] == 1
    assert in_flight.done(), "append must drain in-flight work before a swap"
    np.testing.assert_array_equal(
        np.asarray(in_flight.result()), baseline,
        err_msg="in-flight future answered on the post-swap plan")

    # post-swap: new capacity shapes, same served surface
    assert server.plan is ds.plan and ds.plan.source_tree.root == "F2"
    req_new = tuple(rng.normal(size=np.asarray(d).shape)
                    for d in ds.plan.data)
    r_new = np.asarray(server.submit(req_new).result(timeout=60))
    assert r_new.shape == (ds.plan.num_cols, ds.plan.num_cols)
    server.close()
