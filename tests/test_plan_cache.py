"""Plan lifecycle: bucketed signatures, capacity padding, append refreshes.

Acceptance criteria (ISSUE 3): an append that keeps the bucketed signature
triggers ZERO new traces; two plans differing only within one bucket share a
cached executable; masked QR/SVD/PCA off a capacity plan match a fresh
`build_plan` over the appended data to 1e-10 in float64.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.counts import compute_counts, compute_counts_reference
from repro.core.engine import FigaroEngine
from repro.core.figaro import figaro_r0
from repro.core.join_tree import JoinTree, build_plan
from repro.core.materialize import materialize_join
from repro.core.plan_cache import (bucket_spec, build_capacity_plan,
                                   next_pow2, pad_data, pad_plan,
                                   refresh_plan, spec_fits)
from repro.core.relation import Database, full_reduce

from helpers import TOPOLOGIES, random_acyclic_db


def _chain2_db(s1_keys, s2_keys, *, seed=0):
    """A controlled chain2 database (S1 root — S2) with fixed column widths,
    so two instances differ only in row/key counts (near-miss shapes)."""
    rng = np.random.default_rng(seed)
    tables = {
        "S1": ({"e0": np.asarray(s1_keys)},
               rng.normal(size=(len(s1_keys), 2)), ["a", "b"]),
        "S2": ({"e0": np.asarray(s2_keys)},
               rng.normal(size=(len(s2_keys), 1)), ["c"]),
    }
    edges = [("S1", "S2")]
    db = full_reduce(Database.from_arrays(tables), edges)
    return JoinTree.from_edges(db, "S1", edges)


def _append_one_row(tree, name):
    """(keys, data) for one appended row re-using an existing key of `name`
    (keeps the database fully reduced)."""
    rel = tree.db[name]
    keys = {a: rel.key_col(a)[:1].copy() for a in rel.key_attrs}
    return keys, np.full((1, rel.num_data_cols), 0.5)


# -- bucketing ----------------------------------------------------------------


def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 4, 5, 8, 9, 1023)] == \
        [1, 1, 2, 4, 4, 8, 8, 16, 1024]


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
def test_bucket_spec_layout(rng, topology):
    _, _, plan = random_acyclic_db(topology, rng)
    cap = bucket_spec(plan.spec)
    assert spec_fits(plan.spec, cap)
    row_acc = 0
    for i in reversed(cap.preorder):
        sp = cap.nodes[i]
        assert sp.m == next_pow2(plan.spec.nodes[i].m)
        assert sp.K == next_pow2(plan.spec.nodes[i].K)
        assert sp.P == next_pow2(plan.spec.nodes[i].P)
        assert (sp.tail_row0, sp.out_row0) == (row_acc, row_acc + sp.m)
        row_acc += sp.m + sp.K
    assert cap.r0_rows == row_acc
    # column layout is part of the signature, not bucketed
    assert cap.num_cols == plan.spec.num_cols
    # idempotent: a bucketed spec is its own bucket
    assert bucket_spec(cap) == cap


# -- masked pipeline == exact pipeline ---------------------------------------


@pytest.mark.parametrize("topology", list(TOPOLOGIES))
@pytest.mark.parametrize("cartesian", [False, True])
def test_padded_plan_matches_exact(rng, topology, cartesian):
    """R₀ off the capacity plan has the Gram of the exact join and only zero
    rows beyond the live layout; counts agree with the exact reference on
    live slots and vanish on dead slots."""
    _, tree, plan = random_acyclic_db(topology, rng, cartesian=cartesian)
    cap = pad_plan(plan)
    a = np.asarray(materialize_join(tree))
    r0 = np.asarray(figaro_r0(cap, dtype=jnp.float64))
    assert r0.shape == (cap.spec.r0_rows, cap.spec.num_cols)
    g = a.T @ a
    err = np.abs(g - r0.T @ r0).max() / max(np.abs(g).max(), 1e-30)
    assert err < 1e-11, err

    ref = compute_counts_reference(plan)
    cnt = compute_counts(cap, dtype=jnp.float64)
    for i, sp in enumerate(plan.spec.nodes):
        for key, width in (("rpk", sp.K), ("full", sp.K), ("phi_circ", sp.K)):
            got = np.asarray(cnt[i][key])
            np.testing.assert_allclose(got[:width], ref[i][key], rtol=1e-12,
                                       err_msg=f"{sp.name}:{key}")
            assert (got[width:] == 0).all(), f"{sp.name}:{key} dead slots"


def test_pad_plan_rejects_masked_input(rng):
    _, _, plan = random_acyclic_db("chain2", rng)
    cap = pad_plan(plan)
    with pytest.raises(ValueError, match="exact plan"):
        pad_plan(cap)


def test_pad_data_batched(rng):
    _, _, plan = random_acyclic_db("chain3", rng)
    cap = bucket_spec(plan.spec)
    batch = tuple(np.stack([np.asarray(d)] * 3) for d in plan.data)
    padded = pad_data(batch, cap)
    for d, p, sp in zip(batch, padded, cap.nodes):
        assert p.shape == (3, sp.m, sp.n)
        np.testing.assert_array_equal(p[:, : d.shape[1]], d)
        assert (p[:, d.shape[1]:] == 0).all()


# -- acceptance: zero retraces on signature-preserving appends ----------------


def test_refresh_zero_retrace_and_matches_fresh_plan(rng):
    _, tree, _ = random_acyclic_db("star3", rng)
    # headroom=1: the append below must fit even if a node's live row count
    # already sits exactly on a power of two
    cap = build_capacity_plan(tree, headroom=1)
    engine = FigaroEngine(donate_data=False)

    engine.qr(cap, dtype=jnp.float64)
    assert engine.trace_count("qr") == 1

    # Append a row to a non-root relation, staying inside the buckets.
    name = tree.preorder()[1]
    refreshed = refresh_plan(cap, {name: _append_one_row(tree, name)})
    assert refreshed.spec == cap.spec, "append within capacity changed spec"

    r_cap = np.asarray(engine.qr(refreshed, dtype=jnp.float64))
    assert engine.trace_count("qr") == 1, "signature-preserving append retraced"

    # ... and the masked result equals a fresh exact plan over the new data.
    fresh = build_plan(refreshed.source_tree)
    r_ref = np.asarray(engine.qr(fresh, dtype=jnp.float64))
    np.testing.assert_allclose(r_cap, r_ref,
                               atol=1e-10 * max(np.abs(r_ref).max(), 1.0))

    s_cap, vt_cap = engine.svd(refreshed, dtype=jnp.float64)
    s_ref, _ = engine.svd(fresh, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(s_cap), np.asarray(s_ref),
                               atol=1e-10 * max(np.asarray(s_ref).max(), 1.0))
    assert np.asarray(vt_cap).shape == (cap.spec.num_cols, cap.spec.num_cols)

    pca_cap = engine.pca(refreshed, k=2, dtype=jnp.float64)
    pca_ref = engine.pca(fresh, k=2, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(pca_cap.explained_variance),
                               np.asarray(pca_ref.explained_variance),
                               atol=1e-10)
    np.testing.assert_allclose(np.asarray(pca_cap.mean),
                               np.asarray(pca_ref.mean), atol=1e-10)
    np.testing.assert_allclose(float(pca_cap.num_rows),
                               float(pca_ref.num_rows), rtol=0)


def test_refresh_repeated_appends_stay_cached(rng):
    """A stream of appends re-dispatches one executable until a bucket
    overflows — then exactly one retrace at the grown signature."""
    tree = _chain2_db([0, 0, 1], [0, 1])
    cap = build_capacity_plan(tree)  # S1: m_cap 4
    engine = FigaroEngine(donate_data=False)
    engine.r0(cap, dtype=jnp.float64)
    plan = cap
    while plan.spec.nodes[0].m > plan.source_tree.db["S1"].num_rows:
        plan = refresh_plan(plan, {"S1": _append_one_row(plan.source_tree,
                                                         "S1")})
        engine.r0(plan, dtype=jnp.float64)
        assert engine.trace_count("r0") == 1
    # capacity exhausted: next append grows m_cap 4 -> 8, one retrace
    plan = refresh_plan(plan, {"S1": _append_one_row(plan.source_tree, "S1")})
    assert plan.spec != cap.spec
    assert plan.spec.nodes[0].m == 8
    engine.r0(plan, dtype=jnp.float64)
    assert engine.trace_count("r0") == 2
    # correctness after the growth
    a = np.asarray(materialize_join(plan.source_tree))
    r0 = np.asarray(figaro_r0(plan, dtype=jnp.float64))
    g = a.T @ a
    assert np.abs(g - r0.T @ r0).max() / np.abs(g).max() < 1e-11


# -- acceptance: near-miss shapes share one executable ------------------------


def test_bucket_sharing_across_near_miss_plans():
    """Two plans differing only within one bucket (3 vs 4 fact rows) land on
    one cached executable, via engine bucket=True and via capacity plans."""
    tree_a = _chain2_db([0, 0, 1], [0, 1, 1], seed=1)
    tree_b = _chain2_db([0, 1, 1, 1], [0, 0, 1], seed=2)
    plan_a, plan_b = build_plan(tree_a), build_plan(tree_b)
    assert plan_a.spec != plan_b.spec  # genuinely different exact signatures
    assert bucket_spec(plan_a.spec) == bucket_spec(plan_b.spec)

    engine = FigaroEngine(donate_data=False)
    r_a = engine.qr(plan_a, bucket=True, dtype=jnp.float64)
    r_b = engine.qr(plan_b, bucket=True, dtype=jnp.float64)
    assert engine.trace_count("qr") == 1, "bucketed near-miss plans retraced"

    for tree, r in ((tree_a, r_a), (tree_b, r_b)):
        a = np.asarray(materialize_join(tree))
        g = a.T @ a
        r = np.asarray(r)
        assert np.abs(g - r.T @ r).max() / np.abs(g).max() < 1e-11

    # capacity plans built into the same buckets share the executable too
    cap_a = build_capacity_plan(tree_a)
    cap_b = build_capacity_plan(tree_b)
    assert cap_a.spec == cap_b.spec
    engine.qr(cap_a, dtype=jnp.float64)
    engine.qr(cap_b, dtype=jnp.float64)
    assert engine.trace_count("qr") == 1


def test_bucketed_batched_dispatch_matches_exact(rng):
    """bucket=True on a batched dispatch pads the request rows too."""
    _, _, plan = random_acyclic_db("chain3", rng)
    engine = FigaroEngine(donate_data=False)
    batch = tuple(
        np.stack([np.asarray(d) * (1.0 + 0.1 * i) for i in range(3)])
        for d in plan.data)
    rb = np.asarray(engine.qr(plan, batch, batched=True, bucket=True,
                              dtype=jnp.float64))
    for i in range(3):
        ri = np.asarray(engine.qr(plan, [d[i] for d in batch],
                                  dtype=jnp.float64))
        np.testing.assert_allclose(rb[i], ri,
                                   atol=1e-10 * max(np.abs(ri).max(), 1.0))


# -- refresh plumbing ---------------------------------------------------------


def test_refresh_requires_capacity_plan(rng):
    _, _, plan = random_acyclic_db("chain2", rng)
    with pytest.raises(ValueError, match="build_capacity_plan"):
        refresh_plan(plan, {})


def test_refresh_rejects_dangling_append():
    tree = _chain2_db([0, 0, 1], [0, 1])
    cap = build_capacity_plan(tree)
    # key 7 exists in no S1 row -> database no longer fully reduced
    with pytest.raises(ValueError, match="reduce"):
        refresh_plan(cap, {"S2": ({"e0": np.array([7])},
                                  np.zeros((1, 1)))})


def test_server_append_online(rng):
    from repro.train.serve import make_figaro_server

    _, tree, _ = random_acyclic_db("star3", rng)
    cap = build_capacity_plan(tree, headroom=1)
    engine = FigaroEngine(donate_data=False)
    server = make_figaro_server(cap, kind="qr", dtype=jnp.float64,
                                engine=engine)

    def live_batch(plan_tree, b=2):
        exact = build_plan(plan_tree)
        return tuple(np.stack([np.asarray(d) * (1.0 + 0.1 * i)
                               for i in range(b)]) for d in exact.data)

    rb = np.asarray(server(live_batch(tree)))
    assert rb.shape == (2, cap.spec.num_cols, cap.spec.num_cols)
    assert engine.trace_count("qr_batched") == 1

    name = tree.preorder()[1]
    assert server.append(name, _append_one_row(tree, name))  # same signature
    new_tree = server.plan.source_tree
    rb2 = np.asarray(server(live_batch(new_tree)))
    assert engine.trace_count("qr_batched") == 1, "append retraced the server"

    # the served result reflects the appended data: compare sample 0 against
    # a fresh exact plan over the grown database
    fresh = build_plan(new_tree)
    r_ref = np.asarray(engine.qr(fresh, dtype=jnp.float64))
    np.testing.assert_allclose(rb2[0], r_ref,
                               atol=1e-10 * max(np.abs(r_ref).max(), 1.0))

    # stale-sized request buffers (pre-append live sizes) must raise, not be
    # silently zero-filled into a wrong answer
    stale = live_batch(tree)
    if any(a.shape != b.shape for a, b in zip(stale, live_batch(new_tree))):
        with pytest.raises(ValueError, match="live size"):
            server(stale)

    # capacity plans that grew past their buckets keep the caller's headroom
    cap2 = build_capacity_plan(tree, headroom=3)
    assert cap2.capacity_headroom == 3
    refreshed = refresh_plan(cap2, {name: _append_one_row(tree, name)})
    assert getattr(refreshed, "capacity_headroom", None) == 3
