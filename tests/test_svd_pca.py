"""Downstream LA over joins: SVD, PCA, least squares (paper §1/§10) +
the Exp-4 reverse-engineered accuracy construction."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.figaro import figaro_r0
from repro.core.join_tree import build_plan
from repro.core.materialize import materialize_join
from repro.core.qr import figaro_qr, implicit_q_gram_check
from repro.core.svd import (join_column_moments, least_squares_over_join,
                            pca_over_join, svd_over_join)
from repro.data.relational import accuracy_db

from helpers import random_acyclic_db


def test_svd_over_join_matches_numpy(rng):
    _, tree, plan = random_acyclic_db("snowflake4", rng)
    a = np.asarray(materialize_join(tree))
    s, vt = svd_over_join(plan)
    s_ref = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_ref[: len(s)], rtol=1e-8)
    # right singular vectors agree up to sign
    _, _, vt_ref = np.linalg.svd(a, full_matrices=False)
    dots = np.abs(np.sum(np.asarray(vt) * vt_ref, axis=1))
    np.testing.assert_allclose(dots, 1.0, atol=1e-6)


def test_pca_over_join_matches_numpy(rng):
    _, tree, plan = random_acyclic_db("star3", rng)
    a = np.asarray(materialize_join(tree))
    k = min(3, a.shape[1])
    pca = pca_over_join(plan, k=k)
    ac = a - a.mean(axis=0)
    cov = ac.T @ ac / (a.shape[0] - 1)
    ev_ref = np.sort(np.linalg.eigvalsh(cov))[::-1][:k]
    np.testing.assert_allclose(np.asarray(pca.explained_variance), ev_ref,
                               rtol=1e-7, atol=1e-10)


def test_column_moments_match_join(rng):
    _, tree, plan = random_acyclic_db("chain3", rng)
    a = np.asarray(materialize_join(tree))
    sums, total = join_column_moments(plan)
    assert int(total) == a.shape[0]
    np.testing.assert_allclose(np.asarray(sums) / float(total),
                               a.mean(axis=0), rtol=1e-10)


def test_least_squares_over_join(rng):
    _, tree, plan = random_acyclic_db("snowflake4", rng)
    a = np.asarray(materialize_join(tree))
    if a.shape[1] < 2 or a.shape[0] <= a.shape[1]:
        pytest.skip("needs at least 2 cols and tall A")
    beta, resid = least_squares_over_join(plan, label_col=plan.num_cols - 1)
    beta_ref, *_ = np.linalg.lstsq(a[:, :-1], a[:, -1], rcond=None)
    np.testing.assert_allclose(np.asarray(beta), beta_ref, rtol=1e-6,
                               atol=1e-8)
    res_ref = np.linalg.norm(a[:, :-1] @ beta_ref - a[:, -1])
    np.testing.assert_allclose(np.asarray(resid), res_ref, rtol=1e-6,
                               atol=1e-8)


def test_implicit_q_gram_check(rng):
    """Q = A R⁻¹ is orthogonal ⟺ R⁻ᵀ(AᵀA)R⁻¹ == I — checked without
    materializing A (the paper computes Q this way, §8)."""
    _, tree, plan = random_acyclic_db("star3", rng)
    a = np.asarray(materialize_join(tree))
    r = figaro_qr(plan, dtype=jnp.float64)
    dev = implicit_q_gram_check(r, jnp.array(a.T @ a))
    assert float(dev) < 1e-10


# -- Exp 4: ground-truth accuracy construction --------------------------------


@pytest.mark.parametrize("p,q,n", [(16, 12, 4), (64, 32, 8)])
def test_accuracy_db_ground_truth(p, q, n):
    tree, r_fixed = accuracy_db(p, q, n, seed=9)
    plan = build_plan(tree)
    r = np.asarray(figaro_qr(plan, dtype=jnp.float64))
    # The T-block of R (last n columns, rows n..2n) equals R_fixed up to sign.
    blk = r[n:, n:]
    sign = np.sign(np.diag(blk)) * np.sign(np.diag(r_fixed))
    np.testing.assert_allclose(blk * sign[:, None], r_fixed, rtol=1e-9,
                               atol=1e-9)


def test_accuracy_db_is_consistent_with_materialized():
    tree, r_fixed = accuracy_db(10, 8, 3, seed=2)
    a = np.asarray(materialize_join(tree))
    r_ref = np.linalg.qr(a)[1]
    r_ref *= np.sign(np.diag(r_ref))[:, None]
    blk = r_ref[3:, 3:]
    np.testing.assert_allclose(np.abs(blk), np.abs(r_fixed), rtol=1e-8,
                               atol=1e-8)
