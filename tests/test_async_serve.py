"""Async serving: futures in submission order, micro-batch coalescing
(bit-identical to the one-shot batched dispatch), per-request exception
isolation, interleaved submit/append streams with zero retraces, B=0/B=1
edges, and the shared plan holder between a JoinDataset and its servers."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import figaro
from repro.core.engine import FigaroEngine
from repro.core.join_tree import build_plan
from repro.core.plan_cache import PlanHolder, build_capacity_plan
from repro.launch.mesh import make_data_mesh, serving_batch_capacity
from repro.train.async_serve import FigaroFuture
from repro.train.serve import (AsyncFigaroServer, FigaroServer,
                               SERVE_KINDS, make_figaro_server)


def _star_tables(m_fact: int = 20):
    rng = np.random.default_rng(m_fact)
    return {
        "Orders": ({"cust": np.arange(m_fact) % 8,
                    "prod": np.arange(m_fact) % 4},
                   rng.normal(size=(m_fact, 2)), ["amount", "qty"]),
        "Customers": ({"cust": np.arange(8)},
                      rng.normal(size=(8, 2)), ["age", "income"]),
        "Products": ({"prod": np.arange(4)},
                     rng.normal(size=(4, 1)), ["price"]),
    }


_STAR_EDGES = [("Orders", "Customers"), ("Orders", "Products")]


def _star_ds(session, m_fact=20):
    return session.ingest(_star_tables(m_fact)).join("Orders", _STAR_EDGES)


def _requests(plan, rng, n):
    """n single requests (per-node [m_i, n_i] leaves) at capacity shapes."""
    return [tuple(rng.normal(size=np.asarray(d).shape) for d in plan.data)
            for _ in range(n)]


# -- capacity bucketing -------------------------------------------------------


def test_serving_batch_capacity_buckets():
    assert serving_batch_capacity(0) == 0
    assert serving_batch_capacity(1) == 1
    assert serving_batch_capacity(3) == 4
    assert serving_batch_capacity(8) == 8
    # aligned to a non-power-of-two mesh axis
    assert serving_batch_capacity(1, axis_size=3) == 3
    assert serving_batch_capacity(5, axis_size=3) == 9
    assert serving_batch_capacity(4, axis_size=2) == 4


def test_engine_batch_capacity_shares_executable_across_live_sizes(rng):
    """Partial batches padded to one bucket share one executable; the pad is
    sliced off the result."""
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    b3 = _stack(_requests(plan, rng, 3))
    b5 = _stack(_requests(plan, rng, 5))
    r3 = np.asarray(engine.qr(plan, b3, batched=True, batch_capacity=8,
                              dtype=jnp.float64))
    assert r3.shape == (3, plan.num_cols, plan.num_cols)
    assert engine.trace_count("qr_batched") == 1
    r5 = np.asarray(engine.qr(plan, b5, batched=True, batch_capacity=8,
                              dtype=jnp.float64))
    assert r5.shape[0] == 5
    assert engine.trace_count("qr_batched") == 1, \
        "live sizes in one batch bucket must share the executable"
    with pytest.raises(ValueError, match="batch_capacity"):
        engine.qr(plan, b5, batched=True, batch_capacity=2,
                  dtype=jnp.float64)
    with pytest.raises(ValueError, match="batched"):
        engine.qr(plan, [d[0] for d in b3], batch_capacity=4,
                  dtype=jnp.float64)


def _star_tree():
    from repro.core.join_tree import JoinTree
    from repro.core.relation import Database, full_reduce

    db = full_reduce(Database.from_arrays(_star_tables()), _STAR_EDGES)
    return JoinTree.from_edges(db, "Orders", _STAR_EDGES)


def _stack(reqs):
    return tuple(np.stack([r[j] for r in reqs])
                 for j in range(len(reqs[0])))


# -- futures + coalescing -----------------------------------------------------


def test_coalesced_submit_bit_identical_to_sync_batched_dispatch(rng):
    """pause + submit×4 + resume dispatches ONE coalesced B=4 batch whose
    per-request results are bit-identical to the one-shot batched dispatch of
    the same batch (same executable: same engine, same signature)."""
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=engine)
    reqs = _requests(plan, rng, 4)
    server.pause()
    futures = [server.submit(r) for r in reqs]
    server.resume()
    results = [np.asarray(f.result(timeout=60)) for f in futures]
    assert engine.trace_count("qr_batched") == 1, \
        "4 submits must coalesce into one dispatch"
    r_sync = np.asarray(engine.qr(plan, _stack(reqs), batched=True,
                                  dtype=jnp.float64))
    assert engine.trace_count("qr_batched") == 1  # same executable
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r, r_sync[i], err_msg=f"request {i}")
    server.close()


def test_futures_resolve_in_submission_order(rng, monkeypatch):
    plan = build_plan(_star_tree())
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=FigaroEngine(donate_data=False))
    order = []
    orig = FigaroFuture._resolve

    def spy(self, *a, **k):
        order.append(self)
        return orig(self, *a, **k)

    monkeypatch.setattr(FigaroFuture, "_resolve", spy)
    futures = [server.submit(r) for r in _requests(plan, np.random.
                                                   default_rng(0), 6)]
    server.flush()
    assert all(f.done() for f in futures)
    assert order == futures, "futures must resolve in submission order"
    server.close()


def test_submit_sub_batch_and_call_are_equivalent(rng):
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=engine)
    batch = _stack(_requests(plan, rng, 3))
    via_future = np.asarray(server.submit(batch).result(timeout=60))
    via_call = np.asarray(server(batch))
    assert via_future.shape == (3, plan.num_cols, plan.num_cols)
    np.testing.assert_array_equal(via_future, via_call)
    server.close()


def test_edge_batches_b0_and_b1(rng):
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=engine)
    n = plan.num_cols
    empty = tuple(np.zeros((0,) + np.asarray(d).shape) for d in plan.data)
    assert np.asarray(server.submit(empty).result(timeout=60)).shape \
        == (0, n, n)
    one = _stack(_requests(plan, rng, 1))
    r1 = np.asarray(server.submit(one).result(timeout=60))
    assert r1.shape == (1, n, n)
    # single-request submit: unbatched leaves in, unbatched result out
    single = server.submit(tuple(d[0] for d in one)).result(timeout=60)
    np.testing.assert_array_equal(np.asarray(single), r1[0])
    server.close()


# -- per-request exception isolation ------------------------------------------


def test_validation_error_fails_only_its_own_future(rng):
    plan = build_plan(_star_tree())
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=FigaroEngine(donate_data=False))
    good = _requests(plan, rng, 2)
    bad = tuple(d[:-1] for d in good[0])  # wrong row counts everywhere
    server.pause()
    f_ok1 = server.submit(good[0])
    f_bad = server.submit(bad)
    f_ok2 = server.submit(good[1])
    server.resume()
    r1 = np.asarray(f_ok1.result(timeout=60))
    r2 = np.asarray(f_ok2.result(timeout=60))
    assert r1.shape == r2.shape == (plan.num_cols, plan.num_cols)
    with pytest.raises(ValueError, match="live size|rebuild request"):
        f_bad.result(timeout=60)
    assert isinstance(f_bad.exception(), ValueError)
    server.close()


def test_poisoned_dispatch_does_not_fail_coalesced_batchmates(rng):
    """If the coalesced dispatch itself blows up, each batched request is
    re-dispatched alone: batchmates succeed, only the poisoned request's
    future carries the exception."""
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=engine)
    real = server._dispatch_fn

    def flaky(plan_, batch, cap):
        if any(np.isnan(np.asarray(d)).any() for d in batch):
            raise RuntimeError("poisoned request batch")
        return real(plan_, batch, cap)

    server._dispatch_fn = flaky
    good = _requests(plan, rng, 2)
    poisoned = tuple(np.asarray(d).copy() for d in good[0])
    poisoned[0][0, 0] = np.nan
    server.pause()
    f1 = server.submit(good[0])
    f2 = server.submit(poisoned)
    f3 = server.submit(good[1])
    server.resume()
    r1 = np.asarray(f1.result(timeout=60))
    r3 = np.asarray(f3.result(timeout=60))
    with pytest.raises(RuntimeError, match="poisoned"):
        f2.result(timeout=60)
    # batchmates got real answers (match a clean per-request dispatch)
    ref = FigaroEngine(donate_data=False)
    for r, req in ((r1, good[0]), (r3, good[1])):
        ri = np.asarray(ref.qr(plan, list(req), dtype=jnp.float64))
        np.testing.assert_allclose(r, ri,
                                   atol=1e-10 * max(np.abs(ri).max(), 1.0))
    server.close()


# -- streaming submit/append with zero retraces -------------------------------


def test_interleaved_submit_append_zero_retraces_in_capacity(rng):
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    live = lambda: tuple(
        rng.normal(size=(ds.stats()["nodes"][nm]["live_rows"],
                         ds.tree.db[nm].num_data_cols))
        for nm in ds.tree.preorder())
    for step in range(3):
        r = server.submit(live()).result(timeout=60)
        assert np.asarray(r).shape == (ds.plan.num_cols, ds.plan.num_cols)
        in_cap = server.append("Orders", ({"cust": np.array([step]),
                                           "prod": np.array([step % 4])},
                                          np.ones((1, 2)) * step))
        assert in_cap, "append within headroom must keep the signature"
    server.submit(live()).result(timeout=60)
    st = ds.stats()
    assert st["traces"]["qr_batched"] == 1, \
        "streaming submit+append in capacity must be zero-retrace"
    assert st["appends"] == 3 and st["regrows"] == 0
    server.close()


def test_append_drains_in_flight_requests(rng):
    """append must answer queued requests (validated against the old
    capacities) before swapping the plan."""
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    reqs = _requests(ds.plan, rng, 3)
    server.pause()
    futures = [server.submit(r) for r in reqs]
    server.resume()
    server.append("Orders", ({"cust": np.array([0]), "prod": np.array([0])},
                             np.ones((1, 2))))
    assert all(f.done() for f in futures), "append must drain the queue"
    for f in futures:
        assert np.asarray(f.result()).shape \
            == (ds.plan.num_cols, ds.plan.num_cols)
    server.close()


# -- shared plan holder: no dataset/server fork -------------------------------


def test_server_append_keeps_dataset_in_sync_and_vice_versa():
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    live0 = ds.stats()["nodes"]["Orders"]["live_rows"]

    # server -> dataset
    assert server.append("Orders", ({"cust": np.array([0, 1]),
                                     "prod": np.array([0, 1])},
                                    np.ones((2, 2))))
    assert ds.stats()["nodes"]["Orders"]["live_rows"] == live0 + 2
    assert ds.plan is server.plan, "dataset and server plan state forked"
    assert ds.stats()["appends"] == 1

    # dataset -> server
    assert ds.append("Orders", {"cust": np.array([2]),
                                "prod": np.array([2])}, np.ones((1, 2)))
    assert server.plan is ds.plan
    rows = int(server.plan.source_tree.db["Orders"].num_rows)
    assert rows == live0 + 3
    assert ds.stats()["appends"] == 2

    # two servers over one dataset share the same holder too
    server2 = ds.serve(kind="svd", dtype=jnp.float64)
    assert server2.plan is server.plan
    server.close()
    server2.close()


# -- sharded async path (in-process 1-device mesh; multi-device in CI) --------


def test_async_server_over_data_mesh_matches_per_sample(rng):
    plan = build_plan(_star_tree())
    engine = FigaroEngine(donate_data=False)
    mesh = make_data_mesh()
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=engine, mesh=mesh)
    reqs = _requests(plan, rng, 3)
    server.pause()
    futures = [server.submit(r) for r in reqs]
    server.resume()
    ref = FigaroEngine(donate_data=False)
    for f, req in zip(futures, reqs):
        ri = np.asarray(ref.qr(plan, list(req), dtype=jnp.float64))
        np.testing.assert_allclose(np.asarray(f.result(timeout=60)), ri,
                                   atol=1e-10 * max(np.abs(ri).max(), 1.0))
    assert engine.trace_count("qr_batched") == 1
    server.close()


# -- surface contracts --------------------------------------------------------


def test_serve_kinds_single_source_of_truth():
    assert figaro.SERVE_KINDS == SERVE_KINDS == ("qr", "svd", "pca", "lsq")
    from repro.api import SERVE_KINDS as api_kinds

    assert api_kinds is SERVE_KINDS
    # one validator, both surfaces
    ds = _star_ds(figaro.Session())
    with pytest.raises(ValueError, match="supported kinds: qr, svd, pca, lsq"):
        ds.serve(kind="cholesky")
    cap = build_capacity_plan(_star_tree())
    with pytest.raises(ValueError, match="supported kinds: qr, svd, pca, lsq"):
        make_figaro_server(cap, kind="cholesky")


def test_sync_server_is_async_server():
    cap = build_capacity_plan(_star_tree())
    server = make_figaro_server(cap, kind="qr", dtype=jnp.float64,
                                engine=FigaroEngine(donate_data=False))
    assert isinstance(server, FigaroServer)
    assert isinstance(server, AsyncFigaroServer)
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(tuple(np.asarray(d) for d in cap.data))
    server.close()  # idempotent


def test_append_on_paused_server_does_not_deadlock(rng):
    """flush/append release a pause() hold: append drains every attached
    server, so a held coalescer with queued work must drain, not deadlock."""
    sess = figaro.Session(headroom=16)
    ds = _star_ds(sess)
    server = ds.serve(kind="qr", dtype=jnp.float64)
    server.pause()
    fut = server.submit(_requests(ds.plan, rng, 1)[0])
    # no resume(): append itself must release the hold and drain
    assert ds.append("Orders", {"cust": np.array([0]),
                                "prod": np.array([0])}, np.ones((1, 2)))
    assert fut.done()
    server.close()


def test_coalescer_respects_max_batch_for_sub_batches(rng):
    """Two B=3 sub-batches under max_batch=4 must dispatch as two groups
    (caps 4+4), never one coalesced B=6 group in a B=8 bucket."""
    plan = build_plan(_star_tree())
    server = make_figaro_server(plan, kind="qr", dtype=jnp.float64,
                                engine=FigaroEngine(donate_data=False),
                                max_batch=4)
    seen = []
    real = server._dispatch_fn

    def spy(plan_, batch, cap):
        seen.append((int(np.shape(batch[0])[0]), cap))
        return real(plan_, batch, cap)

    server._dispatch_fn = spy
    b3 = _stack(_requests(plan, rng, 3))
    server.pause()
    futures = [server.submit(b3), server.submit(b3)]
    server.resume()
    for f in futures:
        assert np.asarray(f.result(timeout=60)).shape[0] == 3
    assert seen == [(3, 4), (3, 4)], seen
    server.close()


def test_abandoned_server_threads_exit():
    """Dropping a server without close() must not leak its worker threads:
    the finalizer's shutdown reaches both loops even though the weakref is
    already dead."""
    import gc
    import time as _time

    cap = build_capacity_plan(_star_tree())
    server = make_figaro_server(cap, kind="qr", dtype=jnp.float64,
                                engine=FigaroEngine(donate_data=False))
    server(tuple(np.asarray(d) for d in cap.data))  # starts the threads
    threads = list(server._threads)
    assert all(t.is_alive() for t in threads)
    del server
    gc.collect()
    deadline = _time.time() + 10.0
    while any(t.is_alive() for t in threads) and _time.time() < deadline:
        _time.sleep(0.05)
    assert not any(t.is_alive() for t in threads), \
        "abandoned server leaked its dispatch/completion threads"


def test_complete_loop_fails_inflight_futures_when_server_dies():
    """A group already dispatched to the completion queue when the server is
    collected must fail its futures, not leave them unresolved forever."""
    import queue as _queue

    from repro.train import async_serve as asv

    item = asv._Request()
    later = asv._Request()
    out_q = _queue.Queue()
    out_q.put(([item], [item], None))
    out_q.put(([later], [later], None))
    asv._complete_loop(lambda: None, out_q)  # dead weakref from the start
    for it in (item, later):
        assert it.future.done()
        with pytest.raises(RuntimeError, match="garbage-collected"):
            it.future.result(timeout=0)


def test_constructor_validation():
    cap = build_capacity_plan(_star_tree())
    with pytest.raises(ValueError, match="max_batch"):
        make_figaro_server(cap, kind="qr", max_batch=0)
    with pytest.raises(ValueError, match="queue_depth"):
        make_figaro_server(cap, kind="qr", queue_depth=0)
    with pytest.raises(ValueError, match="built plan"):
        AsyncFigaroServer(PlanHolder(), lambda *a: None)
