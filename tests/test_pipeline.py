"""Data pipeline: determinism, host sharding, prefetch, resumability."""

import numpy as np

from repro.data.pipeline import TokenPipeline


def test_batch_deterministic():
    p = TokenPipeline(512, 32, 8, seed=5)
    b1 = p.batch_at(17)
    b2 = p.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_host_sharding_disjoint_and_deterministic():
    full = TokenPipeline(512, 16, 8, seed=1)
    h0 = TokenPipeline(512, 16, 8, seed=1, host_id=0, num_hosts=2)
    h1 = TokenPipeline(512, 16, 8, seed=1, host_id=1, num_hosts=2)
    assert h0.local_batch == h1.local_batch == 4
    b0, b1 = h0.batch_at(3), h1.batch_at(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # different streams
    np.testing.assert_array_equal(b0["tokens"], h0.batch_at(3)["tokens"])


def test_prefetch_iterator_resumes_at_step():
    p = TokenPipeline(128, 8, 2, seed=2)
    it = p.start(start_step=10)
    got = next(it)
    p.stop()
    np.testing.assert_array_equal(got["tokens"], p.batch_at(10)["tokens"])


def test_tokens_in_vocab_range():
    p = TokenPipeline(64, 16, 4, seed=0)
    t = p.batch_at(0)["tokens"]
    assert t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 64
