"""figaro-lint: every rule fires on its known-bad fixture and stays quiet on
the fixed tree; suppressions, the unused report, and the committed baseline
stay exact."""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, analyze_paths, analyze_source,
                            load_baseline, unused_report)
from repro.analysis.baseline import empty_baseline, write_baseline
from repro.analysis.rules import all_rules

REPO = Path(__file__).resolve().parents[1]


def _findings(source, path="src/repro/core/fixture.py"):
    return analyze_source(textwrap.dedent(source), path, all_rules())


def _rules_fired(source, path="src/repro/core/fixture.py"):
    return {f.rule for f in _findings(source, path)}


# -- FIG001 compat pin -------------------------------------------------------

FIG001_BAD = """
    from jax.sharding import AxisType, PartitionSpec
    from jax.experimental.shard_map import shard_map
    import jax

    def mesh(devices):
        return jax.make_mesh((len(devices),), ("data",))
"""

FIG001_GOOD = """
    from jax.sharding import PartitionSpec
    from repro.compat import AxisType, make_mesh, shard_map

    def mesh(devices):
        return make_mesh((len(devices),), ("data",))
"""


def test_fig001_fires_on_direct_imports():
    findings = [f for f in _findings(FIG001_BAD) if f.rule == "FIG001"]
    msgs = "\n".join(f.message for f in findings)
    assert "AxisType" in msgs
    assert "shard_map" in msgs
    assert "jax.make_mesh" in msgs
    # PartitionSpec is version-stable: not flagged.
    assert "PartitionSpec" not in msgs


def test_fig001_quiet_on_compat_routed():
    assert "FIG001" not in _rules_fired(FIG001_GOOD)


def test_fig001_exempts_the_shim_itself():
    assert "FIG001" not in _rules_fired(FIG001_BAD,
                                        path="src/repro/compat.py")


# -- FIG002 retrace hazards --------------------------------------------------

FIG002_STATIC_DRIFT = """
    import functools
    import jax

    class Engine:
        _STATIC = {
            "qr": ("dtype", "use_kernel", "method"),
            "svd": ("dtype",),
        }

        def _qr_impl(self, plan, data, *, dtype, use_kernel):
            return data

        def _svd_impl(self, plan, data, *, dtype, rank):
            return data
"""

FIG002_STATIC_GOOD = """
    class Engine:
        _STATIC = {
            "qr": ("dtype", "use_kernel"),
            "svd": ("dtype", "rank"),
        }

        def _qr_impl(self, plan, data, *, dtype, use_kernel):
            return data

        def _svd_impl(self, plan, data, *, dtype, rank):
            return data
"""

FIG002_PLAN_CLOSURE = """
    import jax

    def make_fn(plan, dtype):
        def fn(data):
            return run(plan, data, dtype)
        return jax.jit(fn)
"""

FIG002_PLAN_ARG = """
    import jax

    def make_fn(dtype):
        def fn(plan, data):
            return run(plan, data, dtype)
        return jax.jit(fn)
"""

FIG002_BAD_STATIC_NAMES = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("dtype", "methodd"))
    def solve(data, *, dtype, method=None):
        return data
"""

FIG002_UNHASHABLE = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("opts",))
    def solve(data, *, opts=[]):
        return data
"""


def test_fig002_static_table_drift():
    msgs = [f.message for f in _findings(FIG002_STATIC_DRIFT)
            if f.rule == "FIG002"]
    joined = "\n".join(msgs)
    assert "'method'" in joined and "does not accept" in joined
    assert "'rank'" in joined and "missing impl keyword" in joined


def test_fig002_static_table_in_sync_is_quiet():
    assert "FIG002" not in _rules_fired(FIG002_STATIC_GOOD)


def test_fig002_plan_closure():
    msgs = [f.message for f in _findings(FIG002_PLAN_CLOSURE)
            if f.rule == "FIG002"]
    assert any("captures plan value" in m for m in msgs)


def test_fig002_plan_as_argument_is_quiet():
    assert "FIG002" not in _rules_fired(FIG002_PLAN_ARG)


def test_fig002_unknown_static_name():
    msgs = [f.message for f in _findings(FIG002_BAD_STATIC_NAMES)
            if f.rule == "FIG002"]
    assert any("methodd" in m for m in msgs)


def test_fig002_unhashable_static_default():
    msgs = [f.message for f in _findings(FIG002_UNHASHABLE)
            if f.rule == "FIG002"]
    assert any("unhashable" in m for m in msgs)


# -- FIG003 dtype drift ------------------------------------------------------

FIG003_BAD = """
    import jax.numpy as jnp

    def scan(x):
        acc = x.astype(jnp.float32)
        return acc.sum()
"""

FIG003_GOOD = """
    import jax.numpy as jnp

    def scan(x, *, dtype=jnp.float32):
        acc_dtype = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
        acc = x.astype(acc_dtype)
        return acc.sum()
"""


def test_fig003_fires_on_hardcoded_narrowing():
    assert "FIG003" in _rules_fired(FIG003_BAD,
                                    path="src/repro/kernels/fix.py")


def test_fig003_quiet_on_accumulator_idiom_and_defaults():
    assert "FIG003" not in _rules_fired(FIG003_GOOD,
                                        path="src/repro/kernels/fix.py")


def test_fig003_out_of_scope_paths_ignored():
    # The policy covers core/ and kernels/; models/ may pick working dtypes.
    assert "FIG003" not in _rules_fired(FIG003_BAD,
                                        path="src/repro/models/fix.py")


def test_fig003_counts_file_rejects_even_the_idiom():
    fired = _findings(FIG003_GOOD, path="src/repro/core/counts.py")
    msgs = [f.message for f in fired if f.rule == "FIG003"]
    assert any("float64" in m and "2^24" in m for m in msgs)


# -- FIG004 pallas kernel sites ----------------------------------------------

FIG004_BAD = """
    import jax
    from jax.experimental import pallas as pl

    def launch(x, bm, bn):
        m, n = x.shape
        grid = (m // bm, n // bn)
        return pl.pallas_call(kernel, grid=grid,
                              out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                              )(x)
"""

FIG004_GOOD = """
    import jax
    from jax.experimental import pallas as pl
    from repro.kernels._platform import resolve_interpret

    def launch(x, bm, bn, *, interpret=None):
        m, n = x.shape
        mp = -(-m // bm) * bm
        np_ = -(-n // bn) * bn
        grid = (mp // bm, np_ // bn)
        return pl.pallas_call(kernel, grid=grid,
                              out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                              interpret=resolve_interpret(interpret),
                              )(x)
"""

FIG004_FORWARD = """
    def launch(x, *, interpret=None):
        return inner(x, interpret=interpret)
"""

FIG004_AUTOTUNE_BAD = """
    AUTOTUNE = {
        (4, 128): (512, 200),
        (4, None): (4096, 4096),
        (8, 512): (132, 256),
    }
"""

FIG004_AUTOTUNE_GOOD = """
    AUTOTUNE = {
        (4, 128): (512, 128),
        (4, None): (128, 512),
        (8, 512): (128, 256),
        (8, None): (64, 512),
    }
"""


def test_fig004_missing_interpret_and_unpadded_grid():
    msgs = [f.message for f in _findings(FIG004_BAD) if f.rule == "FIG004"]
    joined = "\n".join(msgs)
    assert "without interpret=" in joined
    assert "floor-divides" in joined


def test_fig004_resolved_interpret_and_padded_grid_quiet():
    assert "FIG004" not in _rules_fired(FIG004_GOOD)


def test_fig004_raw_interpret_forwarding():
    msgs = [f.message for f in _findings(FIG004_FORWARD)
            if f.rule == "FIG004"]
    assert any("forwards its unresolved interpret" in m for m in msgs)


def test_fig004_autotune_budget_alignment_catchall():
    msgs = [f.message for f in _findings(FIG004_AUTOTUNE_BAD)
            if f.rule == "FIG004"]
    joined = "\n".join(msgs)
    assert "lane-aligned" in joined        # (512, 200)
    assert "VMEM" in joined                # (4096, 4096) busts the budget
    assert "sublane-aligned" in joined     # (132, 256)
    assert "catch-all" in joined           # itemsize 8 has no None bound


def test_fig004_autotune_good_table_quiet():
    assert "FIG004" not in _rules_fired(FIG004_AUTOTUNE_GOOD)


# -- FIG005 lock discipline --------------------------------------------------

FIG005_BAD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def bump(self):
            self.count += 1
"""

FIG005_GOOD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def bump(self):
            with self._lock:
                self.count += 1

        def read(self):
            return self.count
"""

FIG005_NO_LOCKS = """
    class Plain:
        def __init__(self):
            self.count = 0

        def bump(self):
            self.count += 1
"""


def test_fig005_unlocked_write_fires():
    msgs = [f.message for f in _findings(FIG005_BAD) if f.rule == "FIG005"]
    assert any("Server.bump" in m and "self.count" in m for m in msgs)


def test_fig005_locked_write_and_reads_quiet():
    assert "FIG005" not in _rules_fired(FIG005_GOOD)


def test_fig005_lockless_classes_exempt():
    assert "FIG005" not in _rules_fired(FIG005_NO_LOCKS)


# -- FIG006 cross-thread escape ----------------------------------------------

FIG006_BAD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            self.items = []

        def bump(self):
            with self._lock:
                self.count += 1
                self.items.append(1)

        def stats(self):
            return self.count

        def note(self):
            self.items.append(2)
"""

FIG006_GOOD = """
    import threading
    import queue

    class Server:
        _san_atomic = ("flag",)

        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            self.frozen = 41
            self.q = queue.Queue()
            self.flag = False

        def bump(self):
            with self._lock:
                self.count += 1
                self._grow()

        def _grow(self):
            self.count += 1

        def stats(self):
            with self._lock:
                return self.count

        def lockfree(self):
            self.q.put(1)           # thread-safe factory
            self.flag = True        # figaro-lint: disable=FIG005 -- atomic
            return self.frozen + (1 if self.flag else 0)
"""

FIG006_THREAD_ENTRY = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            threading.Thread(target=self._loop).start()

        def _loop(self):
            return self.count

        def bump(self):
            with self._lock:
                self.count += 1
"""


def test_fig006_unlocked_read_and_mutcall_fire():
    msgs = [f.message for f in _findings(FIG006_BAD) if f.rule == "FIG006"]
    assert any("Server.stats reads" in m and "self.count" in m for m in msgs)
    assert any("Server.note mutates (in place)" in m and "self.items" in m
               for m in msgs)
    # the locked accesses in bump() are not findings
    assert not any("Server.bump" in m for m in msgs)


def test_fig006_exemptions_quiet():
    """Locked reads, immutable attrs, thread-safe factories, _san_atomic
    annotations, and interprocedurally-locked private helpers all pass."""
    assert "FIG006" not in _rules_fired(FIG006_GOOD)


def test_fig006_thread_entry_never_inherits_lock():
    """A method whose bound reference escapes to a Thread target is a thread
    entry: its unlocked read is a finding even though its only in-class
    'call site' is the escape itself."""
    msgs = [f.message for f in _findings(FIG006_THREAD_ENTRY)
            if f.rule == "FIG006"]
    assert any("Server._loop reads" in m and "self.count" in m for m in msgs)


def test_fig006_does_not_duplicate_fig005_writes():
    """Plain unlocked writes stay FIG005 findings only."""
    findings = _findings(FIG005_BAD)
    assert "FIG005" in {f.rule for f in findings}
    assert "FIG006" not in {f.rule for f in findings}


# -- FIG007 sanitizer routing ------------------------------------------------

FIG007_BAD = """
    import threading

    def start(worker):
        lock = threading.Lock()
        t = threading.Thread(target=worker, daemon=True)
        t.start()
        return lock, t
"""

FIG007_GOOD = """
    import threading

    from repro.sanitizer.locks import san_lock
    from repro.sanitizer.threads import san_thread

    def start(worker):
        lock = san_lock("start.lock")
        t = san_thread(worker, daemon=True)
        t.start()
        gate = threading.Event()      # not modelled: allowed raw
        sem = threading.Semaphore(4)  # not modelled: allowed raw
        return lock, t, gate, sem
"""


def test_fig007_raw_threading_in_src_fires():
    msgs = [f.message for f in _findings(FIG007_BAD) if f.rule == "FIG007"]
    assert any("threading.Lock" in m and "san_lock" in m for m in msgs)
    assert any("threading.Thread" in m and "san_thread" in m for m in msgs)


def test_fig007_wrappers_and_unmodelled_primitives_quiet():
    assert "FIG007" not in _rules_fired(FIG007_GOOD)


def test_fig007_out_of_scope_paths_ignored():
    assert "FIG007" not in _rules_fired(
        FIG007_BAD, path="tests/test_stress.py")
    assert "FIG007" not in _rules_fired(
        FIG007_BAD, path="src/repro/sanitizer/locks.py")


# -- FIG008 jax-free planner -------------------------------------------------

FIG008_BAD = """
    import jax
    import jax.numpy as jnp
    from repro.core.join_tree import JoinTree

    def score(tree):
        return jnp.sum(jax.numpy.ones(3))
"""

FIG008_GOOD = """
    from typing import TYPE_CHECKING

    import numpy as np

    from repro.planner.stats import DatabaseStats
    from .cost import orientation_cost

    if TYPE_CHECKING:
        from repro.core.join_tree import JoinTree  # typing only: erased

    def score(stats):
        return float(np.sum([1.0]))
"""


def test_fig008_fires_on_jax_and_runtime_imports_in_planner():
    msgs = [f.message for f in _findings(
        FIG008_BAD, path="src/repro/planner/fixture.py")
        if f.rule == "FIG008"]
    assert any("`jax`" in m for m in msgs)
    assert any("`jax.numpy`" in m for m in msgs)
    assert any("repro.core.join_tree" in m and "duck-type" in m
               for m in msgs)


def test_fig008_quiet_on_numpy_stdlib_and_type_checking():
    assert "FIG008" not in _rules_fired(
        FIG008_GOOD, path="src/repro/planner/fixture.py")


def test_fig008_out_of_scope_paths_ignored():
    # jax imports everywhere else in the runtime are the normal state.
    assert "FIG008" not in _rules_fired(
        FIG008_BAD, path="src/repro/core/fixture.py")


def test_fig008_planner_sources_are_clean():
    findings = analyze_paths([str(REPO / "src" / "repro" / "planner")],
                             rules=all_rules(), root=str(REPO))
    assert [f for f in findings if f.rule == "FIG008"] == []


def test_fix_hint_rendered_in_human_output():
    finding = next(f for f in _findings(FIG007_BAD) if f.rule == "FIG007")
    rendered = finding.render()
    assert "\n    fix: " in rendered and finding.fix_hint in rendered


# -- suppressions ------------------------------------------------------------

def test_line_suppression_silences_only_that_line():
    src = """
    import jax.numpy as jnp

    def f(x):
        a = x.astype(jnp.float32)  # figaro-lint: disable=FIG003 -- test
        b = x.astype(jnp.float32)
        return a + b
    """
    findings = _findings(src, path="src/repro/core/fix.py")
    lines = [f.line for f in findings if f.rule == "FIG003"]
    assert len(lines) == 1  # only the unsuppressed write remains


def test_file_suppression_silences_the_module():
    src = """
    # figaro-lint: disable-file=FIG003 -- fixture corpus
    import jax.numpy as jnp

    def f(x):
        return x.astype(jnp.float32)
    """
    assert "FIG003" not in _rules_fired(src, path="src/repro/core/fix.py")


def test_suppression_in_string_literal_is_inert():
    src = '''
    import jax.numpy as jnp

    NOTE = "# figaro-lint: disable-file=FIG003 -- not a comment"

    def f(x):
        return x.astype(jnp.float32)
    '''
    assert "FIG003" in _rules_fired(src, path="src/repro/core/fix.py")


def test_syntax_error_surfaces_as_fig000():
    findings = _findings("def broken(:\n    pass\n")
    assert [f.rule for f in findings] == ["FIG000"]


# -- baseline ----------------------------------------------------------------

def test_baseline_roundtrip_and_staleness(tmp_path):
    findings = _findings(FIG003_BAD, path="src/repro/kernels/fix.py")
    assert findings
    path = tmp_path / "baseline.json"
    write_baseline(str(path), findings)
    baseline = load_baseline(str(path))
    new, baselined = baseline.split(findings)
    assert not new and len(baselined) == len(findings)
    assert baseline.stale(findings) == []
    # After the violation is fixed the entry goes stale.
    assert baseline.stale([]) == [f.fingerprint() for f in findings]


def test_empty_baseline_covers_nothing():
    findings = _findings(FIG003_BAD, path="src/repro/kernels/fix.py")
    new, baselined = empty_baseline().split(findings)
    assert new == findings and baselined == []


# -- the real tree -----------------------------------------------------------

def test_repo_matches_committed_baseline_exactly():
    """The committed analysis_baseline.json is exact: no un-baselined
    findings in src/, and no stale entries (fixed violations must drop out
    of the baseline)."""
    findings = analyze_paths([str(REPO / "src")], root=str(REPO))
    baseline = load_baseline(str(REPO / "analysis_baseline.json"))
    new, _ = baseline.split(findings)
    assert new == [], "non-baselined findings:\n" + \
        "\n".join(f.render() for f in new)
    assert baseline.stale(findings) == []


def test_repo_import_graph_has_no_orphans():
    report = unused_report(src_root=str(REPO / "src"))
    assert report["orphans"] == [], (
        "dead modules (unreachable and unreferenced): "
        f"{report['orphans']}")
    # The quarantined seed scaffolding stays listed, not silently dropped.
    for mod, info in report["modules"].items():
        if info["class"] == "external-only":
            assert info["referenced_by"], mod


def test_unused_report_on_synthetic_package(tmp_path):
    src = tmp_path / "src"
    pkg = src / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "figaro.py").write_text("from repro import used\n")
    (pkg / "used.py").write_text("X = 1\n")
    (pkg / "dead.py").write_text("Y = 2\n")
    (pkg / "tested.py").write_text("Z = 3\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_t.py").write_text("import repro.tested\n")
    report = unused_report(src_root=str(src),
                           external_dirs=[str(tests)],
                           roots=["repro.figaro"])
    classes = {m: i["class"] for m, i in report["modules"].items()}
    assert classes["repro.used"] == "facade"
    assert classes["repro.tested"] == "external-only"
    assert classes["repro.dead"] == "orphan"
    assert report["orphans"] == ["repro.dead"]


# -- figaro-flow: call graph -------------------------------------------------

import ast as _ast  # noqa: E402

from repro.analysis.callgraph import Program  # noqa: E402
from repro.analysis.framework import FileContext, load_program  # noqa: E402


def _program(*files):
    """Program over in-memory (path, source) modules."""
    ctxs = []
    for path, source in files:
        src = textwrap.dedent(source)
        ctxs.append(FileContext(path, src, _ast.parse(src)))
    return Program(ctxs)


def test_callgraph_aliased_import_resolution():
    prog = _program(
        ("src/repro/core/alib.py", """
            def helper(x):
                return x + 1
        """),
        ("src/repro/core/blib.py", """
            from repro.core.alib import helper as h

            def caller(x):
                return h(x)
        """))
    edges = prog.graph.edges["repro.core.blib:caller"]
    assert "repro.core.alib:helper" in edges


def test_callgraph_self_dispatch_and_jit_decorator():
    prog = _program(("src/repro/core/eng.py", """
        import jax

        class Eng:
            def _qr_impl(self, plan, data):
                return self._one(data)

            def _one(self, d):
                return d

        @jax.jit
        def fast(x):
            return slow(x)

        def slow(x):
            return x

        def host(x):
            return x
    """))
    g = prog.graph
    assert "repro.core.eng:Eng._one" in g.edges["repro.core.eng:Eng._qr_impl"]
    assert g.roots["repro.core.eng:Eng._qr_impl"].kind == "engine-impl"
    assert g.roots["repro.core.eng:fast"].kind == "jax.jit"
    # Transitivity: slow is traced via fast; host stays host.
    assert "repro.core.eng:slow" in g.traced
    assert "repro.core.eng:Eng._one" in g.traced
    assert "repro.core.eng:host" not in g.traced


def test_callgraph_shard_map_and_function_arg_roots():
    prog = _program(("src/repro/core/dist.py", """
        from repro.compat import shard_map

        def body(block):
            return combine(block)

        def combine(b):
            return b

        def launch(mesh, x):
            return shard_map(body, mesh=mesh)(x)
    """))
    g = prog.graph
    assert g.roots["repro.core.dist:body"].kind == "shard_map"
    assert "repro.core.dist:combine" in g.traced


def test_callgraph_report_renders_classification():
    prog = _program(("src/repro/core/eng.py", """
        import jax

        @jax.jit
        def fast(x):
            return x
    """))
    text = prog.graph.render_text()
    assert "traced root [jax.jit]" in text
    dot = prog.graph.render_dot()
    assert "digraph figaro_flow" in dot and "fast" in dot
    js = prog.graph.to_json()
    assert js["functions"]["repro.core.eng:fast"]["root"] == "jax.jit"


def test_load_program_over_repo_src():
    prog = load_program([str(REPO / "src" / "repro" / "analysis")],
                        root=str(REPO))
    assert len(prog.graph.functions) > 50
    # The analysis package is jax-free: no traced regions at all.
    assert not prog.graph.roots


# -- FIG009 host sync (figaro-flow dataflow) ---------------------------------

FIG009_BAD_CHAIN = """
    import jax
    import numpy as np

    @jax.jit
    def entry(x):
        return level1(x)

    def level1(a):
        return level2(a * 2)

    def level2(b):
        return np.asarray(b)
"""

FIG009_GOOD_META = """
    import jax
    import numpy as np

    @jax.jit
    def entry(x):
        rows = int(x.shape[0])
        return level1(x, rows)

    def level1(a, rows):
        return a * rows
"""

FIG009_GOOD_HOST = """
    import numpy as np

    def host_path(x):
        return np.asarray(x)
"""


def test_fig009_fires_through_three_deep_chain():
    findings = [f for f in _findings(FIG009_BAD_CHAIN)
                if f.rule == "FIG009"]
    assert findings, "np.asarray on traced value two calls deep must fire"
    f = findings[0]
    assert "np.asarray" in f.message
    # The dataflow fixpoint attributes the sink to level2, traced via the
    # root chain.
    assert f.traced_context[0] == "entry"
    assert f.traced_context[-1] == "level2"
    assert f.to_json()["traced_context"] == list(f.traced_context)


def test_fig009_metadata_and_host_paths_quiet():
    assert "FIG009" not in _rules_fired(FIG009_GOOD_META)
    assert "FIG009" not in _rules_fired(FIG009_GOOD_HOST)


def test_fig009_static_kwonly_param_is_concrete():
    src = """
        class Eng:
            _STATIC = {"qr": ("panel",)}

            def _qr_impl(self, plan, data, *, panel):
                cols = int(panel)
                return data * cols
    """
    assert "FIG009" not in _rules_fired(src)


def test_fig009_item_sink_on_traced_value():
    src = """
        import jax

        @jax.jit
        def f(x):
            return float(x.sum())
    """
    findings = [f for f in _findings(src) if f.rule == "FIG009"]
    assert findings and "float()" in findings[0].message


# -- FIG010 trace effects ----------------------------------------------------

FIG010_BAD = """
    import jax

    CALLS = []

    @jax.jit
    def f(x):
        CALLS.append(1)
        print("tracing")
        return x * 2
"""

FIG010_BAD_SELF = """
    class Eng:
        def _qr_impl(self, plan, data):
            self.count = self.count + 1
            return data
"""

FIG010_GOOD_LOCKED = """
    import threading
    import jax

    _lock = threading.Lock()
    COUNT = [0]

    @jax.jit
    def f(x):
        with _lock:
            COUNT[0] += 1
        return x * 2
"""

FIG010_GOOD_LOCAL = """
    import jax

    @jax.jit
    def f(x):
        acc = []
        acc.append(x)
        out = {}
        out["y"] = x * 2
        return out["y"]
"""


def test_fig010_fires_on_global_mutation_and_print():
    msgs = [f.message for f in _findings(FIG010_BAD) if f.rule == "FIG010"]
    joined = "\n".join(msgs)
    assert "CALLS" in joined
    assert "print" in joined


def test_fig010_fires_on_self_write_in_impl():
    findings = [f for f in _findings(FIG010_BAD_SELF)
                if f.rule == "FIG010"]
    assert findings and "self.count" in findings[0].message


def test_fig010_lock_guarded_and_local_state_quiet():
    assert "FIG010" not in _rules_fired(FIG010_GOOD_LOCKED)
    assert "FIG010" not in _rules_fired(FIG010_GOOD_LOCAL)


# -- FIG011 donation after dispatch ------------------------------------------

FIG011_BAD_STRAIGHT = """
    def run(plan, batch):
        eng = FigaroEngine()
        r = eng.qr(plan, batch)
        return batch, r
"""

FIG011_BAD_LOOP = """
    def stream(plan, buf, n):
        eng = FigaroEngine()
        outs = []
        for _ in range(n):
            outs.append(eng.r0(plan, buf))
        return outs
"""

FIG011_GOOD_NO_DONATE = """
    def run(plan, batch):
        eng = FigaroEngine(donate_data=False)
        r = eng.qr(plan, batch)
        return batch, r
"""

FIG011_GOOD_REBIND = """
    def stream(plan, batches, n):
        eng = FigaroEngine()
        outs = []
        for buf in batches:
            outs.append(eng.r0(plan, buf))
        return outs
"""

FIG011_GOOD_FACTORY = """
    def run(plan, batch):
        eng = default_engine()
        r = eng.qr(plan, batch)
        return batch, r
"""


def test_fig011_fires_on_read_after_donating_dispatch():
    findings = [f for f in _findings(FIG011_BAD_STRAIGHT)
                if f.rule == "FIG011"]
    assert findings and "donated data position" in findings[0].message


def test_fig011_fires_on_loop_without_rebind():
    findings = [f for f in _findings(FIG011_BAD_LOOP)
                if f.rule == "FIG011"]
    assert findings and "never rebinds" in findings[0].message


def test_fig011_quiet_on_non_donating_and_rebinding_paths():
    assert "FIG011" not in _rules_fired(FIG011_GOOD_NO_DONATE)
    assert "FIG011" not in _rules_fired(FIG011_GOOD_REBIND)
    assert "FIG011" not in _rules_fired(FIG011_GOOD_FACTORY)


# -- FIG012 slab layout proofs -----------------------------------------------

FIG012_STALE_BUMP = """
    import dataclasses

    def layout(specs, preorder, make):
        row_acc = 0
        for i in reversed(preorder):
            sp = specs[i]
            specs[i] = dataclasses.replace(sp, tail_row0=row_acc,
                                           out_row0=row_acc + sp.m)
            row_acc += sp.m
        return make(r0_rows=row_acc,
                    total_rows=sum(sp.m for sp in specs))
"""

FIG012_STALE_OUT = """
    import dataclasses

    def layout(specs, preorder, make):
        row_acc = 0
        for i in reversed(preorder):
            sp = specs[i]
            specs[i] = dataclasses.replace(sp, tail_row0=row_acc,
                                           out_row0=row_acc)
            row_acc += sp.m + sp.K
        return make(r0_rows=row_acc,
                    total_rows=sum(sp.m for sp in specs))
"""

FIG012_GOOD_LAYOUT = """
    import dataclasses

    def layout(specs, preorder, make):
        row_acc = 0
        for i in reversed(preorder):
            sp = specs[i]
            specs[i] = dataclasses.replace(sp, tail_row0=row_acc,
                                           out_row0=row_acc + sp.m)
            row_acc += sp.m + sp.K
        total_rows = sum(sp.m for sp in specs)
        return make(r0_rows=row_acc, total_rows=total_rows)
"""

FIG012_BAD_BAND = """
    def bands(nodes, preorder):
        out = []
        for i in reversed(preorder):
            sp = nodes[i]
            out.append(SlabBand(node=i, kind="tail", row0=sp.out_row0,
                                rows=sp.m, col0=sp.col_start, width=sp.n))
        return out
"""

FIG012_BAD_POW2 = """
    def next_pow2(x):
        return 1 << int(x).bit_length()
"""

FIG012_BAD_PARTIAL_BUCKET = """
    import dataclasses

    def bucket(spec):
        return [dataclasses.replace(sp, m=next_pow2(sp.m),
                                    K=sp.K + 1)
                for sp in spec.nodes]
"""

FIG012_BAD_COL = """
    def columns(order, widths):
        col_start = {}
        acc = 0
        for nme in order:
            col_start[nme] = acc + 1
            acc += widths[nme]
        num_cols = acc
        return col_start, num_cols
"""

FIG012_GOOD_COL = """
    def columns(order, widths):
        col_start = {}
        acc = 0
        for nme in order:
            col_start[nme] = acc
            acc += widths[nme]
        num_cols = acc
        return col_start, num_cols
"""


def test_fig012_stale_row_bump_fires():
    msgs = [f.message for f in _findings(FIG012_STALE_BUMP)
            if f.rule == "FIG012"]
    assert any("advance by" in m for m in msgs)


def test_fig012_stale_out_row0_fires():
    msgs = [f.message for f in _findings(FIG012_STALE_OUT)
            if f.rule == "FIG012"]
    assert any("out_row0" in m for m in msgs)


def test_fig012_canonical_layout_quiet():
    assert "FIG012" not in _rules_fired(FIG012_GOOD_LAYOUT)


def test_fig012_band_contract_violation_fires():
    msgs = [f.message for f in _findings(FIG012_BAD_BAND)
            if f.rule == "FIG012"]
    assert any("tail_row0" in m for m in msgs)


def test_fig012_noncanonical_pow2_fires():
    msgs = [f.message for f in _findings(FIG012_BAD_POW2)
            if f.rule == "FIG012"]
    assert any("canonical" in m for m in msgs)


def test_fig012_partial_bucketing_fires():
    msgs = [f.message for f in _findings(FIG012_BAD_PARTIAL_BUCKET)
            if f.rule == "FIG012"]
    assert any("`K`" in m for m in msgs)


def test_fig012_column_prefix_sums():
    assert "FIG012" in _rules_fired(FIG012_BAD_COL)
    assert "FIG012" not in _rules_fired(FIG012_GOOD_COL)


def test_fig012_real_layout_modules_prove_clean():
    findings = analyze_paths(
        [str(REPO / "src" / "repro" / "core" / "join_tree.py"),
         str(REPO / "src" / "repro" / "core" / "plan_cache.py")],
        root=str(REPO))
    assert [f for f in findings if f.rule == "FIG012"] == []


# -- FIG004 upgrades: backend rows + grid one call level ---------------------

FIG004_AUTOTUNE_GPU_BAD = """
    AUTOTUNE = {
        ("gpu", 4, 128): (96, 128),
        ("gpu", 4, None): (32, 512),
        ("gpu", 8, None): (16, 512),
    }
"""

FIG004_AUTOTUNE_GPU_GOOD = """
    AUTOTUNE = {
        ("gpu", 4, 128): (128, 128),
        ("gpu", 4, None): (16, 512),
        ("gpu", 8, 128): (64, 128),
        ("gpu", 8, None): (16, 512),
    }
"""

FIG004_GRID_HELPERS_GOOD = """
    from repro.kernels._platform import resolve_interpret
    from jax.experimental import pallas as pl

    def _pad_to(x, b):
        return -(-x // b) * b

    def _grid_for(mp, np_, bm, bn):
        return (np_ // bn, mp // bm)

    def launch(kernel, m, n, bm, bn, interpret=None):
        mp = _pad_to(m, bm)
        np_ = _pad_to(n, bn)
        return pl.pallas_call(
            kernel, grid=_grid_for(mp, np_, bm, bn),
            interpret=resolve_interpret(interpret))
"""

FIG004_GRID_HELPERS_BAD = """
    from repro.kernels._platform import resolve_interpret
    from jax.experimental import pallas as pl

    def _grid_for(m, n, bm, bn):
        return (n // bn, m // bm)

    def launch(kernel, m, n, bm, bn, interpret=None):
        return pl.pallas_call(
            kernel, grid=_grid_for(m, n, bm, bn),
            interpret=resolve_interpret(interpret))
"""


def test_fig004_gpu_rows_power_of_two_and_f64_catchall():
    msgs = [f.message for f in _findings(FIG004_AUTOTUNE_GPU_BAD)
            if f.rule == "FIG004"]
    joined = "\n".join(msgs)
    assert "power of two" in joined        # (96, 128)
    assert "f64 itemsize" in joined        # (4, None)=(32,512) at 8 bytes


def test_fig004_gpu_good_table_quiet():
    assert "FIG004" not in _rules_fired(FIG004_AUTOTUNE_GPU_GOOD)


def test_fig004_grid_through_helpers():
    assert "FIG004" not in _rules_fired(FIG004_GRID_HELPERS_GOOD)
    msgs = [f.message for f in _findings(FIG004_GRID_HELPERS_BAD)
            if f.rule == "FIG004"]
    assert any("floor-divides" in m for m in msgs)


def test_real_autotune_table_passes_budget_model():
    findings = analyze_paths(
        [str(REPO / "src" / "repro" / "kernels" / "node_fused" /
             "kernel.py")], root=str(REPO))
    assert [f for f in findings if f.rule == "FIG004"] == []
