"""figaro-lint: every rule fires on its known-bad fixture and stays quiet on
the fixed tree; suppressions, the unused report, and the committed baseline
stay exact."""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, analyze_paths, analyze_source,
                            load_baseline, unused_report)
from repro.analysis.baseline import empty_baseline, write_baseline
from repro.analysis.rules import all_rules

REPO = Path(__file__).resolve().parents[1]


def _findings(source, path="src/repro/core/fixture.py"):
    return analyze_source(textwrap.dedent(source), path, all_rules())


def _rules_fired(source, path="src/repro/core/fixture.py"):
    return {f.rule for f in _findings(source, path)}


# -- FIG001 compat pin -------------------------------------------------------

FIG001_BAD = """
    from jax.sharding import AxisType, PartitionSpec
    from jax.experimental.shard_map import shard_map
    import jax

    def mesh(devices):
        return jax.make_mesh((len(devices),), ("data",))
"""

FIG001_GOOD = """
    from jax.sharding import PartitionSpec
    from repro.compat import AxisType, make_mesh, shard_map

    def mesh(devices):
        return make_mesh((len(devices),), ("data",))
"""


def test_fig001_fires_on_direct_imports():
    findings = [f for f in _findings(FIG001_BAD) if f.rule == "FIG001"]
    msgs = "\n".join(f.message for f in findings)
    assert "AxisType" in msgs
    assert "shard_map" in msgs
    assert "jax.make_mesh" in msgs
    # PartitionSpec is version-stable: not flagged.
    assert "PartitionSpec" not in msgs


def test_fig001_quiet_on_compat_routed():
    assert "FIG001" not in _rules_fired(FIG001_GOOD)


def test_fig001_exempts_the_shim_itself():
    assert "FIG001" not in _rules_fired(FIG001_BAD,
                                        path="src/repro/compat.py")


# -- FIG002 retrace hazards --------------------------------------------------

FIG002_STATIC_DRIFT = """
    import functools
    import jax

    class Engine:
        _STATIC = {
            "qr": ("dtype", "use_kernel", "method"),
            "svd": ("dtype",),
        }

        def _qr_impl(self, plan, data, *, dtype, use_kernel):
            return data

        def _svd_impl(self, plan, data, *, dtype, rank):
            return data
"""

FIG002_STATIC_GOOD = """
    class Engine:
        _STATIC = {
            "qr": ("dtype", "use_kernel"),
            "svd": ("dtype", "rank"),
        }

        def _qr_impl(self, plan, data, *, dtype, use_kernel):
            return data

        def _svd_impl(self, plan, data, *, dtype, rank):
            return data
"""

FIG002_PLAN_CLOSURE = """
    import jax

    def make_fn(plan, dtype):
        def fn(data):
            return run(plan, data, dtype)
        return jax.jit(fn)
"""

FIG002_PLAN_ARG = """
    import jax

    def make_fn(dtype):
        def fn(plan, data):
            return run(plan, data, dtype)
        return jax.jit(fn)
"""

FIG002_BAD_STATIC_NAMES = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("dtype", "methodd"))
    def solve(data, *, dtype, method=None):
        return data
"""

FIG002_UNHASHABLE = """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("opts",))
    def solve(data, *, opts=[]):
        return data
"""


def test_fig002_static_table_drift():
    msgs = [f.message for f in _findings(FIG002_STATIC_DRIFT)
            if f.rule == "FIG002"]
    joined = "\n".join(msgs)
    assert "'method'" in joined and "does not accept" in joined
    assert "'rank'" in joined and "missing impl keyword" in joined


def test_fig002_static_table_in_sync_is_quiet():
    assert "FIG002" not in _rules_fired(FIG002_STATIC_GOOD)


def test_fig002_plan_closure():
    msgs = [f.message for f in _findings(FIG002_PLAN_CLOSURE)
            if f.rule == "FIG002"]
    assert any("captures plan value" in m for m in msgs)


def test_fig002_plan_as_argument_is_quiet():
    assert "FIG002" not in _rules_fired(FIG002_PLAN_ARG)


def test_fig002_unknown_static_name():
    msgs = [f.message for f in _findings(FIG002_BAD_STATIC_NAMES)
            if f.rule == "FIG002"]
    assert any("methodd" in m for m in msgs)


def test_fig002_unhashable_static_default():
    msgs = [f.message for f in _findings(FIG002_UNHASHABLE)
            if f.rule == "FIG002"]
    assert any("unhashable" in m for m in msgs)


# -- FIG003 dtype drift ------------------------------------------------------

FIG003_BAD = """
    import jax.numpy as jnp

    def scan(x):
        acc = x.astype(jnp.float32)
        return acc.sum()
"""

FIG003_GOOD = """
    import jax.numpy as jnp

    def scan(x, *, dtype=jnp.float32):
        acc_dtype = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
        acc = x.astype(acc_dtype)
        return acc.sum()
"""


def test_fig003_fires_on_hardcoded_narrowing():
    assert "FIG003" in _rules_fired(FIG003_BAD,
                                    path="src/repro/kernels/fix.py")


def test_fig003_quiet_on_accumulator_idiom_and_defaults():
    assert "FIG003" not in _rules_fired(FIG003_GOOD,
                                        path="src/repro/kernels/fix.py")


def test_fig003_out_of_scope_paths_ignored():
    # The policy covers core/ and kernels/; models/ may pick working dtypes.
    assert "FIG003" not in _rules_fired(FIG003_BAD,
                                        path="src/repro/models/fix.py")


def test_fig003_counts_file_rejects_even_the_idiom():
    fired = _findings(FIG003_GOOD, path="src/repro/core/counts.py")
    msgs = [f.message for f in fired if f.rule == "FIG003"]
    assert any("float64" in m and "2^24" in m for m in msgs)


# -- FIG004 pallas kernel sites ----------------------------------------------

FIG004_BAD = """
    import jax
    from jax.experimental import pallas as pl

    def launch(x, bm, bn):
        m, n = x.shape
        grid = (m // bm, n // bn)
        return pl.pallas_call(kernel, grid=grid,
                              out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                              )(x)
"""

FIG004_GOOD = """
    import jax
    from jax.experimental import pallas as pl
    from repro.kernels._platform import resolve_interpret

    def launch(x, bm, bn, *, interpret=None):
        m, n = x.shape
        mp = -(-m // bm) * bm
        np_ = -(-n // bn) * bn
        grid = (mp // bm, np_ // bn)
        return pl.pallas_call(kernel, grid=grid,
                              out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                              interpret=resolve_interpret(interpret),
                              )(x)
"""

FIG004_FORWARD = """
    def launch(x, *, interpret=None):
        return inner(x, interpret=interpret)
"""

FIG004_AUTOTUNE_BAD = """
    AUTOTUNE = {
        (4, 128): (512, 200),
        (4, None): (4096, 4096),
        (8, 512): (132, 256),
    }
"""

FIG004_AUTOTUNE_GOOD = """
    AUTOTUNE = {
        (4, 128): (512, 128),
        (4, None): (128, 512),
        (8, 512): (128, 256),
        (8, None): (64, 512),
    }
"""


def test_fig004_missing_interpret_and_unpadded_grid():
    msgs = [f.message for f in _findings(FIG004_BAD) if f.rule == "FIG004"]
    joined = "\n".join(msgs)
    assert "without interpret=" in joined
    assert "floor-divides" in joined


def test_fig004_resolved_interpret_and_padded_grid_quiet():
    assert "FIG004" not in _rules_fired(FIG004_GOOD)


def test_fig004_raw_interpret_forwarding():
    msgs = [f.message for f in _findings(FIG004_FORWARD)
            if f.rule == "FIG004"]
    assert any("forwards its unresolved interpret" in m for m in msgs)


def test_fig004_autotune_budget_alignment_catchall():
    msgs = [f.message for f in _findings(FIG004_AUTOTUNE_BAD)
            if f.rule == "FIG004"]
    joined = "\n".join(msgs)
    assert "lane-aligned" in joined        # (512, 200)
    assert "VMEM" in joined                # (4096, 4096) busts the budget
    assert "sublane-aligned" in joined     # (132, 256)
    assert "catch-all" in joined           # itemsize 8 has no None bound


def test_fig004_autotune_good_table_quiet():
    assert "FIG004" not in _rules_fired(FIG004_AUTOTUNE_GOOD)


# -- FIG005 lock discipline --------------------------------------------------

FIG005_BAD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def bump(self):
            self.count += 1
"""

FIG005_GOOD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0

        def bump(self):
            with self._lock:
                self.count += 1

        def read(self):
            return self.count
"""

FIG005_NO_LOCKS = """
    class Plain:
        def __init__(self):
            self.count = 0

        def bump(self):
            self.count += 1
"""


def test_fig005_unlocked_write_fires():
    msgs = [f.message for f in _findings(FIG005_BAD) if f.rule == "FIG005"]
    assert any("Server.bump" in m and "self.count" in m for m in msgs)


def test_fig005_locked_write_and_reads_quiet():
    assert "FIG005" not in _rules_fired(FIG005_GOOD)


def test_fig005_lockless_classes_exempt():
    assert "FIG005" not in _rules_fired(FIG005_NO_LOCKS)


# -- FIG006 cross-thread escape ----------------------------------------------

FIG006_BAD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            self.items = []

        def bump(self):
            with self._lock:
                self.count += 1
                self.items.append(1)

        def stats(self):
            return self.count

        def note(self):
            self.items.append(2)
"""

FIG006_GOOD = """
    import threading
    import queue

    class Server:
        _san_atomic = ("flag",)

        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            self.frozen = 41
            self.q = queue.Queue()
            self.flag = False

        def bump(self):
            with self._lock:
                self.count += 1
                self._grow()

        def _grow(self):
            self.count += 1

        def stats(self):
            with self._lock:
                return self.count

        def lockfree(self):
            self.q.put(1)           # thread-safe factory
            self.flag = True        # figaro-lint: disable=FIG005 -- atomic
            return self.frozen + (1 if self.flag else 0)
"""

FIG006_THREAD_ENTRY = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0
            threading.Thread(target=self._loop).start()

        def _loop(self):
            return self.count

        def bump(self):
            with self._lock:
                self.count += 1
"""


def test_fig006_unlocked_read_and_mutcall_fire():
    msgs = [f.message for f in _findings(FIG006_BAD) if f.rule == "FIG006"]
    assert any("Server.stats reads" in m and "self.count" in m for m in msgs)
    assert any("Server.note mutates (in place)" in m and "self.items" in m
               for m in msgs)
    # the locked accesses in bump() are not findings
    assert not any("Server.bump" in m for m in msgs)


def test_fig006_exemptions_quiet():
    """Locked reads, immutable attrs, thread-safe factories, _san_atomic
    annotations, and interprocedurally-locked private helpers all pass."""
    assert "FIG006" not in _rules_fired(FIG006_GOOD)


def test_fig006_thread_entry_never_inherits_lock():
    """A method whose bound reference escapes to a Thread target is a thread
    entry: its unlocked read is a finding even though its only in-class
    'call site' is the escape itself."""
    msgs = [f.message for f in _findings(FIG006_THREAD_ENTRY)
            if f.rule == "FIG006"]
    assert any("Server._loop reads" in m and "self.count" in m for m in msgs)


def test_fig006_does_not_duplicate_fig005_writes():
    """Plain unlocked writes stay FIG005 findings only."""
    findings = _findings(FIG005_BAD)
    assert "FIG005" in {f.rule for f in findings}
    assert "FIG006" not in {f.rule for f in findings}


# -- FIG007 sanitizer routing ------------------------------------------------

FIG007_BAD = """
    import threading

    def start(worker):
        lock = threading.Lock()
        t = threading.Thread(target=worker, daemon=True)
        t.start()
        return lock, t
"""

FIG007_GOOD = """
    import threading

    from repro.sanitizer.locks import san_lock
    from repro.sanitizer.threads import san_thread

    def start(worker):
        lock = san_lock("start.lock")
        t = san_thread(worker, daemon=True)
        t.start()
        gate = threading.Event()      # not modelled: allowed raw
        sem = threading.Semaphore(4)  # not modelled: allowed raw
        return lock, t, gate, sem
"""


def test_fig007_raw_threading_in_src_fires():
    msgs = [f.message for f in _findings(FIG007_BAD) if f.rule == "FIG007"]
    assert any("threading.Lock" in m and "san_lock" in m for m in msgs)
    assert any("threading.Thread" in m and "san_thread" in m for m in msgs)


def test_fig007_wrappers_and_unmodelled_primitives_quiet():
    assert "FIG007" not in _rules_fired(FIG007_GOOD)


def test_fig007_out_of_scope_paths_ignored():
    assert "FIG007" not in _rules_fired(
        FIG007_BAD, path="tests/test_stress.py")
    assert "FIG007" not in _rules_fired(
        FIG007_BAD, path="src/repro/sanitizer/locks.py")


# -- FIG008 jax-free planner -------------------------------------------------

FIG008_BAD = """
    import jax
    import jax.numpy as jnp
    from repro.core.join_tree import JoinTree

    def score(tree):
        return jnp.sum(jax.numpy.ones(3))
"""

FIG008_GOOD = """
    from typing import TYPE_CHECKING

    import numpy as np

    from repro.planner.stats import DatabaseStats
    from .cost import orientation_cost

    if TYPE_CHECKING:
        from repro.core.join_tree import JoinTree  # typing only: erased

    def score(stats):
        return float(np.sum([1.0]))
"""


def test_fig008_fires_on_jax_and_runtime_imports_in_planner():
    msgs = [f.message for f in _findings(
        FIG008_BAD, path="src/repro/planner/fixture.py")
        if f.rule == "FIG008"]
    assert any("`jax`" in m for m in msgs)
    assert any("`jax.numpy`" in m for m in msgs)
    assert any("repro.core.join_tree" in m and "duck-type" in m
               for m in msgs)


def test_fig008_quiet_on_numpy_stdlib_and_type_checking():
    assert "FIG008" not in _rules_fired(
        FIG008_GOOD, path="src/repro/planner/fixture.py")


def test_fig008_out_of_scope_paths_ignored():
    # jax imports everywhere else in the runtime are the normal state.
    assert "FIG008" not in _rules_fired(
        FIG008_BAD, path="src/repro/core/fixture.py")


def test_fig008_planner_sources_are_clean():
    findings = analyze_paths([str(REPO / "src" / "repro" / "planner")],
                             rules=all_rules(), root=str(REPO))
    assert [f for f in findings if f.rule == "FIG008"] == []


def test_fix_hint_rendered_in_human_output():
    finding = next(f for f in _findings(FIG007_BAD) if f.rule == "FIG007")
    rendered = finding.render()
    assert "\n    fix: " in rendered and finding.fix_hint in rendered


# -- suppressions ------------------------------------------------------------

def test_line_suppression_silences_only_that_line():
    src = """
    import jax.numpy as jnp

    def f(x):
        a = x.astype(jnp.float32)  # figaro-lint: disable=FIG003 -- test
        b = x.astype(jnp.float32)
        return a + b
    """
    findings = _findings(src, path="src/repro/core/fix.py")
    lines = [f.line for f in findings if f.rule == "FIG003"]
    assert len(lines) == 1  # only the unsuppressed write remains


def test_file_suppression_silences_the_module():
    src = """
    # figaro-lint: disable-file=FIG003 -- fixture corpus
    import jax.numpy as jnp

    def f(x):
        return x.astype(jnp.float32)
    """
    assert "FIG003" not in _rules_fired(src, path="src/repro/core/fix.py")


def test_suppression_in_string_literal_is_inert():
    src = '''
    import jax.numpy as jnp

    NOTE = "# figaro-lint: disable-file=FIG003 -- not a comment"

    def f(x):
        return x.astype(jnp.float32)
    '''
    assert "FIG003" in _rules_fired(src, path="src/repro/core/fix.py")


def test_syntax_error_surfaces_as_fig000():
    findings = _findings("def broken(:\n    pass\n")
    assert [f.rule for f in findings] == ["FIG000"]


# -- baseline ----------------------------------------------------------------

def test_baseline_roundtrip_and_staleness(tmp_path):
    findings = _findings(FIG003_BAD, path="src/repro/kernels/fix.py")
    assert findings
    path = tmp_path / "baseline.json"
    write_baseline(str(path), findings)
    baseline = load_baseline(str(path))
    new, baselined = baseline.split(findings)
    assert not new and len(baselined) == len(findings)
    assert baseline.stale(findings) == []
    # After the violation is fixed the entry goes stale.
    assert baseline.stale([]) == [f.fingerprint() for f in findings]


def test_empty_baseline_covers_nothing():
    findings = _findings(FIG003_BAD, path="src/repro/kernels/fix.py")
    new, baselined = empty_baseline().split(findings)
    assert new == findings and baselined == []


# -- the real tree -----------------------------------------------------------

def test_repo_matches_committed_baseline_exactly():
    """The committed analysis_baseline.json is exact: no un-baselined
    findings in src/, and no stale entries (fixed violations must drop out
    of the baseline)."""
    findings = analyze_paths([str(REPO / "src")], root=str(REPO))
    baseline = load_baseline(str(REPO / "analysis_baseline.json"))
    new, _ = baseline.split(findings)
    assert new == [], "non-baselined findings:\n" + \
        "\n".join(f.render() for f in new)
    assert baseline.stale(findings) == []


def test_repo_import_graph_has_no_orphans():
    report = unused_report(src_root=str(REPO / "src"))
    assert report["orphans"] == [], (
        "dead modules (unreachable and unreferenced): "
        f"{report['orphans']}")
    # The quarantined seed scaffolding stays listed, not silently dropped.
    for mod, info in report["modules"].items():
        if info["class"] == "external-only":
            assert info["referenced_by"], mod


def test_unused_report_on_synthetic_package(tmp_path):
    src = tmp_path / "src"
    pkg = src / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "figaro.py").write_text("from repro import used\n")
    (pkg / "used.py").write_text("X = 1\n")
    (pkg / "dead.py").write_text("Y = 2\n")
    (pkg / "tested.py").write_text("Z = 3\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_t.py").write_text("import repro.tested\n")
    report = unused_report(src_root=str(src),
                           external_dirs=[str(tests)],
                           roots=["repro.figaro"])
    classes = {m: i["class"] for m, i in report["modules"].items()}
    assert classes["repro.used"] == "facade"
    assert classes["repro.tested"] == "external-only"
    assert classes["repro.dead"] == "orphan"
    assert report["orphans"] == ["repro.dead"]
